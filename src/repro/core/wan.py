"""Per-link WAN topology subsystem (paper §V/§VII; cf. Heron's green
modular-DC routing and XWind's cross-site renewable-farm router).

The seed modeled the WAN as one uniform NIC rate with fabric-wide hourly
brownouts.  :class:`WanTopology` generalizes that to

  * per-site NIC rates, asymmetric per direction (``nic_out_bps`` egress,
    ``nic_in_bps`` ingress),
  * a per-link ``(src, dst)`` capacity matrix (``np.inf`` = NIC-limited,
    ``0`` = no link / partitioned),
  * an hourly brownout calendar scoped to the whole fabric (the legacy
    flaky-WAN regime, bit-identical calendar for a given seed) or to
    individual links,

behind two query surfaces shared by every consumer (the simulator transfer
loop, ``ClusterState.build``'s advertised-bandwidth matrix, the
``launch.dryrun --plan`` planner and the ``launch.serve --green-route``
router):

  * :meth:`shared_rates` — the per-flow effective rate under fair sharing,
  * :meth:`advertised_matrix` — the policy-facing ``(n, n)`` bandwidth
    matrix under the *current* flow set.

Sharing models (``WanTopology(sharing=...)``, both used consistently by
the transfer loop and the advertised matrix):

  * ``"conservative"`` (default) — every flow traverses three resources
    (source NIC, destination NIC, the (src, dst) link) and is granted the
    minimum equal split ``cap(r) / flows(r)`` over them.  Each resource
    hands out at most its capacity, and on a uniform topology (equal
    NICs, uncapped links) the grant reduces *exactly* to the seed's
    ``min(nic / src_flows, nic / dst_flows)``.  This is the first round
    of max-min fair sharing: residual capacity that full water-filling
    would redistribute to unbottlenecked flows is left unclaimed.
  * ``"waterfill"`` — full max-min water-filling: raise every flow's rate
    in lockstep, freeze the flows crossing each resource as it saturates,
    redistribute the residual among the rest, repeat.  Per-flow rates
    dominate (are >=) the conservative split and still never oversubscribe
    any resource.  Exact-reduction caveat: waterfill coincides with the
    conservative split whenever every flow is frozen in the first round
    (e.g. all flows sharing one source or one destination NIC on a
    uniform fabric); with *several* disjoint bottlenecks a flow whose
    peers are frozen elsewhere inherits their residual, so waterfill is
    strictly greater — that residual is exactly what the conservative
    model leaves unclaimed.

:class:`WanProfile` is the scenario-composable *spec* (plain floats and
tuples, frozen); ``WanProfile.build_topology(n_sites, days, seed)``
materializes the arrays + brownout calendar.  See
:mod:`repro.core.scenarios` for registry entries (``hub-spoke-wan``,
``asymmetric-uplink``, ``partitioned-wan``).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

HOUR = 3600.0


# ---------------------------------------------------------------------------
# Scenario-facing spec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WanProfile:
    """WAN spec a :class:`~repro.core.scenarios.Scenario` composes.

    Uniform fields (the seed model): ``gbps`` per-site NIC rate, plus the
    flaky-link regime — each hour, with probability ``hourly_degrade_prob``,
    capacity drops to ``degraded_gbps`` for that hour.

    Topology fields (all optional; ``None`` keeps the uniform model):

      nic_gbps       per-site egress NIC rates, one entry per site
      nic_in_gbps    per-site ingress NIC rates (defaults to egress —
                     set both for asymmetric uplink/downlink)
      link_gbps      full (src, dst) per-link capacity matrix; ``None`` /
                     ``inf`` entries mean NIC-limited, ``0`` means no link
      brownout_scope ``"fabric"`` (whole WAN degrades at once — legacy) or
                     ``"per-link"`` (each link draws its own calendar)
      sharing        ``"conservative"`` (single-round split, legacy) or
                     ``"waterfill"`` (full max-min water-filling)
      multi_hop      allow one-relay paths: a ``src -> dst`` transfer may
                     traverse ``src -> h -> dst`` when that path's base
                     capacity strictly beats the direct link (hub-and-
                     spoke fabrics: spoke->spoke rides the hub)
    """

    gbps: float = 10.0
    hourly_degrade_prob: float = 0.0
    degraded_gbps: float = 1.0
    nic_gbps: Optional[Tuple[float, ...]] = None
    nic_in_gbps: Optional[Tuple[float, ...]] = None
    link_gbps: Optional[Tuple[Tuple[Optional[float], ...], ...]] = None
    brownout_scope: str = "fabric"
    sharing: str = "conservative"
    multi_hop: bool = False

    @property
    def is_uniform(self) -> bool:
        return (self.nic_gbps is None and self.nic_in_gbps is None
                and self.link_gbps is None)

    def build_topology(self, n_sites: int, days: int, seed: int) -> "WanTopology":
        """Materialize the runtime :class:`WanTopology` (arrays + calendar).

        The fabric-scope brownout calendar reproduces the seed's flaky-WAN
        stream bit-for-bit: ``default_rng(seed + 31).random(days*48 + 1) <
        prob``.
        """
        def per_site(vals, what):
            arr = np.asarray(vals, dtype=np.float64) * 1e9
            if arr.shape != (n_sites,):
                raise ValueError(
                    f"{what} must have one entry per site ({n_sites}), "
                    f"got shape {arr.shape}")
            return arr

        if self.nic_gbps is not None:
            nic_out = per_site(self.nic_gbps, "nic_gbps")
        else:
            nic_out = np.full(n_sites, self.gbps * 1e9, dtype=np.float64)
        if self.nic_in_gbps is not None:
            nic_in = per_site(self.nic_in_gbps, "nic_in_gbps")
        else:
            nic_in = nic_out.copy()

        link = np.full((n_sites, n_sites), np.inf, dtype=np.float64)
        if self.link_gbps is not None:
            rows = self.link_gbps
            if len(rows) != n_sites or any(len(r) != n_sites for r in rows):
                raise ValueError(
                    f"link_gbps must be a {n_sites}x{n_sites} matrix")
            for s, row in enumerate(rows):
                for d, cap in enumerate(row):
                    if cap is not None:
                        link[s, d] = float(cap) * 1e9

        mask = None
        if self.hourly_degrade_prob > 0.0:
            n_hours = int(days * 24 * 2) + 1  # seed calendar length (2x slack)
            rng = np.random.default_rng(seed + 31)
            if self.brownout_scope == "fabric":
                mask = rng.random(n_hours) < self.hourly_degrade_prob
            elif self.brownout_scope == "per-link":
                mask = rng.random((n_hours, n_sites, n_sites)) < self.hourly_degrade_prob
                mask[:, np.arange(n_sites), np.arange(n_sites)] = False
            else:
                raise ValueError(
                    f"brownout_scope must be 'fabric' or 'per-link', "
                    f"got {self.brownout_scope!r}")
        return WanTopology(nic_out, nic_in, link, mask,
                           self.degraded_bps, self.sharing, self.multi_hop)

    @property
    def degraded_bps(self) -> float:
        return self.degraded_gbps * 1e9


# ---------------------------------------------------------------------------
# Runtime topology
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class WanTopology:
    """Materialized WAN: per-site NIC rate arrays, per-link capacity matrix
    and an optional hourly brownout calendar.  All rates in bits/s."""

    nic_out_bps: np.ndarray  # (n,) egress NIC per site
    nic_in_bps: np.ndarray  # (n,) ingress NIC per site
    link_bps: np.ndarray  # (n, n); inf = NIC-limited, 0 = no link
    brownout_mask: Optional[np.ndarray] = None  # (n_hours,) or (n_hours, n, n)
    degraded_bps: float = 0.0
    sharing: str = "conservative"  # or "waterfill" (full max-min)
    multi_hop: bool = False  # allow one-relay src->h->dst paths

    def __post_init__(self):
        n = len(self.nic_out_bps)
        if self.nic_in_bps.shape != (n,) or self.link_bps.shape != (n, n):
            raise ValueError("inconsistent WanTopology array shapes")
        if self.sharing not in ("conservative", "waterfill"):
            raise ValueError(
                f"sharing must be 'conservative' or 'waterfill', "
                f"got {self.sharing!r}")

    # -- basic facts ---------------------------------------------------------
    @property
    def n_sites(self) -> int:
        return len(self.nic_out_bps)

    @classmethod
    def uniform(cls, n_sites: int, nic_bps: float) -> "WanTopology":
        """The seed model: one symmetric NIC rate, uncapped links."""
        nic = np.full(n_sites, float(nic_bps))
        return cls(nic, nic.copy(), np.full((n_sites, n_sites), np.inf))

    @property
    def is_uniform(self) -> bool:
        return bool(
            np.isinf(self.link_bps).all()
            and (self.nic_out_bps == self.nic_out_bps[0]).all()
            and (self.nic_in_bps == self.nic_out_bps[0]).all()
        )

    # -- brownout calendar ---------------------------------------------------
    def _hour(self, t: float) -> int:
        return min(int(t // HOUR), len(self.brownout_mask) - 1)

    def _state_key(self, t: float):
        """Hashable id of the link state at ``t`` (fabric: one bool; per-
        link: the hour index) — the cache key for derived capacity arrays."""
        m = self.brownout_mask
        if m is None:
            return None
        h = self._hour(t)
        return bool(m[h]) if m.ndim == 1 else h

    @cached_property
    def _resource_cache(self) -> dict:
        return {}

    def resources_at(self, t: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(nic_out, nic_in, link) capacities at sim-time ``t`` with the
        brownout calendar applied.  Fabric scope degrades every resource
        (shared-backbone brownout — reduces to the seed's degraded NIC
        rate); per-link scope degrades only the affected links.  Cached per
        link state; treat the returned arrays as read-only."""
        key = self._state_key(t)
        cached = self._resource_cache.get(key)
        if cached is not None:
            return cached
        out, in_, link = self.nic_out_bps, self.nic_in_bps, self.link_bps
        m = self.brownout_mask
        if m is not None:
            if m.ndim == 1:  # fabric scope
                if key:
                    d = self.degraded_bps
                    out, in_, link = (np.minimum(out, d), np.minimum(in_, d),
                                      np.minimum(link, d))
            else:
                bad = m[self._hour(t)]
                if bad.any():
                    link = np.where(bad, np.minimum(link, self.degraded_bps),
                                    link)
        res = (out, in_, link)
        self._resource_cache[key] = res
        return res

    @cached_property
    def _brownout_edges(self) -> List[float]:
        """Times at which the brownout state changes (hour boundaries)."""
        m = self.brownout_mask
        if m is None:
            return []
        return [h * HOUR for h in range(1, len(m))
                if np.any(m[h] != m[h - 1])]

    def next_transition(self, t: float) -> float:
        """Next sim-time the link state changes (inf if never) — an event
        source for the next-event engine."""
        edges = self._brownout_edges
        i = bisect.bisect_right(edges, t)
        return edges[i] if i < len(edges) else float("inf")

    def nic_bps_at(self, t: float) -> float:
        """Fabric NIC rate at ``t`` for (near-)uniform topologies — the
        legacy ``ClusterSimulator._nic_bps`` scalar."""
        return float(self.resources_at(t)[0].max())

    # -- multi-hop relay table -----------------------------------------------
    @cached_property
    def relay(self) -> Optional[np.ndarray]:
        """(n, n) relay table for ``multi_hop`` fabrics: ``relay[s, d]`` is
        the relay site ``h`` when the one-hop path ``s -> h -> d`` has
        strictly more *base* capacity (min over all six traversed
        resources) than the direct link, else ``-1`` (direct).  Chosen
        from base (structural) capacities so the routing is deterministic
        across brownouts; among equal-capacity relays the lowest ``h``
        wins.  ``None`` when multi-hop is off — every query then takes
        the single-leg fast path unchanged."""
        if not self.multi_hop:
            return None
        n = self.n_sites
        out, in_, link = self.nic_out_bps, self.nic_in_bps, self.link_bps
        rel = np.full((n, n), -1, dtype=np.int64)
        for s in range(n):
            for d in range(n):
                if s == d:
                    continue
                best = min(out[s], in_[d], link[s, d])
                for h in range(n):
                    if h == s or h == d:
                        continue
                    cap = min(out[s], in_[h], link[s, h],
                              out[h], in_[d], link[h, d])
                    if cap > best:
                        best = cap
                        rel[s, d] = h
        return rel

    def _path(self, src: int, dst: int) -> Tuple[Tuple[int, int], ...]:
        """The legs a ``src -> dst`` flow traverses: ``((src, dst),)``
        direct, or ``((src, h), (h, dst))`` through the relay."""
        r = self.relay
        if r is None:
            return ((src, dst),)
        h = int(r[src, dst])
        if h < 0:
            return ((src, dst),)
        return ((src, h), (h, dst))

    # -- capacity / sharing --------------------------------------------------
    def capacity(self, src: int, dst: int, t: float) -> float:
        """Uncontended point-to-point capacity src -> dst at time t (over
        the relay path on multi-hop fabrics)."""
        out, in_, link = self.resources_at(t)
        return float(min(
            min(out[a], in_[b], link[a, b])
            for a, b in self._path(src, dst)))

    def reachable(self, src: int, dst: int) -> bool:
        """Whether src -> dst has any *structural* capacity (base NICs and
        link, brownouts ignored — a browned-out link recovers, a 0-capacity
        link never does).  Migrations to unreachable sites are invalid.
        On multi-hop fabrics a zero direct link with a live relay path is
        reachable."""
        if min(self.nic_out_bps[src], self.nic_in_bps[dst],
               self.link_bps[src, dst]) > 0.0:
            return True
        r = self.relay
        return r is not None and r[src, dst] >= 0

    @cached_property
    def _capacity_cache(self) -> dict:
        return {}

    def capacity_matrix(self, t: float) -> np.ndarray:
        """Uncontended (src, dst) capacity matrix at time t (cached per
        link state; treat as read-only)."""
        key = self._state_key(t)
        cached = self._capacity_cache.get(key)
        if cached is not None:
            return cached
        out, in_, link = self.resources_at(t)
        cap = np.minimum(np.minimum(out[:, None], in_[None, :]), link)
        r = self.relay
        if r is not None:
            for s, d in zip(*np.nonzero(r >= 0)):
                h = int(r[s, d])
                cap[s, d] = min(out[s], in_[h], link[s, h],
                                out[h], in_[d], link[h, d])
        self._capacity_cache[key] = cap
        return cap

    def shared_rates(
        self, flows: Sequence[Tuple[int, int]], t: float = 0.0
    ) -> np.ndarray:
        """Effective bps granted to each flow (aligned with ``flows``),
        under the topology's ``sharing`` model.

        ``"conservative"``: each flow gets the minimum equal split over the
        three resources it traverses — ``min(out[s]/flows(out_s),
        in[d]/flows(in_d), link[s,d]/flows(link_sd))``.  Never
        oversubscribes any resource; reduces exactly to
        ``min(nic/src_flows, nic/dst_flows)`` on uniform topologies.

        ``"waterfill"``: full max-min (see :meth:`_waterfill_rates`) —
        per-flow rates dominate the conservative split.

        On multi-hop fabrics a relayed flow traverses *both* legs'
        resources (six in total) and its grant is the minimum split over
        all of them — relayed traffic and direct hub traffic contend for
        the same hub NICs, so no resource is ever oversubscribed."""
        if not len(flows):
            return np.zeros(0)
        out, in_, link = self.resources_at(t)
        if self.sharing == "waterfill":
            return self._waterfill_rates(flows, out, in_, link)
        if self.relay is not None:
            paths = [self._path(s, d) for s, d in flows]
            n_src: Dict[int, int] = {}
            n_dst: Dict[int, int] = {}
            n_link: Dict[Tuple[int, int], int] = {}
            for path in paths:
                for a, b in path:
                    n_src[a] = n_src.get(a, 0) + 1
                    n_dst[b] = n_dst.get(b, 0) + 1
                    n_link[(a, b)] = n_link.get((a, b), 0) + 1
            return np.array([
                min(min(out[a] / n_src[a], in_[b] / n_dst[b],
                        link[a, b] / n_link[(a, b)]) for a, b in path)
                for path in paths
            ])
        n_src = {}
        n_dst = {}
        n_link = {}
        for s, d in flows:
            n_src[s] = n_src.get(s, 0) + 1
            n_dst[d] = n_dst.get(d, 0) + 1
            n_link[(s, d)] = n_link.get((s, d), 0) + 1
        return np.array([
            min(out[s] / n_src[s], in_[d] / n_dst[d],
                link[s, d] / n_link[(s, d)])
            for s, d in flows
        ])

    @staticmethod
    def _waterfill_table(
        paths: Sequence[Tuple[Tuple[int, int], ...]],
        out: np.ndarray, in_: np.ndarray, link: np.ndarray,
    ) -> Tuple[List[float], List[List[int]], Dict[Tuple, int]]:
        """Resource table for :meth:`_waterfill_solve`: capacities + member
        flow indices per (src NIC, dst NIC, link) resource, over each
        flow's leg path (one leg direct, two through a relay;
        infinite-capacity links are omitted — they can never bind)."""
        caps: List[float] = []
        members: List[List[int]] = []
        index: Dict[Tuple, int] = {}

        def add(key: Tuple, cap: float, i: int) -> None:
            k = index.get(key)
            if k is None:
                k = len(caps)
                index[key] = k
                caps.append(float(cap))
                members.append([])
            members[k].append(i)

        for i, path in enumerate(paths):
            for a, b in path:
                add(("o", a), out[a], i)
                add(("i", b), in_[b], i)
                if np.isfinite(link[a, b]):
                    add(("l", a, b), link[a, b], i)
        return caps, members, index

    def _waterfill_rates(
        self,
        flows: Sequence[Tuple[int, int]],
        out: np.ndarray, in_: np.ndarray, link: np.ndarray,
    ) -> np.ndarray:
        paths = [self._path(s, d) for s, d in flows]
        caps, members, _ = self._waterfill_table(paths, out, in_, link)
        return self._waterfill_solve(len(flows), caps, members)

    @staticmethod
    def _waterfill_solve(
        m: int, caps: List[float], members: List[List[int]],
    ) -> np.ndarray:
        """Max-min fair water-filling over the (src NIC, dst NIC, link)
        resource hypergraph.

        Iterate: raise every unfrozen flow's rate in lockstep by the
        smallest per-resource headroom-per-unfrozen-flow increment,
        freeze the flows crossing each resource that saturates, and
        redistribute the residual among the rest until every flow is
        frozen.  Terminates after at most ``#resources`` rounds (every
        round saturates at least one finite resource).  Flows through a
        zero-capacity resource freeze at 0 in the first round."""
        rate = np.zeros(m)
        frozen = np.zeros(m, dtype=bool)
        alloc = np.zeros(len(caps))
        while not frozen.all():
            best = float("inf")
            n_active = [0] * len(caps)
            for k, mem in enumerate(members):
                n_act = sum(1 for i in mem if not frozen[i])
                n_active[k] = n_act
                if n_act and np.isfinite(caps[k]):
                    inc = max(0.0, caps[k] - alloc[k]) / n_act
                    if inc < best:
                        best = inc
            if not np.isfinite(best):  # only inf-capacity resources left
                break  # unreachable with finite NICs; safety net
            rate[~frozen] += best
            for k, mem in enumerate(members):
                if not n_active[k]:
                    continue
                alloc[k] += best * n_active[k]
                if np.isfinite(caps[k]) and alloc[k] >= caps[k] * (1 - 1e-12):
                    for i in mem:
                        frozen[i] = True
        return rate

    def advertised_matrix(
        self, t: float = 0.0, flows: Sequence[Tuple[int, int]] = ()
    ) -> np.ndarray:
        """Policy-facing (src, dst) bandwidth matrix under the *current*
        flow set — what a transfer on that pair is being granted right now
        (idle resources advertise full capacity).  The same share model as
        :meth:`shared_rates`, so the snapshot always agrees with the
        transfer loop.

        Under ``sharing="waterfill"`` pairs carrying flows advertise their
        water-filled grant (all flows on one pair are symmetric, hence
        equal); idle pairs advertise the rate a *new* flow on that pair
        would be granted (post-admission water-fill) — under max-min the
        "current grant on an idle pair" is undefined, and the
        post-admission rate is the honest, strictly-less-optimistic
        number."""
        if not len(flows):
            return self.capacity_matrix(t)
        out, in_, link = self.resources_at(t)
        if self.sharing == "waterfill":
            m = len(flows)
            paths = [self._path(s, d) for s, d in flows]
            caps, members, index = self._waterfill_table(paths, out, in_, link)
            rates = self._waterfill_solve(m, caps, members)
            adv = np.array(self.capacity_matrix(t), copy=True)
            loaded = {}
            for (s, d), r in zip(flows, rates):
                loaded[(s, d)] = float(r)
            for s in range(self.n_sites):
                for d in range(self.n_sites):
                    if s == d:
                        continue
                    if (s, d) in loaded:
                        adv[s, d] = loaded[(s, d)]
                    elif adv[s, d] > 0.0:
                        # post-admission solve for the idle pair: reuse the
                        # base resource table, appending only the candidate
                        # flow's own leg resources (no per-pair rebuild)
                        caps2 = list(caps)
                        members2 = [list(mem) for mem in members]
                        for a, b in self._path(s, d):
                            for key, cap in ((("o", a), out[a]),
                                             (("i", b), in_[b]),
                                             (("l", a, b), link[a, b])):
                                if key[0] == "l" and not np.isfinite(cap):
                                    continue
                                k = index.get(key)
                                if k is None:
                                    caps2.append(float(cap))
                                    members2.append([m])
                                else:
                                    members2[k].append(m)
                        adv[s, d] = self._waterfill_solve(
                            m + 1, caps2, members2)[-1]
            return adv
        n = self.n_sites
        if self.relay is not None:
            # leg-aware current-grant matrix: count every flow on every
            # resource its path traverses, then advertise each pair the
            # min split over its own path (idle resources = full rate)
            n_src: Dict[int, int] = {}
            n_dst: Dict[int, int] = {}
            n_link: Dict[Tuple[int, int], int] = {}
            for s, d in flows:
                for a, b in self._path(s, d):
                    n_src[a] = n_src.get(a, 0) + 1
                    n_dst[b] = n_dst.get(b, 0) + 1
                    n_link[(a, b)] = n_link.get((a, b), 0) + 1
            adv = np.array(self.capacity_matrix(t), copy=True)
            for s in range(n):
                for d in range(n):
                    if s == d:
                        continue
                    adv[s, d] = min(
                        min(out[a] / max(n_src.get(a, 1), 1),
                            in_[b] / max(n_dst.get(b, 1), 1),
                            link[a, b] / max(n_link.get((a, b), 1), 1))
                        for a, b in self._path(s, d))
            return adv
        src_n = np.ones(n)
        dst_n = np.ones(n)
        link_n = np.ones((n, n))
        for s, d in flows:
            src_n[s] += 1.0
            dst_n[d] += 1.0
            link_n[s, d] += 1.0
        # counts start at 1 (idle = full rate), so subtract the extra 1
        # wherever a flow was actually counted
        src_n[src_n > 1] -= 1.0
        dst_n[dst_n > 1] -= 1.0
        link_n[link_n > 1] -= 1.0
        return np.minimum(
            np.minimum((out / src_n)[:, None], (in_ / dst_n)[None, :]),
            link / link_n,
        )

    def post_admission_rate(
        self, src: int, dst: int,
        flows: Sequence[Tuple[int, int]] = (), t: float = 0.0,
    ) -> float:
        """The rate a NEW ``src -> dst`` transfer would actually be granted
        given the in-flight ``flows`` — the new flow itself dilutes every
        resource it traverses (the ``(flows+1)`` share the advertised
        matrix deliberately omits).  This is the number admission checks
        should use: the advertised matrix is the *current* grant and is
        systematically optimistic for a would-be transfer."""
        return float(self.shared_rates(list(flows) + [(src, dst)], t)[-1])


# ---------------------------------------------------------------------------
# Link-matrix builders for common fabrics
# ---------------------------------------------------------------------------


def hub_spoke_links(
    n_sites: int, hub: int = 0, spoke_gbps: float = 1.0
) -> Tuple[Tuple[Optional[float], ...], ...]:
    """Hub-and-spoke link matrix: hub-adjacent links NIC-limited (None),
    direct spoke-to-spoke links capped at ``spoke_gbps``."""
    rows = []
    for s in range(n_sites):
        row = []
        for d in range(n_sites):
            row.append(None if (s == hub or d == hub or s == d) else spoke_gbps)
        rows.append(tuple(row))
    return tuple(rows)


def partitioned_links(
    groups: Sequence[Sequence[int]], inter_gbps: float = 0.25
) -> Tuple[Tuple[Optional[float], ...], ...]:
    """Partitioned fabric: NIC-limited links inside each group, thin
    ``inter_gbps`` links between groups (0 = fully partitioned)."""
    n = sum(len(g) for g in groups)
    part = {}
    for gi, g in enumerate(groups):
        for s in g:
            part[s] = gi
    if sorted(part) != list(range(n)):
        raise ValueError("groups must partition range(n_sites)")
    rows = []
    for s in range(n):
        rows.append(tuple(
            None if part[s] == part[d] else inter_gbps for d in range(n)))
    return tuple(rows)


__all__ = [
    "WanProfile", "WanTopology", "hub_spoke_links", "partitioned_links",
]
