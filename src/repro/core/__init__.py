"""The paper's contribution: feasibility-domain model (§IV/§VI),
feasibility-aware orchestration (§V, Algorithm 1) behind a typed
Action/ClusterState API, CAISO-calibrated traces, a scenario registry and
the trace-driven multi-site simulator (§VII)."""
from repro.core import feasibility  # noqa: F401
from repro.core.feasibility import (  # noqa: F401
    ALPHA, CLASS_A_MAX_S, CLASS_B_MAX_S, P_NODE_KW, P_SYS_KW,
    FeasibilityVerdict, breakeven_time_s, classify, classify_by_size,
    evaluate, migration_cost_s, migration_energy_kwh, phase_diagram,
    stochastic_feasible, transfer_time_s,
)
from repro.core.actions import (  # noqa: F401
    Action, Defer, Migrate, Pause, Resume, Throttle,
)
from repro.core.state import (  # noqa: F401
    ClusterState, JobSoA, JobView, SiteView, advertised_bandwidth,
    nic_share_counts,
)
from repro.core.orchestrator import (  # noqa: F401
    DeferConfig, DeferToWindowPolicy, EnergyOnlyPolicy, FeasibilityAwarePolicy,
    FeasibilityConfig, GridThrottlePolicy, OraclePolicy, OrchestratorContext,
    PlanAheadConfig, PlanAheadPolicy, Policy, PolicyConfig,
    RecedingHorizonConfig, RecedingHorizonPolicy, StaticPolicy,
    ThrottleConfig, available_policies, make_policy, register_policy,
)
from repro.core.forecast import (  # noqa: F401
    ForecastHorizon, OutageForecast, WindowForecast,
)
from repro.core.ledger import (  # noqa: F401
    BatteryConfig, DVFS_CURVE_POINTS, PowerLedger, ThrottleCurve,
)
from repro.core.signals import (  # noqa: F401
    CurtailRequest, GridSignals, SignalProfile, SignalStack,
    curtail_requests_from_carbon, generate_signals, grid_signal_integral,
)
from repro.core.wan import (  # noqa: F401
    WanProfile, WanTopology, hub_spoke_links, partitioned_links,
)
from repro.core.serving import (  # noqa: F401
    DEFAULT_MODEL_CLASSES, ModelClass, Request, RequestBatch, Router,
    ServingPlane, ServingProfile, ServingView, available_routers,
    generate_requests, make_router, register_router,
)
from repro.core.scenarios import (  # noqa: F401
    FailureRegime, ForecastNoise, JobMix, Scenario,
    available_scenarios, get_scenario, register_scenario,
)
from repro.core.simulator import (  # noqa: F401
    ClusterSimulator, SimConfig, SimJob, SimResult, generate_jobs,
    normalized_table, run_policy_comparison,
)
from repro.core.traces import (  # noqa: F401
    Forecaster, SiteTrace, TraceProfile, TraceStack, Window, generate_trace,
    stack_traces, trace_stats,
)
from repro.core.sweep import (  # noqa: F401
    RunRecord, SweepResult, SweepSpec, run_sweep,
)
