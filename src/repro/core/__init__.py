"""The paper's contribution: feasibility-domain model (§IV/§VI),
feasibility-aware orchestration (§V, Algorithm 1), CAISO-calibrated traces
and the trace-driven multi-site simulator (§VII)."""
from repro.core import feasibility  # noqa: F401
from repro.core.feasibility import (  # noqa: F401
    ALPHA, CLASS_A_MAX_S, CLASS_B_MAX_S, P_NODE_KW, P_SYS_KW,
    FeasibilityVerdict, breakeven_time_s, classify, classify_by_size,
    evaluate, migration_cost_s, migration_energy_kwh, phase_diagram,
    stochastic_feasible, transfer_time_s,
)
from repro.core.orchestrator import (  # noqa: F401
    EnergyOnlyPolicy, FeasibilityAwarePolicy, OrchestratorContext, Policy,
    StaticPolicy, make_policy,
)
from repro.core.simulator import (  # noqa: F401
    ClusterSimulator, SimConfig, SimJob, SimResult, generate_jobs,
    normalized_table, run_policy_comparison,
)
from repro.core.traces import Forecaster, SiteTrace, Window, generate_trace, trace_stats  # noqa: F401
