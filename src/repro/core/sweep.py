"""Monte-Carlo sweep engine: scenarios × policies × seeds, fanned out
over a process pool (the evaluation scale-up the ROADMAP's "as many
scenarios as you can imagine" asks for; cf. Heron's multi-DC trace
sweeps and Wiesner et al.'s multi-seed curtailment studies).

A sweep is a grid of *cells*; one cell = one ``(scenario, seed)`` pair.
Within a cell every policy runs against the **same** trace, job list, WAN
topology and forecast horizon (built once, shared — the same-trace-
same-jobs guarantee ``run_policy_comparison`` has always made, now for
every seed), so per-policy differences are policy effects, not sampling
noise.  Cells are independent and deterministic, so they parallelize
perfectly: ``run_sweep(spec, workers=N)`` produces byte-identical
per-run summaries to ``workers=1`` (tests/test_sweep.py), with results
merged in spec order regardless of completion order.

``run_policy_comparison`` is a 1-seed sweep through this engine;
``python -m benchmarks.run --sweep`` prints the aggregate table
(mean ± 95% CI per metric) for a multi-scenario many-seed grid.
"""
from __future__ import annotations

import copy
import math
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

#: summary keys that are wall-clock measurements, not model outputs —
#: nondeterministic by nature, excluded from determinism comparisons
TIMING_KEYS = ("ticks_per_sec", "decide_s", "decide_first_s", "wall_s")


@dataclass(frozen=True)
class SweepSpec:
    """A scenarios × policies × seeds grid (+ SimConfig overrides applied
    to every cell and per-policy configs).

    ``vary`` selects which random streams the sweep's seeds drive — the
    variance-decomposition split the coupled legacy seeding could not
    express:

      * ``"both"`` (default) — the legacy behaviour: one seed varies the
        environment (traces, WAN brownouts, failures, forecast noise,
        signals) *and* the job arrival process together;
      * ``"traces"`` — seeds vary only the environment; every cell runs
        the identical job workload drawn from ``pin_seed``;
      * ``"jobs"`` — seeds vary only the arrival process over the fixed
        ``pin_seed`` environment.

    Comparing the per-metric variance of a ``"traces"`` sweep against a
    ``"jobs"`` sweep decomposes how much of the ``"both"`` spread each
    stream contributes.
    """

    scenarios: Tuple[str, ...]
    policies: Tuple[str, ...]
    seeds: Tuple[int, ...] = (0,)
    overrides: Optional[Mapping[str, object]] = None
    policy_configs: Optional[Mapping[str, object]] = None  # name -> PolicyConfig|dict
    vary: str = "both"  # "both" | "traces" | "jobs"
    pin_seed: int = 0  # the pinned stream's seed under a split mode

    def cells(self, keep_results: bool = True) -> List[tuple]:
        """Materialize the work list: one ``(cfg, label, seed, policies,
        policy_configs, keep_results, job_seed)`` tuple per
        (scenario, seed), in spec order (the deterministic merge order).
        ``cfg.seed`` carries the environment stream; ``job_seed`` the
        arrival stream (equal under ``vary="both"``)."""
        from repro.core.scenarios import get_scenario

        if self.vary not in ("both", "traces", "jobs"):
            raise ValueError(
                f"vary must be 'both', 'traces' or 'jobs', not {self.vary!r}")
        cells = []
        pconf = dict(self.policy_configs or {})
        for scn in self.scenarios:
            s = get_scenario(scn)
            for seed in self.seeds:
                env_seed = self.pin_seed if self.vary == "jobs" else seed
                job_seed = self.pin_seed if self.vary == "traces" else seed
                cfg = s.sim_config(**{**dict(self.overrides or {}),
                                      "seed": env_seed})
                # scenario-scoped policy defaults; spec-level configs win
                cell_pconf = {**{k: dict(v)
                                 for k, v in s.policy_configs.items()},
                              **pconf}
                cells.append((cfg, s.name, seed, tuple(self.policies),
                              cell_pconf, keep_results, job_seed))
        return cells


@dataclass(frozen=True)
class RunRecord:
    """One simulation run inside a sweep."""

    scenario: str
    policy: str
    seed: int
    summary: dict  # SimResult.summary()
    result: Optional[object] = None  # the full SimResult when kept


@dataclass
class SweepResult:
    """All runs of a sweep plus aggregation helpers."""

    runs: List[RunRecord]
    wall_s: float = 0.0
    workers: int = 1

    def deterministic_summaries(self) -> List[dict]:
        """Per-run summaries with wall-clock keys stripped — the object
        the workers=N == workers=1 determinism guarantee covers."""
        return [
            {**{k: v for k, v in r.summary.items() if k not in TIMING_KEYS},
             "scenario": r.scenario, "seed": r.seed}
            for r in self.runs
        ]

    def aggregate(self) -> Dict[Tuple[str, str], Dict[str, dict]]:
        """(scenario, policy) -> metric -> {mean, std, ci95, n} over
        seeds (sample std, normal-approximation 95% CI)."""
        groups: Dict[Tuple[str, str], List[dict]] = {}
        for r in self.runs:
            groups.setdefault((r.scenario, r.policy), []).append(r.summary)
        out: Dict[Tuple[str, str], Dict[str, dict]] = {}
        for key, summaries in groups.items():
            metrics: Dict[str, dict] = {}
            for name, v0 in summaries[0].items():
                if not isinstance(v0, (int, float)) or isinstance(v0, bool):
                    continue
                vals = [float(s[name]) for s in summaries]
                n = len(vals)
                mean = sum(vals) / n
                var = (sum((v - mean) ** 2 for v in vals) / (n - 1)
                       if n > 1 else 0.0)
                std = math.sqrt(var)
                metrics[name] = {
                    "mean": mean, "std": std,
                    "ci95": 1.96 * std / math.sqrt(n), "n": n,
                }
            out[key] = metrics
        return out

    def table(self, metrics: Sequence[str] = (
            "grid_kwh", "grid_gco2", "grid_cost", "renewable_frac",
            "migrations", "completed", "mean_jct_h")) -> str:
        """Aggregate table: one row per (scenario, policy), mean ± ci95."""
        agg = self.aggregate()
        headers = ["scenario", "policy"] + [f"{m} (±ci95)" for m in metrics]
        rows = []
        for (scn, pol), ms in agg.items():
            row = [scn, pol]
            for m in metrics:
                got = ms.get(m)
                row.append("-" if got is None else
                           f"{got['mean']:.2f} ±{got['ci95']:.2f}")
            rows.append(row)
        widths = [max(len(str(r[i])) for r in [headers] + rows)
                  for i in range(len(headers))]
        fmt = "  ".join(f"{{:<{w}}}" for w in widths)
        return "\n".join([fmt.format(*headers)]
                         + [fmt.format(*r) for r in rows])


def _cell_sims(cell: tuple) -> Tuple[str, int, bool, List[Tuple[str, object]]]:
    """Build one (scenario, seed) cell's simulators on shared inputs:
    ``(label, seed, keep_results, [(policy_name, simulator), ...])``.

    Traces, the WAN topology, the grid signals and (per forecast sigma)
    the ForecastHorizon are constructed once and shared across the cell's
    simulators; the job list is deep-copied per run (simulators mutate
    it).  The trailing ``job_seed`` drives the arrival stream separately
    from ``cfg.seed``'s environment stream (split-seed sweeps).
    """
    from repro.core.forecast import ForecastHorizon
    from repro.core.orchestrator import make_policy
    from repro.core.signals import generate_signals
    from repro.core.simulator import ClusterSimulator, generate_jobs
    from repro.core.traces import generate_trace

    cfg, label, seed, policies, policy_configs, keep_results, *rest = cell
    job_seed = rest[0] if rest else cfg.seed  # legacy 6-tuples: coupled
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed,
                            profile=cfg.trace)
    base_jobs = generate_jobs(cfg, seed=job_seed)
    wan = cfg.wan_profile().build_topology(cfg.n_sites, cfg.days, cfg.seed)
    signals = generate_signals(cfg.n_sites, cfg.days, seed=cfg.seed,
                               profile=cfg.signals)
    horizons: Dict[float, ForecastHorizon] = {}
    sims: List[Tuple[str, object]] = []
    for name in policies:
        pconf = policy_configs.get(name)
        if isinstance(pconf, dict):
            pol = make_policy(name, **pconf)
        else:
            pol = make_policy(name, config=pconf)
        sigma = 0.0 if pol.wants_oracle_forecast else cfg.forecast_sigma_s
        horizon = horizons.get(sigma)
        if horizon is None:
            horizon = horizons[sigma] = ForecastHorizon.build(
                traces, wan=wan, signals=signals,
                horizon_s=cfg.forecast_horizon_s,
                sigma_s=sigma, seed=cfg.seed + 7)
        sims.append((name, ClusterSimulator(
            cfg, pol, traces=traces, jobs=copy.deepcopy(base_jobs),
            oracle_forecast=pol.wants_oracle_forecast,
            wan_topology=wan, forecast_horizon=horizon,
            grid_signals=signals)))
    return label, seed, keep_results, sims


def _run_cell(cell: tuple) -> Tuple[str, int, List[Tuple[str, object, dict]]]:
    """Run every policy of one (scenario, seed) cell on shared inputs;
    yields ``(policy, SimResult-or-None, summary)`` triples.  When the
    caller does not keep full results, the per-job ``SimResult`` is
    dropped *worker-side* — only the summary dict crosses the process
    boundary.  Top-level so the process pool can pickle it.
    """
    label, seed, keep_results, sims = _cell_sims(cell)
    out: List[Tuple[str, object, dict]] = []
    for name, sim in sims:
        r = sim.run()
        out.append((name, r if keep_results else None, r.summary()))
    return label, seed, out


class _BatchRun:
    """One suspended cell×policy simulation inside the batched runner."""

    __slots__ = ("idx", "name", "sim", "gen", "state", "key", "label", "seed")

    def __init__(self, idx, name, sim):
        import dataclasses as _dc

        self.idx, self.name, self.sim = idx, name, sim
        self.gen = sim._event_gen()
        self.state = None
        pol = sim.policy
        # config-identical policies share one decide_batch call; policies
        # that aren't dataclasses have no stable value repr and stay solo
        # (their default decide_batch loops decide anyway)
        self.key = ((type(pol).__name__, repr(pol))
                    if _dc.is_dataclass(pol) else (type(pol).__name__, id(pol)))

    def advance(self, actions):
        """Run events until the next orchestrator tick; True while live."""
        try:
            self.state = self.gen.send(actions)
            return True
        except StopIteration:
            self.state = None
            return False


def run_cells_batched(cells: Sequence[tuple], *,
                      keep_results: bool = True) -> SweepResult:
    """Execute prepared cells in ONE process with cross-cell batched
    decide: every cell×policy simulation is advanced as a coroutine
    (``ClusterSimulator._event_gen``) to its next orchestrator tick, and
    all snapshots awaiting a config-identical policy are answered by a
    single ``Policy.decide_batch`` call — one fused
    ``(cells × jobs × sites)`` kernel pass per group per round instead of
    a python loop over cells (see :mod:`repro.core.policy_kernels`).

    Per-run summaries are identical to :func:`run_cells` minus
    ``TIMING_KEYS`` (the determinism guarantee tests/test_sweep.py
    extends to this runner); the batched decide wall is attributed to the
    member runs in equal shares.  Cells requesting the fixed-dt engine
    run inline, unbatched.
    """
    t0 = time.perf_counter()
    slots: List[Optional[Tuple[str, int, str, object, dict]]] = []
    keeps: List[bool] = []
    live: List[_BatchRun] = []
    for cell in cells:
        label, seed, keep, sims = _cell_sims(cell)
        for name, sim in sims:
            idx = len(slots)
            slots.append(None)
            keeps.append(keep)
            if sim.cfg.engine != "event":
                r = sim.run()
                slots[idx] = (label, seed, name, r, r.summary())
                continue
            run = _BatchRun(idx, name, sim)
            run.label, run.seed = label, seed
            if run.advance(None):
                live.append(run)
            else:
                r = sim._result(t0)
                slots[idx] = (label, seed, name, r, r.summary())

    def finalize(run: _BatchRun) -> None:
        r = run.sim._result(t0)
        slots[run.idx] = (run.label, run.seed, run.name, r, r.summary())

    while live:
        groups: Dict[tuple, List[_BatchRun]] = {}
        for run in live:
            groups.setdefault(run.key, []).append(run)
        live = []
        for members in groups.values():
            pol = members[0].sim.policy
            d0 = time.perf_counter()
            acts = pol.decide_batch([run.state for run in members])
            share = (time.perf_counter() - d0) / len(members)
            for run, actions in zip(members, acts):
                run.sim._record_decide(share)
                if run.advance(actions):
                    live.append(run)
                else:
                    finalize(run)
    runs = [
        RunRecord(scenario=label, policy=name, seed=seed, summary=summary,
                  result=r if keeps[i] else None)
        for i, (label, seed, name, r, summary) in enumerate(slots)
    ]
    return SweepResult(runs=runs, wall_s=time.perf_counter() - t0, workers=1)


def run_cells(cells: Sequence[tuple], *, workers: Optional[int] = None,
              keep_results: bool = True) -> SweepResult:
    """Execute prepared cells (see :meth:`SweepSpec.cells`) and merge in
    submission order.  ``workers=1`` (or a single cell) runs inline —
    no pool, no pickling; ``workers=None`` sizes the pool to
    ``min(len(cells), cpu_count)``."""
    t0 = time.perf_counter()
    if workers is None:
        workers = min(len(cells), os.cpu_count() or 1)
    workers = max(1, min(workers, len(cells)))
    if workers == 1:
        results = [_run_cell(c) for c in cells]
    else:
        with ProcessPoolExecutor(max_workers=workers) as ex:
            # map() yields in submission order — completion order never
            # leaks into the merge
            results = list(ex.map(_run_cell, cells))
    runs = [
        RunRecord(scenario=label, policy=name, seed=seed, summary=summary,
                  result=r if keep_results else None)
        for label, seed, cell_out in results
        for name, r, summary in cell_out
    ]
    return SweepResult(runs=runs, wall_s=time.perf_counter() - t0,
                       workers=workers)


def run_sweep(spec: SweepSpec, *, workers: Optional[int] = None,
              keep_results: bool = True) -> SweepResult:
    """Fan a :class:`SweepSpec` out over the process pool."""
    return run_cells(spec.cells(keep_results=keep_results), workers=workers,
                     keep_results=keep_results)


__all__ = [
    "RunRecord", "SweepResult", "SweepSpec", "TIMING_KEYS", "run_cells",
    "run_cells_batched", "run_sweep",
]
