"""Migration engine: the glue between the orchestrator's *decision* and the
training substrate's *mechanism*.

migrate_job() performs a real end-to-end migration between two site
directories: export the newest checkpoint, model the WAN transfer with the
feasibility equations (optionally actually sleeping), import at the
destination, and restore into a trainer bound to the destination mesh —
which may have a different shape (elastic restore via shardings).

Returns a MigrationReport whose timings are exactly the terms of eq. (1),
so examples/tests can check the measured overhead against the model.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Optional

from repro.checkpoint.manager import CheckpointManager
from repro.core import feasibility as fz


@dataclass
class MigrationReport:
    job: str
    step: int
    nbytes: int
    bandwidth_bps: float
    t_transfer_s: float  # modeled WAN time (eq. 1 dominant term)
    t_serialize_s: float  # measured local export time
    t_load_s: float  # modeled restore/load time
    t_downtime_s: float
    workload_class: int  # 0=A, 1=B, 2=C
    feasible_in_window: Optional[bool]

    @property
    def t_cost_s(self) -> float:
        return self.t_transfer_s + self.t_load_s + self.t_downtime_s


def migrate_job(
    src: CheckpointManager,
    dst_root: str,
    *,
    bandwidth_bps: float = 10e9,
    window_s: Optional[float] = None,
    t_load_s: float = fz.T_LOAD_S,
    realtime: bool = False,
) -> tuple[CheckpointManager, MigrationReport]:
    """Move the newest checkpoint of `src` to `dst_root` over a WAN model."""
    t0 = time.time()
    raw = src.export_bytes()
    t_ser = time.time() - t0
    nbytes = len(raw)
    t_transfer = float(fz.transfer_time_s(nbytes, bandwidth_bps))
    if realtime:
        time.sleep(min(t_transfer, 5.0))  # bounded demo sleep
    step = src.latest.step
    dst = CheckpointManager.import_bytes(dst_root, src.job, step, raw)
    verdict = None
    if window_s is not None:
        verdict = bool(
            fz.evaluate(nbytes, bandwidth_bps, window_s, t_load_s=t_load_s).feasible
        )
    report = MigrationReport(
        job=src.job,
        step=step,
        nbytes=nbytes,
        bandwidth_bps=bandwidth_bps,
        t_transfer_s=t_transfer,
        t_serialize_s=t_ser,
        t_load_s=t_load_s,
        t_downtime_s=fz.T_DOWNTIME_S,
        workload_class=int(fz.classify(nbytes, bandwidth_bps)),
        feasible_in_window=verdict,
    )
    return dst, report
