"""Unified per-site power ledger + prosumer microgrid layer.

Historically the energy/carbon/price accounting was smeared across four
layers: the simulator's per-span kWh integration (``flush``), the
signal integrals (:mod:`repro.core.signals`), the serving plane's
separate ``serve_*`` accumulators and the scalar model in
``feasibility``.  Any storage or sell-back model must hook into *all*
of them, so the prerequisite is one accounting spine:
:class:`PowerLedger` — a per-site ledger that reconciles **sources**
(renewable window, grid, battery discharge) against **sinks** (training
compute, serving compute, migration NIC draw, battery charge, sell-back
export) analytically per inter-event span.

The ledger is a pure relocation of the existing accounting when storage
is disabled: every posting reproduces the historical float expressions
*op for op* (same association order, same guards), so all benchmark
digits are bit-identical with ``battery=None``.  The invariant is
enforced structurally — every posting feeds a per-site source/sink
pair (:meth:`PowerLedger.audit` checks sources ≡ sinks), and the
conservation accumulators are separate floats that never touch the
billing arithmetic.

On top of the ledger sits the prosumer layer (the paper's §VIII
"grid-level control and demand-response ecosystems" horizon; cf.
*Carbon-Aware Compute–Power Scheduling with Microgrid Prosumer
Operations* for the battery/sell-back operating model and the
curtailment-window studies for why charging from otherwise-curtailed
energy dominates the economics):

  * :class:`BatteryConfig` — per-site storage that charges from
    curtailed renewables (green window time at ``max_charge_kw``, the
    round-trip efficiency applied on the charge leg so delivered energy
    is exactly ``e_in * rte``), and discharges through carbon peaks
    (demand-driven at posting time, gated on the span's mean dark-time
    carbon intensity) — grid kWh/gCO2/$ billed for a span shrink by the
    battery-covered fraction.
  * sell-back: residual green time after the battery is full exports at
    ``sellback_kw``, billed in :class:`~repro.core.signals.SignalStack`
    dollars only over segments with ``price >= sellback_price_floor``
    (the negative-price guard: exporting into a negative price would
    *cost* money, so the prosumer simply doesn't).
  * :class:`ThrottleCurve` — a physical power-cap model: ``Throttle``
    actions set a GPU *power* fraction which maps through a measured
    piecewise-linear power→throughput curve (DVFS-sweep shaped —
    sub-linear power savings at high caps, super-linear throughput loss
    near idle) instead of the legacy linear scalar.

All of the battery/sell-back machinery is fully deterministic and
consumes **zero** RNG draws; enabling it changes no stream anywhere.

Approximations (documented, conservative): concurrently-posted spans at
one site each see up to ``max_discharge_kw`` of battery power (the
energy budget is shared and never exceeds the state of charge, but the
power cap is per-flow); the battery timeline is advanced to each span's
*end* before discharging, so charge landed late in a span can serve
dark time earlier in the same span (spans are one inter-event interval,
typically minutes).  Serving compute is reconciled as a sink but not
battery-backed.
"""
from __future__ import annotations

import numpy as np

from dataclasses import dataclass
from functools import cached_property
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.signals import GridSignals, grid_signal_integral

HOUR = 3600.0

#: Measured DVFS-sweep shape (normalized): capping GPU power to 50%
#: keeps ~66% of throughput — power savings are sub-linear because
#: static/idle draw doesn't scale with the cap.
DVFS_CURVE_POINTS: Tuple[Tuple[float, float], ...] = (
    (0.0, 0.0), (0.3, 0.42), (0.5, 0.66), (0.7, 0.85), (1.0, 1.0),
)


@dataclass(frozen=True, eq=False)
class ThrottleCurve:
    """Piecewise-linear power→throughput map for power-capped compute.

    ``points`` are ``(power_frac, throughput_frac)`` knots, strictly
    increasing in power, interpolated linearly (``np.interp``) and
    clamped at the ends.  The default is the normalized DVFS-sweep
    shape above.  ``ThrottleCurve.linear()`` gives the legacy
    throughput == power identity.
    """

    points: Tuple[Tuple[float, float], ...] = DVFS_CURVE_POINTS

    def __post_init__(self):
        px = [p for p, _ in self.points]
        if len(px) < 2 or any(b <= a for a, b in zip(px, px[1:])):
            raise ValueError(
                "ThrottleCurve needs >= 2 points, strictly increasing "
                f"in power_frac: {self.points!r}")

    @classmethod
    def linear(cls) -> "ThrottleCurve":
        return cls(points=((0.0, 0.0), (1.0, 1.0)))

    @cached_property
    def _px(self) -> np.ndarray:
        return np.array([p for p, _ in self.points], dtype=np.float64)

    @cached_property
    def _py(self) -> np.ndarray:
        return np.array([y for _, y in self.points], dtype=np.float64)

    def throughput(self, power_frac: float) -> float:
        """Throughput fraction delivered at ``power_frac`` of nominal."""
        return float(np.interp(power_frac, self._px, self._py))

    def throughput_rows(self, power_fracs: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`throughput` (same interp, same clamping)."""
        return np.interp(np.asarray(power_fracs, dtype=np.float64),
                         self._px, self._py)


@dataclass(frozen=True)
class BatteryConfig:
    """Per-site storage + sell-back spec (scenario-composable, frozen).

    The charge leg applies the full round-trip efficiency (state of
    charge gains ``e_in * round_trip_efficiency``); the discharge leg
    delivers 1:1 — so round-trip delivered energy is *exactly*
    ``e_in * rte`` in one multiply (the property tests check this
    bit-exactly).  ``discharge_threshold_g`` gates discharge on the
    span's mean dark-time carbon intensity (discharge through forecast
    carbon peaks, hold through clean hours); ``<= 0`` discharges
    whenever there is dark demand.  ``sellback_kw > 0`` exports
    residual green time (after the battery is full) at that power,
    credited in dollars only where ``price >= sellback_price_floor``.
    """

    capacity_kwh: float = 20.0
    max_charge_kw: float = 5.0
    max_discharge_kw: float = 5.0
    round_trip_efficiency: float = 0.90
    discharge_threshold_g: float = 250.0  # mean dark gCO2/kWh gate
    sellback_kw: float = 0.0  # 0 = no export
    sellback_price_floor: float = 0.0  # $/kWh; the negative-price guard
    initial_soc_frac: float = 0.0

    def __post_init__(self):
        if self.capacity_kwh <= 0.0:
            raise ValueError("capacity_kwh must be > 0")
        if not 0.0 < self.round_trip_efficiency <= 1.0:
            raise ValueError("round_trip_efficiency must be in (0, 1]")
        if not 0.0 <= self.initial_soc_frac <= 1.0:
            raise ValueError("initial_soc_frac must be in [0, 1]")


class PowerLedger:
    """Per-site source/sink reconciliation for one simulation run.

    Owns every energy/carbon/price accumulator the run reports:

    ======================  =================================================
    attribute               meaning
    ======================  =================================================
    ``grid_kwh``            grid energy drawn by training + migration
    ``renewable_kwh``       in-window energy consumed by training
    ``migration_kwh``       NIC/system draw of checkpoint transfers
    ``grid_gco2/grid_cost`` signal-billed training+migration carbon / $
    ``site_grid_gco2/...``  the per-site split of the same (sums exactly)
    ``serve_*``             the serving plane's separate accumulators
    ``request_gco2``        signal-billed serving carbon (+ per-site split)
    ``battery_*_kwh``       charge input / discharged / conversion loss
    ``sellback_kwh/usd``    exported energy and SignalStack-billed revenue
    ``dr_*_ws``             demand-response requested vs shed watt-seconds
    ``soc``                 (n,) current state of charge, kWh
    ======================  =================================================

    Postings (``post_train`` / ``post_migration`` / ``post_serve``)
    reproduce the historical accounting bit-for-bit when
    ``battery is None``; every posting also feeds the per-site
    conservation pair checked by :meth:`audit`.
    """

    def __init__(
        self,
        n_sites: int,
        *,
        signals: Optional[GridSignals] = None,
        traces: Optional[Sequence] = None,
        battery: Optional[BatteryConfig] = None,
    ):
        self.n_sites = n_sites
        self.signals = signals
        self.traces = traces
        self.battery = battery
        # training + migration accounting (the simulator's historical set)
        self.grid_kwh = 0.0
        self.renewable_kwh = 0.0
        self.migration_kwh = 0.0
        self.grid_gco2 = 0.0
        self.grid_cost = 0.0
        self.site_grid_gco2 = np.zeros(n_sites)
        self.site_grid_cost = np.zeros(n_sites)
        # serving accounting (the plane's historical separate set)
        self.serve_grid_kwh = 0.0
        self.serve_renewable_kwh = 0.0
        self.request_gco2 = 0.0
        self.site_request_gco2 = np.zeros(n_sites)
        # prosumer layer
        self.battery_charge_kwh = 0.0  # energy drawn INTO the charger
        self.battery_discharge_kwh = 0.0  # energy delivered to compute
        self.battery_loss_kwh = 0.0  # conversion loss (charge leg)
        self.sellback_kwh = 0.0
        self.sellback_usd = 0.0
        # demand-response compliance (watt-seconds, see dr_compliance)
        self.dr_requested_ws = 0.0
        self.dr_shed_ws = 0.0
        # battery state
        if battery is not None:
            self.soc = np.full(
                n_sites, battery.capacity_kwh * battery.initial_soc_frac)
            self._batt_t = np.zeros(n_sites)
        else:
            self.soc = np.zeros(n_sites)
        # per-site conservation pair (separate floats: these NEVER feed
        # the billing arithmetic, so tracking them cannot move a digit)
        self._src_kwh = np.zeros(n_sites)
        self._snk_kwh = np.zeros(n_sites)
        # serve-bill sync hook: a serving plane that defers its bills
        # registers its flush here; every OTHER posting (and the audit)
        # drains the deferred bills first so the global add order onto
        # the shared accumulators stays exactly the per-event order
        self._serve_sync: Optional[Callable[[], None]] = None
        # demand-response curtail index: per-site start-sorted arrays
        self._dr: Optional[List] = None
        if signals is not None and signals.curtailments:
            per: List[List] = [[] for _ in range(n_sites)]
            for c in signals.curtailments:
                if 0 <= c.site < n_sites:
                    per[c.site].append(c)
            self._dr = []
            for lst in per:
                if lst:
                    self._dr.append((
                        np.array([c.start_s for c in lst]),
                        np.array([c.end_s for c in lst]),
                        np.array([c.power_frac for c in lst])))
                else:
                    self._dr.append(None)

    # -- postings ------------------------------------------------------------
    def post_train(
        self, site: int, p_kw: float, t0: float, t1: float,
        green_s: float = 0.0, p_nominal_kw: Optional[float] = None,
    ) -> Tuple[float, float]:
        """Bill one training-compute span drawing ``p_kw``.

        ``green_s`` is the renewable-window overlap of ``[t0, t1]``
        (the caller's ``traces[site].renewable_seconds``).  Returns
        ``(renewable_kwh, grid_kwh)`` for the span so the caller can
        keep per-job accounting; with a battery the grid half is net of
        battery discharge.  ``p_nominal_kw`` (the un-throttled draw)
        enables demand-response compliance tracking.
        """
        if self._serve_sync is not None:
            self._serve_sync()
        span = t1 - t0
        e_g = p_kw * green_s / HOUR
        e_b = p_kw * (span - green_s) / HOUR
        self.renewable_kwh += e_g
        self._src_kwh[site] += e_g
        self._snk_kwh[site] += e_g + e_b
        if p_nominal_kw is not None and self._dr is not None:
            self.post_dr(site, p_kw, p_nominal_kw, t0, t1)
        e_grid = self._grid_sink(site, p_kw, e_b, t0, t1, green_s)
        return e_g, e_grid

    def post_migration(
        self, site: int, p_kw: float, t0: float, t1: float,
    ) -> float:
        """Bill one migration (NIC/system draw) span: all grid, no
        renewable credit — exactly the historical treatment."""
        if self._serve_sync is not None:
            self._serve_sync()
        span = t1 - t0
        e = p_kw * span / HOUR
        self.migration_kwh += e
        self._snk_kwh[site] += e
        return self._grid_sink(site, p_kw, e, t0, t1, 0.0)

    def post_serve(self, site: int, p_kw: float, t0: float, t1: float):
        """Bill one serving-replica service span (the plane's historical
        ``_bill``, guards and all — serving digits never move)."""
        if self._serve_sync is not None:
            self._serve_sync()
        span = t1 - t0
        if span <= 0.0:
            return
        green = self.traces[site].renewable_seconds(t0, t1)
        self.serve_renewable_kwh += p_kw * green / HOUR
        self.serve_grid_kwh += p_kw * (span - green) / HOUR
        e_tot = p_kw * span / HOUR
        self._src_kwh[site] += e_tot
        self._snk_kwh[site] += e_tot
        if self.signals is None or green >= span:
            if self.signals is None:
                return
        if green <= 0.0:
            ci = self.signals.carbon.integral(site, t0, t1)
        else:
            ov = self.traces[site].overlaps(t0, t1)
            ci = grid_signal_integral(self.signals.carbon, site, ov, t0, t1)
        g = p_kw / HOUR * ci
        self.request_gco2 += g
        self.site_request_gco2[site] += g

    @cached_property
    def _serve_window_stack(self):
        """Padded window stack for :meth:`post_serve_block` span
        classification (built lazily; serving traces are static for the
        life of a run, matching the plane's own stack assumption)."""
        from repro.core.traces import stack_traces
        return stack_traces(self.traces)

    @cached_property
    def _serve_window_lists(self):
        """Per-site window boundaries as Python lists plus the mutable
        warm-start pointer state for :meth:`post_serve_block` (the +inf
        padding from the stack doubles as the sentinel that stops the
        pointer advance)."""
        st = self._serve_window_stack
        return ([row.tolist() for row in st.starts],
                [row.tolist() for row in st.ends],
                [-1] * len(st.starts))

    def post_serve_block(self, sites, p_kw: float, t0s, t1s) -> None:
        """Bill a sequence of service spans, bit-identical to calling
        :meth:`post_serve` once per span in order.

        Sub-second service spans almost never straddle a renewable
        window edge, which leaves two exact-arithmetic regimes:

        * fully inside one window — ``renewable_seconds`` returns the
          span itself (one ``min/max`` clip, no summation), so the grid
          half is ``p_kw * (span - span) / HOUR == +0.0`` and
          ``grid_signal_integral`` over the full overlap is
          ``tot - tot == +0.0``: both adds are bitwise no-ops and can
          be skipped;
        * fully inside one gap — ``renewable_seconds`` is ``+0.0``, the
          renewable add is a no-op, and the carbon integral takes the
          ``green <= 0`` branch, whose batched mirror is
          ``SignalStack.integral_rows`` (documented bit-identical).

        Spans that do straddle an edge (or are non-positive) fall back
        to the scalar posting, preserving sequence order around them.
        """
        n = len(sites)
        if n == 0:
            return
        if self.traces is None or n < 8:
            for i in range(n):
                self.post_serve(sites[i], p_kw, t0s[i], t1s[i])
            return
        if n >= 4096:
            self._post_serve_block_vec(sites, p_kw, t0s, t1s)
            return
        sig = self.signals
        has_sig = sig is not None
        # classify each span against its site's renewable windows with a
        # persistent per-site pointer: service spans complete in nearly
        # monotone time order per site, so the warm-start walk is O(1)
        # amortized (the pointer regresses only when a span's start
        # jitters back across a boundary)
        st_l, en_l, ptrs = self._serve_window_lists
        # 0 = skip (span <= 0), 1 = window, 2 = gap, 3 = straddle
        cls_l: list = []
        ca = cls_l.append
        gi_: list = []
        gs_: list = []
        g0_: list = []
        g1_: list = []
        i = -1
        for s, t0v, t1v in zip(sites, t0s, t1s):
            i += 1
            if t1v <= t0v:
                ca(0)
                continue
            sts = st_l[s]
            p = ptrs[s]
            while sts[p + 1] <= t0v:
                p += 1
            while p >= 0 and sts[p] > t0v:
                p -= 1
            ptrs[s] = p
            if p >= 0:
                if t1v <= en_l[s][p]:
                    ca(1)
                    continue
                if not (t0v >= en_l[s][p] and t1v <= sts[p + 1]):
                    ca(3)
                    continue
            elif t1v > sts[0]:
                ca(3)
                continue
            ca(2)
            if has_sig:
                gi_.append(i)
                gs_.append(s)
                g0_.append(t0v)
                g1_.append(t1v)
        g_l = None
        if has_sig and gi_:
            ci = sig.carbon.integral_rows(
                np.asarray(gs_, dtype=np.int64),
                np.asarray(g0_, dtype=np.float64),
                np.asarray(g1_, dtype=np.float64))
            coef = p_kw / HOUR
            g_l = [0.0] * n
            cil = ci.tolist()
            for j, i in enumerate(gi_):
                g_l[i] = coef * cil[j]
        src = self._src_kwh
        snk = self._snk_kwh
        sg = self.site_request_gco2
        # hoisted float accumulators (flushed around scalar fallbacks,
        # which mutate the same attributes)
        ren = self.serve_renewable_kwh
        grd = self.serve_grid_kwh
        rg = self.request_gco2
        i = -1
        for c, s, t0v, t1v in zip(cls_l, sites, t0s, t1s):
            i += 1
            if c == 1:
                e = p_kw * (t1v - t0v) / HOUR
                ren += e
                src[s] += e
                snk[s] += e
            elif c == 2:
                e = p_kw * (t1v - t0v) / HOUR
                grd += e
                if has_sig:
                    g = g_l[i]
                    rg += g
                    sg[s] += g
                src[s] += e
                snk[s] += e
            elif c == 3:
                self.serve_renewable_kwh = ren
                self.serve_grid_kwh = grd
                self.request_gco2 = rg
                self.post_serve(s, p_kw, t0v, t1v)
                ren = self.serve_renewable_kwh
                grd = self.serve_grid_kwh
                rg = self.request_gco2
        self.serve_renewable_kwh = ren
        self.serve_grid_kwh = grd
        self.request_gco2 = rg

    def _post_serve_block_vec(
        self, sites, p_kw: float, t0s, t1s,
    ) -> None:
        """Large-flush mirror of the pointer-walk path: classification
        by padded-stack broadcast, energies elementwise, and every float
        accumulator advanced with ``np.add.accumulate`` — a strict left
        fold, so the bits match the equivalent scalar ``+=`` loop.
        Straddle spans split the flush into segments and replay through
        the scalar posting at their exact position in the sequence."""
        sa = np.asarray(sites, dtype=np.int64)
        t0a = np.asarray(t0s, dtype=np.float64)
        t1a = np.asarray(t1s, dtype=np.float64)
        n = sa.shape[0]
        st = self._serve_window_stack
        starts, ends = st.starts, st.ends
        cls = np.empty(n, dtype=np.int8)
        # chunked so the (rows, windows) gather/broadcast temporaries
        # stay a few MB regardless of flush size
        for lo in range(0, n, 65536):
            hi = min(lo + 65536, n)
            s_ = sa[lo:hi]
            t0_ = t0a[lo:hi]
            t1_ = t1a[lo:hi]
            stg = starts[s_]
            # p = last window start <= t0 (same count the pointer walk
            # converges to; the +inf padding never counts)
            p = (t0_[:, None] >= stg).sum(axis=1) - 1
            endp = ends[s_, np.maximum(p, 0)]
            nxt = stg[np.arange(hi - lo), p + 1]
            has_p = p >= 0
            w = has_p & (t1_ <= endp)
            gap = np.where(has_p, (t0_ >= endp) & (t1_ <= nxt),
                           t1_ <= stg[:, 0])
            c = np.full(hi - lo, 3, dtype=np.int8)
            c[gap] = 2
            c[w] = 1
            c[t1_ <= t0_] = 0
            cls[lo:hi] = c
        e = p_kw * (t1a - t0a) / HOUR
        wm = cls == 1
        gm = cls == 2
        sig = self.signals
        g_arr = None
        if sig is not None and gm.any():
            ci = sig.carbon.integral_rows(sa[gm], t0a[gm], t1a[gm])
            g_arr = np.zeros(n)
            g_arr[gm] = (p_kw / HOUR) * ci
        e12 = wm | gm
        src = self._src_kwh
        snk = self._snk_kwh
        sg = self.site_request_gco2
        present = np.unique(sa).tolist()

        def _acc(lo: int, hi: int) -> None:
            seg_w = wm[lo:hi]
            seg_g = gm[lo:hi]
            seg_e = e[lo:hi]
            ew = seg_e[seg_w]
            if ew.size:
                self.serve_renewable_kwh = _chain(
                    self.serve_renewable_kwh, ew)
            eg = seg_e[seg_g]
            if eg.size:
                self.serve_grid_kwh = _chain(self.serve_grid_kwh, eg)
                if g_arr is not None:
                    self.request_gco2 = _chain(
                        self.request_gco2, g_arr[lo:hi][seg_g])
            seg_s = sa[lo:hi]
            seg_12 = e12[lo:hi]
            for s in present:
                ms = seg_s == s
                es = seg_e[ms & seg_12]
                if es.size:
                    src[s] = _chain(src[s], es)
                    snk[s] = _chain(snk[s], es)
                if g_arr is not None:
                    gs_v = g_arr[lo:hi][ms & seg_g]
                    if gs_v.size:
                        sg[s] = _chain(sg[s], gs_v)

        prev = 0
        for si in np.flatnonzero(cls == 3).tolist():
            if si > prev:
                _acc(prev, si)
            self.post_serve(int(sa[si]), p_kw,
                            float(t0a[si]), float(t1a[si]))
            prev = si + 1
        if prev < n:
            _acc(prev, n)

    def post_train_tick(
        self, site: int, e_kwh: float, green: bool,
        carb: np.ndarray, price: np.ndarray,
    ) -> None:
        """Fixed-dt (rectangle-rule) training posting — the legacy
        engine's per-tick accounting.  Storage is event-engine only."""
        if self._serve_sync is not None:
            self._serve_sync()
        self._snk_kwh[site] += e_kwh
        self._src_kwh[site] += e_kwh
        if green:
            self.renewable_kwh += e_kwh
        else:
            self.grid_kwh += e_kwh
            self._bill_tick(site, e_kwh, carb, price)

    def post_migration_tick(
        self, site: int, e_kwh: float, carb: np.ndarray, price: np.ndarray,
    ) -> None:
        if self._serve_sync is not None:
            self._serve_sync()
        self.migration_kwh += e_kwh
        self.grid_kwh += e_kwh
        self._snk_kwh[site] += e_kwh
        self._src_kwh[site] += e_kwh
        self._bill_tick(site, e_kwh, carb, price)

    def post_dr(
        self, site: int, p_kw: float, p_nominal_kw: float,
        t0: float, t1: float,
    ) -> None:
        """Demand-response compliance accounting: for every
        :class:`~repro.core.signals.CurtailRequest` overlapping the
        span, accumulate the watt-seconds the request asked to shed
        (``p_nominal * (1 - power_frac)``) and the watt-seconds
        actually shed (``p_nominal - p_kw``)."""
        if self._dr is None or self._dr[site] is None:
            return
        starts, ends, fracs = self._dr[site]
        i = int(np.searchsorted(ends, t0, side="right"))
        n = len(starts)
        while i < n and starts[i] < t1:
            ov = min(t1, ends[i]) - max(t0, starts[i])
            if ov > 0.0:
                self.dr_requested_ws += p_nominal_kw * (1.0 - fracs[i]) * ov
                self.dr_shed_ws += (p_nominal_kw - p_kw) * ov
            i += 1

    # -- the shared grid/battery sink --------------------------------------
    def _grid_sink(
        self, site: int, p_kw: float, e_b: float,
        t0: float, t1: float, green_s: float,
    ) -> float:
        """Grid-draw posting shared by training and migration spans:
        signal-bill the dark portion, let the battery cover what it can,
        and return the net grid kWh actually drawn."""
        span = t1 - t0
        sig = self.signals
        billable = not (span <= 0.0 or green_s >= span) and sig is not None
        if billable:
            if green_s <= 0.0:
                # fully dark span: straight integral
                ci = sig.carbon.integral(site, t0, t1)
                pi = sig.price.integral(site, t0, t1)
            else:
                # mixed span: subtract the window overlaps
                ov = self.traces[site].overlaps(t0, t1)
                ci = grid_signal_integral(sig.carbon, site, ov, t0, t1)
                pi = grid_signal_integral(sig.price, site, ov, t0, t1)
        else:
            ci = pi = 0.0
        if self.battery is None:
            # storage-off fast path: the historical accounting verbatim
            # (no extra multiplies anywhere near the billed values)
            self.grid_kwh += e_b
            self._src_kwh[site] += e_b
            if billable:
                g = p_kw / HOUR * ci
                c = p_kw / HOUR * pi
                self.grid_gco2 += g
                self.grid_cost += c
                self.site_grid_gco2[site] += g
                self.site_grid_cost[site] += c
            return e_b
        # prosumer branch: advance the battery timeline through this
        # span (charging / selling its green subspans), then discharge
        # into its dark demand
        batt = self.battery
        self._advance_battery(site, t1)
        e_d = 0.0
        dark_s = span - green_s
        if e_b > 0.0 and dark_s > 0.0 and self.soc[site] > 0.0:
            thr = batt.discharge_threshold_g
            if thr <= 0.0 or (billable and ci / dark_s >= thr):
                e_d = min(self.soc[site],
                          batt.max_discharge_kw * dark_s / HOUR, e_b)
                if e_d > 0.0:
                    self.soc[site] -= e_d
                    self.battery_discharge_kwh += e_d
        e_grid = e_b - e_d
        self.grid_kwh += e_grid
        self._src_kwh[site] += e_grid + e_d
        if billable:
            g = p_kw / HOUR * ci
            c = p_kw / HOUR * pi
            if e_d > 0.0:
                scale = e_grid / e_b
                g *= scale
                c *= scale
            self.grid_gco2 += g
            self.grid_cost += c
            self.site_grid_gco2[site] += g
            self.site_grid_cost[site] += c
        return e_grid

    def _bill_tick(self, site: int, e_kwh: float,
                   carb: np.ndarray, price: np.ndarray) -> None:
        """Rectangle-rule signal billing of one fixed-dt grid tick."""
        if self.signals is None or e_kwh <= 0.0:
            return
        g = e_kwh * float(carb[site])
        c = e_kwh * float(price[site])
        self.grid_gco2 += g
        self.grid_cost += c
        self.site_grid_gco2[site] += g
        self.site_grid_cost[site] += c

    # -- battery timeline ----------------------------------------------------
    def _advance_battery(self, site: int, t: float) -> None:
        """Advance a site's battery cursor to ``t``: charge from the
        renewable windows (curtailed energy — the trace's green time is
        surplus by construction) at ``max_charge_kw`` until full, then
        export residual green time at ``sellback_kw`` wherever the
        price clears the floor.  Deterministic, zero RNG."""
        t0 = float(self._batt_t[site])
        if t <= t0 or self.traces is None:
            if t > t0:
                self._batt_t[site] = t
            return
        batt = self.battery
        rte = batt.round_trip_efficiency
        cap = batt.capacity_kwh
        for a, b in self.traces[site].overlaps(t0, t):
            if b <= a:
                continue
            # charge leg: rte applied here, so discharge delivers 1:1
            # and round-trip = e_in * rte exactly
            a2 = a
            room = cap - self.soc[site]
            if room > 0.0 and batt.max_charge_kw > 0.0:
                t_full = a + room / (batt.max_charge_kw * rte) * HOUR
                chg_end = min(b, t_full)
                if chg_end > a:
                    e_in = batt.max_charge_kw * (chg_end - a) / HOUR
                    e_st = e_in * rte
                    self.soc[site] += e_st
                    if self.soc[site] > cap:
                        self.soc[site] = cap
                    self.battery_charge_kwh += e_in
                    self.battery_loss_kwh += e_in - e_st
                    self._src_kwh[site] += e_in
                    self._snk_kwh[site] += e_st + (e_in - e_st)
                    a2 = chg_end
            # sell-back: export residual green time where price >= floor
            if (batt.sellback_kw > 0.0 and b > a2
                    and self.signals is not None):
                pi, dur = self.signals.price.integral_where_ge(
                    site, a2, b, batt.sellback_price_floor)
                if dur > 0.0:
                    e_x = batt.sellback_kw * dur / HOUR
                    self.sellback_kwh += e_x
                    self.sellback_usd += batt.sellback_kw / HOUR * pi
                    self._src_kwh[site] += e_x
                    self._snk_kwh[site] += e_x
        self._batt_t[site] = t

    def finalize(self, t_end: float) -> None:
        """Run the battery/sell-back timeline of every site out to the
        end of the simulation (idle sites still charge and export)."""
        if self.battery is not None and self.traces is not None:
            for s in range(self.n_sites):
                self._advance_battery(s, t_end)

    # -- derived metrics -----------------------------------------------------
    @property
    def battery_cycles(self) -> float:
        """Equivalent full discharge cycles summed over the fleet."""
        if self.battery is None:
            return 0.0
        return self.battery_discharge_kwh / self.battery.capacity_kwh

    @property
    def dr_compliance(self) -> float:
        """Fraction of curtail-request span-watts actually shed
        (1.0 when no request overlapped any compute span)."""
        if self.dr_requested_ws <= 0.0:
            return 1.0
        return min(1.0, max(0.0, self.dr_shed_ws / self.dr_requested_ws))

    # -- invariants ----------------------------------------------------------
    def audit(self, rel_tol: float = 1e-9, abs_tol: float = 1e-6) -> None:
        """Conservation invariants (AssertionError on violation):
        per-site sources ≡ sinks (within float accumulation tolerance —
        ``(e_b - e_d) + e_d`` is one ulp off ``e_b``), and the state of
        charge stays within ``[0, capacity]``."""
        if self._serve_sync is not None:
            self._serve_sync()
        scale = np.maximum(np.abs(self._src_kwh), np.abs(self._snk_kwh))
        err = np.abs(self._src_kwh - self._snk_kwh)
        bad = err > np.maximum(rel_tol * scale, abs_tol)
        assert not bad.any(), (
            "ledger sources != sinks at sites "
            f"{np.nonzero(bad)[0].tolist()}: src="
            f"{self._src_kwh[bad]}, snk={self._snk_kwh[bad]}")
        if self.battery is not None:
            cap = self.battery.capacity_kwh
            assert (self.soc >= -abs_tol).all() and (
                self.soc <= cap + abs_tol).all(), (
                f"battery SoC out of [0, {cap}]: {self.soc}")


def _chain(x0, vals: np.ndarray):
    """Sequential-order sum ``(((x0 + v0) + v1) + ...)``: ufunc
    ``accumulate`` is a strict left fold (no pairwise regrouping), so
    the result is bit-identical to a Python loop of ``+=`` adds."""
    buf = np.empty(vals.size + 1)
    buf[0] = x0
    buf[1:] = vals
    np.add.accumulate(buf, out=buf)
    return float(buf[-1])


__all__ = [
    "BatteryConfig", "DVFS_CURVE_POINTS", "PowerLedger", "ThrottleCurve",
]
