"""Streaming multi-site inference serving plane (paper §II "fluid,
geographically adaptive" execution; cf. Heron's renewable-aware request
routing in *AI Greenferencing* and XWind's cross-farm balancing).

The training side of the repo migrates long-running jobs between
renewable windows; this module adds the other half of the green-compute
story: a *request-driven* serving plane that shares the event spine, the
renewable traces, the grid signals and the WAN fabric with the training
simulator, so inference traffic and checkpoint transfers compete for the
same green windows and the same links.

Pieces:

  * :func:`generate_requests` — Poisson request arrivals per origin
    region with a diurnal rate curve (same ``_bump`` shape family as
    :func:`repro.core.signals.generate_signals`), or trace-driven
    arrivals via ``ServingProfile.arrival_trace``.  Deterministic
    per-seed: each site draws from its own ``default_rng([seed, 151,
    site])`` stream, so enabling serving consumes **zero** draws from
    any existing stream (serving off ⇒ bit-identical training results).
  * :class:`ServingPlane` — per-site replica pools with FIFO batch
    queues: arrivals accumulate into per-(origin, model-class) batches
    closed by ``max_batch`` or ``batch_timeout_s``; closed batches are
    routed, ship their request bytes over the WAN as first-class flows
    (sharing :meth:`WanTopology.shared_rates` with migrations), queue at
    the chosen site and occupy a replica for a latency-table service
    time.  Per-request deadline accounting yields p50/p95/p99 latency
    and SLO-violation counts; grid energy drawn by serving is billed in
    gCO2 through the same signal integrals as training.
  * the :class:`Router` registry (``@register_router`` — mirroring the
    policy registry) with three built-ins: ``nearest`` (latency-greedy
    baseline), ``green-first`` (renewable-window-first with grid spill —
    the ``serve --green-route`` behaviour made dynamic) and
    ``carbon-slo`` (forecast-carbon-aware: sheds load away from sites
    ahead of forecast brownouts / carbon peaks while respecting the
    per-class latency SLO).

Event classes (all interleaved with the training engine's events):
request **arrival**, **batch-close** (timeout), **transfer completion**
(routed batch bytes arrive), **service completion**.  The plane exposes
``next_event_s()`` / ``process(t)`` to the next-event loop and
``flow_pairs()`` / ``rerate()`` to the shared WAN re-split, so a
brownout or a new checkpoint transfer slows in-flight request batches
exactly as it slows migrations (and vice versa).
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ledger import PowerLedger
from repro.core.signals import GridSignals, _bump, grid_signal_integral

HOUR = 3600.0
#: RNG stream tag for serving (jobs=+1, failures=+23, forecaster=+7,
#: WAN=+31, signals=131 — serving draws only from [seed, 151, ...]).
_RNG_TAG = 151

#: Router sentinel: "serve nowhere".  A router may return SHED instead
#: of a site id to drop the batch *before* it burns queue space or
#: service energy (``carbon-slo``'s proactive load-shedding ahead of
#: forecast blackouts).  The plane counts shed requests separately from
#: queue-overflow drops (``requests_shed`` vs ``requests_dropped``).
SHED = -2


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelClass:
    """One row of the per-model-class latency table.

    ``batch_s`` is the fixed per-batch service cost (prefill / weight
    paging), ``per_req_s`` the marginal per-request decode cost;
    ``slo_s`` the per-request latency SLO (deadline = arrival + slo),
    ``req_bytes`` the payload shipped over the WAN when routed off the
    origin region (prompt + KV/stream state).
    """

    name: str
    frac: float  # fraction of arrivals drawing this class
    batch_s: float  # fixed service cost per batch
    per_req_s: float  # marginal service cost per request
    slo_s: float  # latency SLO (deadline = t_arrival + slo_s)
    req_bytes: float  # WAN payload per request when routed remotely


DEFAULT_MODEL_CLASSES: Tuple[ModelClass, ...] = (
    ModelClass("chat-small", 0.70, 0.25, 0.05, 10.0, 0.5e6),
    ModelClass("chat-large", 0.25, 1.00, 0.20, 30.0, 2.0e6),
    ModelClass("embed-batch", 0.05, 2.50, 0.40, 120.0, 8.0e6),
)


@dataclass(frozen=True)
class ServingProfile:
    """Scenario-composable serving spec (all plain floats/tuples, frozen).

    ``req_per_s_per_site`` is the base Poisson rate per origin region;
    the realized rate follows a diurnal curve ``base * site_mult *
    (1 + diurnal_amplitude * bump(hour_of_day))`` peaking at
    ``peak_hour`` (evening by default — inference demand peaks exactly
    when the duck-curve carbon does).  ``arrival_trace`` switches to
    trace-driven arrivals: an explicit ``(t_s, origin_site)`` sequence
    replayed verbatim (model classes still drawn per-seed).
    """

    req_per_s_per_site: float = 0.0  # 0 and no trace => serving disabled
    diurnal_amplitude: float = 0.8
    peak_hour: float = 20.5
    peak_width_h: float = 3.5
    site_spread: float = 0.25  # per-site rate multiplier half-range
    model_classes: Tuple[ModelClass, ...] = DEFAULT_MODEL_CLASSES
    replicas_per_site: int = 2
    #: optional per-site replica override (len >= n_sites slices apply);
    #: a 0 entry marks the site *dead* — it serves nothing and, crucially,
    #: :func:`generate_requests` skips its arrival stream entirely so
    #: editing replica counts never shifts RNG draws for live sites
    replicas_by_site: Optional[Tuple[int, ...]] = None
    max_batch: int = 8
    batch_timeout_s: float = 2.0
    max_queue_batches: int = 16  # per-site FIFO bound; beyond => drop
    p_serve_kw: float = 0.35  # replica power draw while serving
    jitter_frac: float = 0.10  # lognormal sigma on service times
    arrival_trace: Optional[Tuple[Tuple[float, int], ...]] = None
    validate: bool = False  # audit conservation at every event boundary

    @property
    def enabled(self) -> bool:
        return self.req_per_s_per_site > 0.0 or bool(self.arrival_trace)

    def replicas_at(self, site: int) -> int:
        """Replica pool size for ``site`` (honouring the optional
        per-site override; sites past the override tuple fall back to
        ``replicas_per_site``)."""
        if (self.replicas_by_site is not None
                and 0 <= site < len(self.replicas_by_site)):
            return int(self.replicas_by_site[site])
        return int(self.replicas_per_site)


# ---------------------------------------------------------------------------
# Runtime records
# ---------------------------------------------------------------------------


@dataclass(slots=True)
class Request:
    rid: int
    t_arrival_s: float
    origin: int
    cls: ModelClass
    deadline_s: float


@dataclass(slots=True)
class RequestBatch:
    """A formed batch: accumulates at the origin until closed (max size
    or timeout), is routed once, ships as one WAN flow when remote, and
    occupies one replica for one service span."""

    bid: int
    origin: int
    cls: ModelClass
    requests: List[Request]
    opened_s: float
    site: int = -1  # routed destination (-1 until routed)
    t_service_start_s: float = -1.0
    service_s: float = 0.0

    @property
    def nominal_service_s(self) -> float:
        """Jitter-free service estimate (what routers may assume without
        consuming RNG)."""
        return self.cls.batch_s + self.cls.per_req_s * len(self.requests)

    @property
    def wan_bits(self) -> float:
        return 8.0 * self.cls.req_bytes * len(self.requests)

    @property
    def earliest_deadline_s(self) -> float:
        return min(r.deadline_s for r in self.requests)


@dataclass(slots=True)
class ServeFlow:
    """An in-flight routed batch on the WAN (one flow per remote batch),
    sharing capacity with checkpoint transfers via the same
    ``shared_rates`` split — same lazy heap-invalidation protocol as
    ``SimJob`` transfers (``ver`` bumps on every re-rate)."""

    fid: int
    batch: RequestBatch
    src: int
    dst: int
    remaining_bits: float
    rate_bps: float = 0.0
    anchor_s: float = 0.0
    ver: int = 0


@dataclass(frozen=True, eq=False)
class ServingView:
    """Immutable per-site serving summary attached to
    ``ClusterState.serving`` — what routers read (alongside the site /
    forecast arrays) to place a batch."""

    replicas: np.ndarray  # (n,) int replica pool size
    busy_replicas: np.ndarray  # (n,) int replicas in service
    queue_batches: np.ndarray  # (n,) int batches waiting (excl. in service)
    queue_requests: np.ndarray  # (n,) int requests waiting
    est_wait_s: np.ndarray  # (n,) float est. queueing delay for a new batch
    max_queue_batches: int = 16
    p_serve_kw: float = 0.35

    def queue_full(self, site: int) -> bool:
        return int(self.queue_batches[site]) >= self.max_queue_batches


# ---------------------------------------------------------------------------
# Arrival process
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestEvents:
    """Columnar request stream — the chunked fast path's native format.

    Rows are sorted by ``(t_s, origin)`` (ties broken by draw order,
    matching the historical stable sort over Request tuples); ``cls_idx``
    indexes ``profile.model_classes``.  :func:`generate_requests` is a
    thin wrapper materializing per-row :class:`Request` objects from
    these arrays, so both paths consume the *same* draws."""

    t_s: np.ndarray  # (m,) float64 arrival times
    origin: np.ndarray  # (m,) int64 origin site
    cls_idx: np.ndarray  # (m,) int64 index into profile.model_classes
    deadline_s: np.ndarray  # (m,) float64 == t_s + slo_s[cls_idx]

    def __len__(self) -> int:
        return int(self.t_s.shape[0])


def generate_request_events(
    profile: ServingProfile, n_sites: int, days: int, *, seed: int = 0,
) -> RequestEvents:
    """Materialize the request stream as sorted columnar arrays.

    Poisson mode: per-site *thinned* non-homogeneous Poisson — draw at
    the per-site peak rate ``lam_max`` and accept each point with
    probability ``rate(t)/lam_max`` (exact for a piecewise-smooth rate
    curve).  Each site owns its stream ``default_rng([seed, 151, site])``
    so the merged process is deterministic per seed and independent of
    every other stream in the run; a site with zero replicas configured
    (``replicas_by_site``) is skipped *before* its rng is constructed,
    so dead sites consume no draws and editing replica counts never
    shifts the arrivals of live sites.  Trace mode replays
    ``profile.arrival_trace`` verbatim (class draws still per-seed).
    """
    horizon = days * 24 * HOUR
    classes = profile.model_classes
    fracs = np.array([c.frac for c in classes], dtype=np.float64)
    cum = np.cumsum(fracs / fracs.sum())
    slo = np.array([c.slo_s for c in classes], dtype=np.float64)

    t_parts: List[np.ndarray] = []
    o_parts: List[np.ndarray] = []
    u_parts: List[np.ndarray] = []
    if profile.arrival_trace is not None:
        rng = np.random.default_rng([seed, _RNG_TAG, 0])
        tr_t: List[float] = []
        tr_o: List[int] = []
        tr_u: List[float] = []
        for t, origin in profile.arrival_trace:
            if 0 <= origin < n_sites:
                tr_t.append(float(t))
                tr_o.append(int(origin))
                tr_u.append(float(rng.random()))
        if tr_t:
            t_parts.append(np.asarray(tr_t, dtype=np.float64))
            o_parts.append(np.asarray(tr_o, dtype=np.int64))
            u_parts.append(np.asarray(tr_u, dtype=np.float64))
    else:
        base = profile.req_per_s_per_site
        amp = profile.diurnal_amplitude
        spread = profile.site_spread
        for site in range(n_sites):
            if profile.replicas_at(site) == 0:
                continue  # dead site: no stream, no draws (see docstring)
            rng = np.random.default_rng([seed, _RNG_TAG, site])
            mult = 1.0 + spread * (2.0 * rng.random() - 1.0)
            lam_max = base * mult * (1.0 + max(amp, 0.0))
            if lam_max <= 0.0:
                continue
            n = rng.poisson(lam_max * horizon)
            ts = np.sort(rng.uniform(0.0, horizon, n))
            hod = (ts / HOUR) % 24.0
            rate = base * mult * (1.0 + amp * _bump(
                hod, profile.peak_hour, profile.peak_width_h))
            keep = rng.random(n) < rate / lam_max
            us = rng.random(n)
            t_parts.append(ts[keep])
            o_parts.append(np.full(int(keep.sum()), site, dtype=np.int64))
            u_parts.append(us[keep])
    if t_parts:
        t_all = np.concatenate(t_parts).astype(np.float64, copy=False)
        o_all = np.concatenate(o_parts).astype(np.int64, copy=False)
        u_all = np.concatenate(u_parts).astype(np.float64, copy=False)
    else:
        t_all = np.zeros(0, dtype=np.float64)
        o_all = np.zeros(0, dtype=np.int64)
        u_all = np.zeros(0, dtype=np.float64)
    # lexsort is stable per key, so equal (t, origin) rows keep draw
    # order — identical to the historical stable list.sort on (t, origin)
    order = np.lexsort((o_all, t_all))
    t_all, o_all, u_all = t_all[order], o_all[order], u_all[order]
    cls_idx = np.searchsorted(cum, u_all, side="left").astype(np.int64)
    deadline = t_all + slo[cls_idx]
    return RequestEvents(t_all, o_all, cls_idx, deadline)


def generate_requests(
    profile: ServingProfile, n_sites: int, days: int, *, seed: int = 0,
) -> List[Request]:
    """Materialize the request stream as time-sorted :class:`Request`
    objects (the scalar plane's format) — a row-wise view of
    :func:`generate_request_events`, bit-identical draws."""
    ev = generate_request_events(profile, n_sites, days, seed=seed)
    classes = profile.model_classes
    return [
        Request(rid, t, origin, classes[ci], dl)
        for rid, (t, origin, ci, dl) in enumerate(zip(
            ev.t_s.tolist(), ev.origin.tolist(),
            ev.cls_idx.tolist(), ev.deadline_s.tolist()))
    ]


# ---------------------------------------------------------------------------
# Router registry (mirrors the policy registry in core/orchestrator.py)
# ---------------------------------------------------------------------------

_ROUTERS: Dict[str, type] = {}
_ROUTER_ALIASES: Dict[str, str] = {}


def _norm(name: str) -> str:
    return name.lower().replace("_", "-")


def register_router(name: str, *, aliases: Tuple[str, ...] = ()):
    """Class decorator: add a Router under ``name`` (stored normalized).
    Unlike the policy registry, re-registering a taken name is an error —
    silently shadowing a built-in router would change routing results."""
    key = _norm(name)

    def deco(cls: type) -> type:
        if key in _ROUTERS and _ROUTERS[key] is not cls:
            raise ValueError(f"router {key!r} is already registered")
        cls.name = key
        _ROUTERS[key] = cls
        for a in aliases:
            _ROUTER_ALIASES[_norm(a)] = key
        return cls

    return deco


def make_router(name: str, **kw) -> "Router":
    key = _norm(name)
    key = _ROUTER_ALIASES.get(key, key)
    if key not in _ROUTERS:
        raise KeyError(
            f"unknown router {name!r}; available: "
            f"{', '.join(available_routers())}")
    return _ROUTERS[key](**kw)


def available_routers() -> List[str]:
    return sorted(_ROUTERS)


class Router:
    """Pluggable batch placement: ``route(batch, state) -> site``.

    ``state`` is a :class:`~repro.core.state.ClusterState` carrying the
    serving view (``state.serving``), the site/forecast arrays and the
    WAN (``state.post_admission_bps`` for admission).  Return any site
    id; the plane guards unreachable / over-full choices (falls back to
    the origin queue, dropping only when that is full too)."""

    name = "router"

    def route(self, batch: RequestBatch, state) -> int:  # pragma: no cover
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------
    @staticmethod
    def _xfer_s(batch: RequestBatch, state, site: int) -> float:
        """Estimated WAN shipping time origin -> site for this batch
        (post-admission rate: the batch's own flow dilutes the links)."""
        if site == batch.origin:
            return 0.0
        rate = state.post_admission_bps(batch.origin, site)
        return batch.wan_bits / rate if rate > 0.0 else float("inf")

    @staticmethod
    def _candidates(batch: RequestBatch, state) -> List[int]:
        """Sites a batch could go to: queue not full, and (for remote
        sites) structurally reachable from the origin.  The origin is
        always a candidate — over-full origins are the plane's drop
        decision, not the router's."""
        sv = state.serving
        wan = state.wan
        out = [batch.origin]
        for s in range(state.n_sites):
            if s == batch.origin or sv.queue_full(s):
                continue
            if wan is not None and not wan.reachable(batch.origin, s):
                continue
            out.append(s)
        return out


@register_router("nearest", aliases=("latency", "local-first"))
class NearestRouter(Router):
    """Latency-greedy baseline: stay at the origin unless its queue is
    full (or clearly slower); otherwise the candidate minimizing
    transfer + queueing delay.  Carbon-blind by construction."""

    def route(self, batch: RequestBatch, state) -> int:
        sv = state.serving
        if not sv.queue_full(batch.origin):
            return batch.origin
        best, best_key = batch.origin, (float("inf"), batch.origin)
        for s in self._candidates(batch, state):
            delay = self._xfer_s(batch, state, s) + float(sv.est_wait_s[s])
            key = (delay, s)
            if key < best_key:
                best, best_key = s, key
        return best


@register_router("green-first", aliases=("green", "renewable-first"))
class GreenFirstRouter(Router):
    """The ``serve --green-route`` behaviour made dynamic: renewable
    sites first (longest remaining window wins), then sites whose
    forecast window opens within ``lookahead_s``, then grid spill by
    least queue (cleanest grid breaking ties).  ``min_gbps`` > 0 demands
    that post-admission bandwidth for remote placement."""

    def __init__(self, lookahead_s: float = 2 * HOUR, min_gbps: float = 0.0):
        self.lookahead_s = float(lookahead_s)
        self.min_gbps = float(min_gbps)

    def _admissible(self, batch: RequestBatch, state, site: int) -> bool:
        if site == batch.origin or self.min_gbps <= 0.0:
            return True
        return (state.post_admission_bps(batch.origin, site)
                >= self.min_gbps * 1e9)

    def route(self, batch: RequestBatch, state) -> int:
        sv = state.serving
        green = state.site_renewable
        window = state.site_window_s
        nxt = state.site_next_window_s
        cands = [s for s in self._candidates(batch, state)
                 if self._admissible(batch, state, s)]
        free_green = [s for s in cands if green[s]]
        if free_green:
            return max(free_green, key=lambda s: (
                float(window[s]), -float(sv.est_wait_s[s]), -s))
        soon = [s for s in cands
                if state.t < float(nxt[s]) <= state.t + self.lookahead_s]
        if soon:
            return min(soon, key=lambda s: (
                float(nxt[s]), float(sv.est_wait_s[s]), s))
        carbon = state.site_carbon
        return min(cands, key=lambda s: (
            float(sv.est_wait_s[s]), bool(not green[s]),
            float(carbon[s]), s))


@register_router("carbon-slo", aliases=("carbon", "slo-carbon"))
class CarbonSloRouter(Router):
    """Carbon-aware routing under the latency SLO: estimate, per
    candidate site, when the batch would start and finish service
    (transfer + queue + service), veto remote placements whose transfer
    window collides with a forecast WAN outage, and pick the minimum
    *forecast grid carbon* of the service span among SLO-feasible sites
    (falling back to earliest-completion when none is feasible) —
    shedding load away from sites heading into forecast brownouts or
    carbon peaks while respecting deadlines.

    Under an active fault plan the router additionally consults the
    realized fault calendar (``ForecastHorizon.site_repair_grid`` /
    ``next_fault_start_grid``): remote candidates whose endpoint is dark
    *now* or whose link is forecast to die before the payload lands are
    vetoed, and when ``proactive_shed`` is on and no candidate can meet
    the SLO budget at all, the batch is **shed** (:data:`SHED`) instead
    of burning queue space and service energy on a guaranteed miss.
    Both layers are inert on fault-free scenarios (the grids are None
    without a plan), so fault-free routing digits are untouched."""

    def __init__(self, slo_margin: float = 0.9, proactive_shed: bool = True):
        self.slo_margin = float(slo_margin)
        self.proactive_shed = bool(proactive_shed)

    def route(self, batch: RequestBatch, state) -> int:
        sv = state.serving
        fc = state.forecast
        t = state.t
        deadline = batch.earliest_deadline_s
        # feasibility budget: finish within slo_margin of the tightest
        # remaining deadline (absorbs jitter + estimate error)
        budget = t + self.slo_margin * max(deadline - t, 0.0)
        svc = batch.nominal_service_s
        # realized fault calendar — None without an active fault plan,
        # which keeps every fault-aware branch below inert on fault-free
        # scenarios (bit-identical routing to the pre-fault router)
        rep = fc.site_repair_grid(t) if fc is not None else None
        nf = fc.next_fault_start_grid(t) if rep is not None else None
        best, best_key = batch.origin, None
        for s in self._candidates(batch, state):
            xfer = self._xfer_s(batch, state, s)
            if not np.isfinite(xfer):
                continue
            if s != batch.origin:
                if rep is not None and (rep[s] > 0.0
                                        or rep[batch.origin] > 0.0):
                    continue  # endpoint blacked out right now
                # a forecast outage opening before the payload lands
                # would stall the batch mid-flight: shed away from it
                if fc is not None and fc.next_outage_start_s(
                        batch.origin, s, t) < t + xfer:
                    continue
                if nf is not None and nf[batch.origin, s] < t + xfer:
                    continue  # hard fault forecast to cut the link
            est_start = t + xfer + float(sv.est_wait_s[s])
            est_done = est_start + svc
            feasible = est_done <= budget
            if fc is not None:
                grams = fc.grid_carbon_g(s, est_start, est_done,
                                         sv.p_serve_kw)
            else:
                grams = 0.0
            key = (not feasible, grams, est_done, s)
            if best_key is None or key < best_key:
                best, best_key = s, key
        if (self.proactive_shed and rep is not None
                and best_key is not None and best_key[0]):
            # fault plan active and *no* candidate meets the SLO budget:
            # serving would burn energy on a guaranteed miss — shed
            return SHED
        return best


# ---------------------------------------------------------------------------
# The serving plane
# ---------------------------------------------------------------------------


class ServingPlane:
    """Per-site replica pools + batch queues + WAN request flows, driven
    by the next-event loop.

    Protocol with the engine (``ClusterSimulator._run_event``):

      * ``next_event_s()`` joins the engine's ``min()`` over event
        sources;
      * ``process(t)`` handles every due serving event (arrivals, batch
        closes, flow landings, service completions) and returns True
        when the WAN flow set changed (the engine then re-splits all
        rates, migrations included);
      * ``flow_pairs()`` / ``rerate(t, rates)`` let the engine's
        ``refresh_transfers`` treat request flows and checkpoint
        transfers as one flow set over :meth:`WanTopology.shared_rates`.

    All RNG use is confined to the ``[seed, 151, ...]`` streams (arrival
    generation at construction + one jitter stream at service start), so
    a run with serving disabled draws identically to one without the
    plane constructed at all.
    """

    def __init__(
        self,
        profile: ServingProfile,
        router: Router,
        *,
        n_sites: int,
        days: int,
        seed: int,
        topo,
        traces: Sequence,
        signals: Optional[GridSignals] = None,
        state_fn: Optional[Callable[[float], object]] = None,
        ledger: Optional[PowerLedger] = None,
    ):
        self.profile = profile
        self.router = router
        self.n_sites = n_sites
        self.topo = topo
        self.traces = traces
        self.signals = signals
        self._state_fn = state_fn
        # all serve-energy/request-carbon accounting posts to the shared
        # per-site PowerLedger (the simulator passes its own; a plane
        # constructed standalone gets a private one) — the postings
        # reproduce the historical `_bill` op for op
        self.ledger = ledger if ledger is not None else PowerLedger(
            n_sites, signals=signals, traces=traces)
        self.requests = generate_requests(profile, n_sites, days, seed=seed)
        self._ptr = 0
        self._jitter_rng = np.random.default_rng([seed, _RNG_TAG, 10 ** 6])
        # batch formation / queues / replicas
        self._open: Dict[Tuple[int, str], RequestBatch] = {}
        self._batches: Dict[int, RequestBatch] = {}
        self._next_bid = 0
        self._close_heap: List[Tuple[float, int]] = []
        self._queues: List[deque] = [deque() for _ in range(n_sites)]
        self._queued_reqs = np.zeros(n_sites, dtype=np.int64)
        self._pending_service_s = np.zeros(n_sites)
        self.replicas = np.array(
            [profile.replicas_at(s) for s in range(n_sites)], dtype=np.int64)
        self.busy = np.zeros(n_sites, dtype=np.int64)
        # WAN flows
        self._flows: Dict[int, ServeFlow] = {}
        self._next_fid = 0
        self._flow_heap: List[Tuple[float, int, int]] = []
        # in-service batches
        self._svc_heap: List[Tuple[float, int]] = []
        # counters / accounting
        self.arrived = 0
        self.served = 0
        self.dropped = 0
        self.shed = 0  # router-initiated proactive sheds (not overflow)
        self.slo_violations = 0
        self._timing: Optional[Dict[str, float]] = None
        self.latencies: List[float] = []
        self.queue_samples: List[int] = []
        self.site_served = np.zeros(n_sites, dtype=np.int64)
        self.site_routed = np.zeros(n_sites, dtype=np.int64)
        # Little's-law area integral: ∫ N_in_system dt
        self._in_system = 0
        self._area_t = 0.0
        self.area_request_s = 0.0

    # -- wiring --------------------------------------------------------------
    def bind(self, state_fn: Callable[[float], object]) -> None:
        """Attach the routing-state factory (the simulator's light,
        noise-free snapshot builder)."""
        self._state_fn = state_fn

    # -- event interface -----------------------------------------------------
    def next_event_s(self) -> float:
        """Earliest pending serving event (inf when idle)."""
        INF = float("inf")
        t = (self.requests[self._ptr].t_arrival_s
             if self._ptr < len(self.requests) else INF)
        while self._close_heap:
            tc, bid = self._close_heap[0]
            b = self._batches.get(bid)
            if b is not None and b.site < 0:
                t = min(t, tc)
                break
            heapq.heappop(self._close_heap)
        while self._flow_heap:
            tf, fid, ver = self._flow_heap[0]
            f = self._flows.get(fid)
            if f is not None and f.ver == ver:
                t = min(t, tf)
                break
            heapq.heappop(self._flow_heap)
        if self._svc_heap:
            t = min(t, self._svc_heap[0][0])
        return t

    def pending(self) -> bool:
        """Whether any request remains unprocessed (future arrivals or
        requests still in the system)."""
        return self._ptr < len(self.requests) or self._in_system > 0

    def enable_timing(self) -> Dict[str, float]:
        """Turn on the per-event-class wall breakdown (arrivals /
        batch-close / flow / service / router) and return the live
        accumulator dict — read it after the run."""
        if self._timing is None:
            self._timing = {"arrivals_s": 0.0, "batch_close_s": 0.0,
                            "flow_s": 0.0, "service_s": 0.0,
                            "router_s": 0.0}
        return self._timing

    def process(self, t: float, eps: float = 1e-6) -> bool:
        """Handle every serving event due at ``t``; returns True when the
        WAN flow set changed (caller must re-split shared rates)."""
        flows_dirty = False
        tm = self._timing
        if tm is not None:
            _t0 = time.perf_counter()
        # 1) arrivals -> batch formation (max-batch closes route now)
        while (self._ptr < len(self.requests)
               and self.requests[self._ptr].t_arrival_s <= t + eps):
            r = self.requests[self._ptr]
            self._ptr += 1
            self.arrived += 1
            self._bump_area(t)
            self._in_system += 1
            key = (r.origin, r.cls.name)
            b = self._open.get(key)
            if b is None:
                b = RequestBatch(self._next_bid, r.origin, r.cls, [r], t)
                self._next_bid += 1
                self._batches[b.bid] = b
                self._open[key] = b
                heapq.heappush(self._close_heap,
                               (t + self.profile.batch_timeout_s, b.bid))
            else:
                b.requests.append(r)
            if len(b.requests) >= self.profile.max_batch:
                self._open.pop(key, None)
                flows_dirty |= self._dispatch(b, t)
        if tm is not None:
            _t1 = time.perf_counter()
            tm["arrivals_s"] += _t1 - _t0
            _t0 = _t1
        # 2) batch-close timeouts
        while self._close_heap and self._close_heap[0][0] <= t + eps:
            _, bid = heapq.heappop(self._close_heap)
            b = self._batches.get(bid)
            if b is None or b.site >= 0:
                continue  # already dispatched at max size
            self._open.pop((b.origin, b.cls.name), None)
            flows_dirty |= self._dispatch(b, t)
        if tm is not None:
            _t1 = time.perf_counter()
            tm["batch_close_s"] += _t1 - _t0
            _t0 = _t1
        # 3) WAN flow landings: the routed batch reaches its queue
        while self._flow_heap and self._flow_heap[0][0] <= t + eps:
            _, fid, ver = heapq.heappop(self._flow_heap)
            f = self._flows.get(fid)
            if f is None or f.ver != ver:
                continue
            self._flush_flow(f, t)
            self._flows.pop(fid, None)
            flows_dirty = True
            self._enqueue(f.batch, f.dst, t)
        if tm is not None:
            _t1 = time.perf_counter()
            tm["flow_s"] += _t1 - _t0
            _t0 = _t1
        # 4) service completions
        while self._svc_heap and self._svc_heap[0][0] <= t + eps:
            _, bid = heapq.heappop(self._svc_heap)
            b = self._batches.pop(bid)
            self._complete_service(b, t)
        self._start_services(t)
        if tm is not None:
            tm["service_s"] += time.perf_counter() - _t0
        if self.profile.validate:
            self.audit()
        return flows_dirty

    # -- WAN flow interface (shared split with migrations) -------------------
    def flow_pairs(self) -> List[Tuple[int, int]]:
        """In-flight request flows as (src, dst) pairs, insertion-ordered
        (appended after migration pairs in the engine's shared split)."""
        return [(f.src, f.dst) for f in self._flows.values()]

    def rerate(self, t: float, rates: Sequence[float]) -> None:
        """Apply freshly split rates (aligned with :meth:`flow_pairs`):
        flush bits at the old rate, set the new one, requeue landings."""
        for f, r in zip(self._flows.values(), rates):
            self._flush_flow(f, t)
            f.rate_bps = float(r)
            f.ver += 1
            if f.rate_bps > 0.0:
                heapq.heappush(
                    self._flow_heap,
                    (t + f.remaining_bits / f.rate_bps, f.fid, f.ver))
            # rate 0 (browned out): lands when a re-rate revives the link

    def _flush_flow(self, f: ServeFlow, t: float) -> None:
        span = t - f.anchor_s
        if span > 0.0:
            f.remaining_bits = max(0.0, f.remaining_bits - f.rate_bps * span)
        f.anchor_s = t

    # -- internals -----------------------------------------------------------
    def _dispatch(self, batch: RequestBatch, t: float) -> bool:
        """Route a closed batch; returns True when a WAN flow started."""
        site = batch.origin
        if self._state_fn is not None:
            tm = self._timing
            if tm is not None:
                _t0 = time.perf_counter()
            try:
                site = int(self.router.route(batch, self._state_fn(t)))
            except Exception:
                site = batch.origin
            if tm is not None:
                tm["router_s"] += time.perf_counter() - _t0
        if site == SHED:
            self._shed(batch, t)
            return False
        if not 0 <= site < self.n_sites:
            site = batch.origin
        if site != batch.origin and not self.topo.reachable(batch.origin,
                                                            site):
            site = batch.origin
        batch.site = site
        self.site_routed[site] += len(batch.requests)
        if site == batch.origin:
            self._enqueue(batch, site, t)
            return False
        f = ServeFlow(self._next_fid, batch, batch.origin, site,
                      batch.wan_bits, anchor_s=t)
        self._next_fid += 1
        self._flows[f.fid] = f
        return True  # caller re-splits; rerate() queues the landing

    def _enqueue(self, batch: RequestBatch, site: int, t: float) -> None:
        q = self._queues[site]
        if len(q) >= self.profile.max_queue_batches:
            self._drop(batch, t)
            return
        q.append(batch)
        self._queued_reqs[site] += len(batch.requests)
        self._pending_service_s[site] += batch.nominal_service_s
        self.queue_samples.append(int(self._queued_reqs[site]))

    def _drop(self, batch: RequestBatch, t: float) -> None:
        n = len(batch.requests)
        self.dropped += n
        self._bump_area(t)
        self._in_system -= n
        self._batches.pop(batch.bid, None)

    def _shed(self, batch: RequestBatch, t: float) -> None:
        """Router-initiated proactive shed (carbon-slo ahead of forecast
        faults): the batch leaves the system unserved, counted apart
        from queue-overflow drops."""
        n = len(batch.requests)
        self.shed += n
        self._bump_area(t)
        self._in_system -= n
        self._batches.pop(batch.bid, None)

    def _start_services(self, t: float) -> None:
        for s in range(self.n_sites):
            q = self._queues[s]
            while q and self.busy[s] < self.replicas[s]:
                b = q.popleft()
                self._queued_reqs[s] -= len(b.requests)
                self._pending_service_s[s] -= b.nominal_service_s
                self.busy[s] += 1
                jitter = float(np.exp(self._jitter_rng.normal(
                    0.0, self.profile.jitter_frac)))
                b.service_s = b.nominal_service_s * jitter
                b.t_service_start_s = t
                heapq.heappush(self._svc_heap, (t + b.service_s, b.bid))

    def _complete_service(self, b: RequestBatch, t: float) -> None:
        s = b.site
        self.busy[s] -= 1
        n = len(b.requests)
        self.served += n
        self.site_served[s] += n
        self._bump_area(t)
        self._in_system -= n
        for r in b.requests:
            lat = t - r.t_arrival_s
            self.latencies.append(lat)
            if t > r.deadline_s:
                self.slo_violations += 1
        self._bill(s, b.t_service_start_s, t)

    # -- fault interface (core/faults.py replica-crash spans) ----------------
    def crash_replica(self, site: int, t: float) -> bool:
        """A replica-crash span opens at ``site``: capacity drops to zero
        until :meth:`repair_replica`.  In-service batches are interrupted
        (the energy already drawn is billed, the work is lost) and
        re-routed through the router like a fresh dispatch; queued
        batches re-drain the same way.  Requests never leave the system
        (``audit`` conservation holds across arbitrary crash sequences) —
        a batch the router sends back to the dead site simply waits in
        its queue for the repair.  Returns True when the WAN flow set
        changed (re-routes that cross the WAN)."""
        s = int(site)
        self.replicas[s] = 0
        flows_dirty = False
        interrupted: List[RequestBatch] = []
        keep: List[Tuple[float, int]] = []
        for td, bid in self._svc_heap:
            b = self._batches.get(bid)
            if b is not None and b.site == s:
                interrupted.append(b)
            else:
                keep.append((td, bid))
        if interrupted:
            heapq.heapify(keep)
            self._svc_heap = keep
        for b in interrupted:
            self.busy[s] -= 1
            self._bill(s, b.t_service_start_s, t)
            b.t_service_start_s = -1.0
            b.service_s = 0.0
            flows_dirty |= self._dispatch(b, t)
        q = self._queues[s]
        if q:
            drained = list(q)
            q.clear()
            for b in drained:
                self._queued_reqs[s] -= len(b.requests)
                self._pending_service_s[s] -= b.nominal_service_s
                flows_dirty |= self._dispatch(b, t)
        self._start_services(t)
        if self.profile.validate:
            self.audit()
        return flows_dirty

    def repair_replica(self, site: int, t: float) -> bool:
        """The crash span closes: capacity returns and whatever queued at
        the dead site during the span starts draining.  Never changes the
        WAN flow set (returns False)."""
        s = int(site)
        self.replicas[s] = self.profile.replicas_at(s)
        self._start_services(t)
        if self.profile.validate:
            self.audit()
        return False

    def _bill(self, site: int, t0: float, t1: float) -> None:
        """Bill the service span's energy: renewable overlap free, the
        grid remainder in kWh + gCO2 (posted through the shared
        PowerLedger — same exact signal integrals as the training
        accounting, separate accumulators, so training digits never
        move)."""
        self.ledger.post_serve(site, self.profile.p_serve_kw, t0, t1)

    # serve accounting lives in the ledger; these read-through views
    # keep the plane's historical attribute surface
    @property
    def serve_grid_kwh(self) -> float:
        return self.ledger.serve_grid_kwh

    @property
    def serve_renewable_kwh(self) -> float:
        return self.ledger.serve_renewable_kwh

    @property
    def request_gco2(self) -> float:
        return self.ledger.request_gco2

    @property
    def site_request_gco2(self) -> np.ndarray:
        return self.ledger.site_request_gco2

    def _bump_area(self, t: float) -> None:
        self.area_request_s += self._in_system * (t - self._area_t)
        self._area_t = t

    # -- views / invariants / stats ------------------------------------------
    def view(self) -> ServingView:
        """Immutable router-facing per-site summary (copies — the plane
        mutates its arrays in place)."""
        est = np.where(
            self.replicas > 0,
            self._pending_service_s / np.maximum(self.replicas, 1),
            float("inf"))
        return ServingView(
            replicas=self.replicas.copy(),
            busy_replicas=self.busy.copy(),
            queue_batches=np.array([len(q) for q in self._queues],
                                   dtype=np.int64),
            queue_requests=self._queued_reqs.copy(),
            est_wait_s=est,
            max_queue_batches=self.profile.max_queue_batches,
            p_serve_kw=self.profile.p_serve_kw,
        )

    @property
    def in_flight(self) -> int:
        """Requests in the system right now (open batches + WAN flows +
        queued + in service)."""
        return self._in_system

    def audit(self) -> None:
        """Conservation invariants (raise AssertionError on violation):
        arrived == served + dropped + shed + in-system, and the in-system
        count decomposes exactly into open/flying/queued/in-service
        requests."""
        assert self.arrived == (self.served + self.dropped + self.shed
                                + self._in_system), (
            self.arrived, self.served, self.dropped, self.shed,
            self._in_system)
        open_n = sum(len(b.requests) for b in self._open.values())
        fly_n = sum(len(f.batch.requests) for f in self._flows.values())
        q_n = int(self._queued_reqs.sum())
        svc_n = sum(len(self._batches[bid].requests)
                    for _, bid in self._svc_heap if bid in self._batches
                    and self._batches[bid].t_service_start_s >= 0.0)
        assert self._in_system == open_n + fly_n + q_n + svc_n, (
            self._in_system, open_n, fly_n, q_n, svc_n)

    def latency_percentiles(self) -> Tuple[float, float, float]:
        if not self.latencies:
            return (0.0, 0.0, 0.0)
        arr = np.asarray(self.latencies)
        p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
        return float(p50), float(p95), float(p99)

    def queue_depth_p95(self) -> float:
        if not self.queue_samples:
            return 0.0
        return float(np.percentile(np.asarray(self.queue_samples), 95.0))


__all__ = [
    "DEFAULT_MODEL_CLASSES", "CarbonSloRouter", "GreenFirstRouter",
    "ModelClass", "NearestRouter", "Request", "RequestBatch",
    "RequestEvents", "Router", "SHED", "ServeFlow", "ServingPlane",
    "ServingProfile", "ServingView", "available_routers",
    "generate_request_events", "generate_requests", "make_router",
    "register_router",
]
