"""Scenario registry: named, composable experiment setups.

A :class:`Scenario` bundles everything that defines an experiment other
than the policy: the renewable trace profile, the job mix, the WAN
topology/failure behaviour, the node-failure regime and the forecast noise.
The simulator (``ClusterSimulator.from_scenario`` /
``run_policy_comparison(scenario=...)``), the benchmarks and the examples
all consume scenarios by name, so new workloads are added here once instead
of by editing ``SimConfig`` defaults at every call site.

Built-ins:

  paper-table6       the paper's §VII setup (5 sites, 10 Gbps, 240 jobs,
                     7-day CAISO-calibrated trace, A/B/C = 70/20/10)
  flaky-wan          inter-site links randomly degrade to 0.5 Gbps for
                     hour-long episodes — feasibility filtering matters most
  solar-heavy        long midday surplus windows, little night wind
  large-ckpt-classC  half the jobs carry 100–300 GB (class C) checkpoints
  failure-storm      aggressive node failures + checkpoint/restart churn
  hub-spoke-wan      40 Gbps hub at site 0, 1 Gbps direct spoke-to-spoke
  asymmetric-uplink  2.5 Gbps egress / 10 Gbps ingress NICs everywhere
  partitioned-wan    two island fabrics joined by thin 0.25 Gbps links
  forecastable-brownouts  per-link brownout calendars readable through
                     state.forecast — the plan-ahead policy's home turf
  carbon-peaks       hard duck-curve carbon intensity (evening ~700
                     gCO2/kWh over a midday trough) — the
                     receding-horizon policy's home turf
  price-spread       wide per-site wholesale price spread; grid_cost
                     separates policies the kWh columns cannot
  demand-response    advisory curtail-request events during carbon peaks,
                     honoured only by signal-aware policies
  battery-bridging   per-site 20 kWh batteries charge from curtailed midday
                     surplus and discharge through the evening carbon peak
  sellback-spread    price seams + a 5 kW export line gated at 0.12 $/kWh:
                     sell-back revenue separates sites carbon cannot
  inference-diurnal  serving-dominated: evening-peaked request stream over
                     a light training load, routed green-first
  train-plus-serve   the combined fabric: paper-table6 training plus a
                     carbon-slo-routed inference stream on the same WAN
  chaos-monkey       all five fault classes at once at mild rates — the
                     whole recovery spine on one run, every job completes
  blackout-cascade   rolling correlated site blackouts + hard link
                     failures; fault-aware planning vs the fault-blind trap

The WAN half of a scenario is a :class:`repro.core.wan.WanProfile`
(per-site NIC rates, per-link capacity matrix, fabric- or per-link-scoped
brownouts); ``Scenario.build_wan()`` materializes the
:class:`~repro.core.wan.WanTopology` that the simulator, the dry-run
planner and the serve router all consume.

Register your own:

    from repro.core.scenarios import Scenario, register_scenario
    register_scenario(Scenario(name="my-case", description="...",
                               wan=WanProfile(gbps=1.0)))

Scenarios are frozen dataclasses — derive variants with
``dataclasses.replace`` (composability without mutation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Union

from repro.core.faults import FaultRegime, RetryPolicy
from repro.core.ledger import BatteryConfig, ThrottleCurve
from repro.core.serving import ServingProfile
from repro.core.signals import SignalProfile
from repro.core.traces import SiteTrace, TraceProfile, generate_trace
from repro.core.wan import (  # noqa: F401  (WanProfile re-exported)
    WanProfile, WanTopology, hub_spoke_links, partitioned_links,
)


@dataclass(frozen=True)
class JobMix:
    """Arrival volume and checkpoint-size classes (paper §VII)."""

    n_jobs: int = 240
    frac_a: float = 0.70
    frac_b: float = 0.20
    size_a_gb: tuple = (1.0, 6.0)
    size_b_gb: tuple = (10.0, 40.0)
    size_c_gb: tuple = (100.0, 300.0)
    mean_compute_h: float = 3.5


@dataclass(frozen=True)
class FailureRegime:
    """Legacy per-job Poisson rollback spec — the alias path for
    :class:`repro.core.faults.FaultRegime.job_failure_rate_per_slot_hour`.
    New scenarios should carry a ``faults=FaultRegime(...)`` instead;
    both feed the same unified ``default_rng([seed, 23])`` stream."""

    rate_per_slot_hour: float = 0.0
    checkpoint_interval_s: float = 1800.0


@dataclass(frozen=True)
class ForecastNoise:
    sigma_s: float = 900.0  # 15-min 1-sigma error on remaining-window
    horizon_s: float = 24 * 3600.0  # ClusterState.forecast lookahead


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    n_sites: int = 5
    slots_per_site: int = 4
    days: int = 7
    dt_s: float = 30.0
    engine: str = "event"  # "event" (next-event) or "fixed-dt" (legacy)
    seed: int = 0
    trace: TraceProfile = field(default_factory=TraceProfile)
    jobs: JobMix = field(default_factory=JobMix)
    wan: WanProfile = field(default_factory=WanProfile)
    failures: FailureRegime = field(default_factory=FailureRegime)
    # fault-injection spec (core/faults.py): site blackouts, hard link
    # failures, checkpoint corruption, replica crashes, stragglers +
    # the recovery knobs (None = no injected faults; the legacy
    # ``failures`` field above remains the per-job-rollback alias)
    faults: Optional[FaultRegime] = None
    forecast: ForecastNoise = field(default_factory=ForecastNoise)
    signals: SignalProfile = field(default_factory=SignalProfile)
    # inference serving plane (None / disabled profile = training only)
    serving: Optional[ServingProfile] = None
    serving_router: str = "green-first"
    # prosumer microgrid layer (core/ledger.py): per-site battery /
    # sell-back spec and the physical power→throughput curve Throttle
    # actions map through (both None = the pre-ledger behaviour)
    battery: Optional[BatteryConfig] = None
    throttle_curve: Optional[ThrottleCurve] = None
    # per-policy default config overrides, applied when the policy is
    # resolved BY NAME for this scenario (an explicit Policy instance or
    # per-call policy_configs entry wins) — lets a scenario exercise a
    # policy knob (price-spread's price-primary objective) without
    # moving that policy's digits on every other scenario
    policy_configs: Mapping[str, Mapping] = field(default_factory=dict)

    def sim_config(self, **overrides):
        """Materialize a ``SimConfig`` for this scenario (overrides win).

        The legacy scalar WAN overrides (``wan_gbps``, ``wan_degrade_prob``,
        ``wan_degraded_gbps``) are folded back into the scenario's
        :class:`WanProfile` so the materialized topology honours them;
        pass ``wan=WanProfile(...)`` to replace the profile wholesale.
        """
        from repro.core.simulator import SimConfig

        kw = dict(
            n_sites=self.n_sites,
            slots_per_site=self.slots_per_site,
            days=self.days,
            dt_s=self.dt_s,
            engine=self.engine,
            seed=self.seed,
            trace=self.trace,
            wan=self.wan,
            wan_gbps=self.wan.gbps,
            wan_degrade_prob=self.wan.hourly_degrade_prob,
            wan_degraded_gbps=self.wan.degraded_gbps,
            n_jobs=self.jobs.n_jobs,
            frac_a=self.jobs.frac_a,
            frac_b=self.jobs.frac_b,
            size_a_gb=self.jobs.size_a_gb,
            size_b_gb=self.jobs.size_b_gb,
            size_c_gb=self.jobs.size_c_gb,
            mean_compute_h=self.jobs.mean_compute_h,
            failure_rate_per_slot_hour=self.failures.rate_per_slot_hour,
            checkpoint_interval_s=self.failures.checkpoint_interval_s,
            faults=self.faults,
            forecast_sigma_s=self.forecast.sigma_s,
            forecast_horizon_s=self.forecast.horizon_s,
            signals=self.signals,
            serving=self.serving,
            serving_router=self.serving_router,
            battery=self.battery,
            throttle_curve=self.throttle_curve,
        )
        kw.update(overrides)
        if "wan" not in overrides:
            if "wan_gbps" in overrides and self.wan.nic_gbps is not None:
                raise ValueError(
                    f"scenario {self.name!r} sets per-site nic_gbps, which "
                    "shadows the uniform wan_gbps override — override "
                    "wan=dataclasses.replace(scenario.wan, nic_gbps=...) "
                    "instead")
            kw["wan"] = dataclasses.replace(
                kw["wan"],
                gbps=kw["wan_gbps"],
                hourly_degrade_prob=kw["wan_degrade_prob"],
                degraded_gbps=kw["wan_degraded_gbps"],
            )
        return SimConfig(**kw)

    def build_traces(self, seed: Optional[int] = None) -> List[SiteTrace]:
        return generate_trace(self.n_sites, self.days,
                              seed=self.seed if seed is None else seed,
                              profile=self.trace)

    def build_wan(self, seed: Optional[int] = None) -> WanTopology:
        """Materialize the scenario's WAN topology — the one object the
        simulator, ``dryrun --plan`` and ``serve --green-route`` share."""
        return self.wan.build_topology(
            self.n_sites, self.days, self.seed if seed is None else seed)

    def build_signals(self, seed: Optional[int] = None):
        """Materialize the scenario's grid signals (carbon/price traces +
        demand-response curtail requests) — identical to what the
        simulator bills against for this scenario/seed."""
        from repro.core.signals import generate_signals

        return generate_signals(self.n_sites, self.days,
                                seed=self.seed if seed is None else seed,
                                profile=self.signals)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (also usable as a decorator on a
    zero-arg factory function returning a Scenario)."""
    if callable(scenario) and not isinstance(scenario, Scenario):
        scn = scenario()
        register_scenario(scn)
        return scenario
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: Union[str, Scenario]) -> Scenario:
    if isinstance(name, Scenario):
        return name
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; available: {', '.join(available_scenarios())}"
        )
    return _REGISTRY[name]


def available_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------

register_scenario(Scenario(
    name="paper-table6",
    description="Paper §VII headline setup: 5 sites x 4 slots, 10 Gbps WAN, "
                "240 jobs / 7 days, A:70% 1-6 GB, B:20% 10-40 GB, "
                "C:10% 100-300 GB, CAISO-calibrated windows.",
))

register_scenario(Scenario(
    name="flaky-wan",
    description="Shared-backbone brownouts: every hour the fabric degrades "
                "to 0.5 Gbps with p=0.25. Transfer-time feasibility is the "
                "whole game; energy-only strands class-B checkpoints.",
    wan=WanProfile(gbps=10.0, hourly_degrade_prob=0.25, degraded_gbps=0.5),
))

register_scenario(Scenario(
    name="solar-heavy",
    description="Long midday curtailment (mean 6.5 h), almost no night "
                "wind: windows are wide but synchronized, so migration "
                "targets saturate.",
    trace=TraceProfile(mean_window_h=6.5, p_wind=0.1, phase_spread_h=4.0),
))

register_scenario(Scenario(
    name="large-ckpt-classC",
    description="Checkpoint-heavy mix: 50% class C (100-300 GB). The §VI.D "
                "class gate dominates; most of the fleet must stay put.",
    jobs=JobMix(frac_a=0.20, frac_b=0.30),
))

register_scenario(Scenario(
    name="failure-storm",
    description="Beyond-paper fault sweep: 0.2 node failures per slot-hour "
                "with 15-min checkpoints — rollback churn stresses the "
                "pause/restart accounting.  (Migrated from the legacy "
                "FailureRegime alias onto core/faults.FaultRegime.)",
    faults=FaultRegime(job_failure_rate_per_slot_hour=0.2,
                       checkpoint_interval_s=900.0),
))

register_scenario(Scenario(
    name="hub-spoke-wan",
    description="Hub-and-spoke fabric: site 0 is a 40 Gbps exchange hub; "
                "direct spoke-to-spoke links are capped at 1 Gbps, but "
                "multi-hop routing relays spoke-to-spoke transfers "
                "through the hub at the full 10 Gbps spoke NIC rate "
                "(contending with hub-adjacent traffic for the hub NICs).",
    wan=WanProfile(gbps=10.0,
                   nic_gbps=(40.0, 10.0, 10.0, 10.0, 10.0),
                   link_gbps=hub_spoke_links(5, hub=0, spoke_gbps=1.0),
                   multi_hop=True),
))

register_scenario(Scenario(
    name="asymmetric-uplink",
    description="Consumer-grade uplinks at renewable micro-sites: every "
                "site ingests at 10 Gbps but egresses at only 2.5 Gbps — "
                "the *source* NIC, not the destination, is the migration "
                "bottleneck, and concurrent evacuations of one dark site "
                "quarter each other.",
    wan=WanProfile(gbps=10.0,
                   nic_gbps=(2.5,) * 5,  # egress
                   nic_in_gbps=(10.0,) * 5),
))

register_scenario(Scenario(
    name="forecastable-brownouts",
    description="Per-link hourly brownouts (p=0.2 to 0.5 Gbps) whose "
                "calendar is published through state.forecast, over windows "
                "with wide geographic phase spread: a reactive policy "
                "starts transfers that stall mid-brownout and burns grid "
                "through dark gaps a planner would Pause or Defer across — "
                "the scenario where plan-ahead's lookahead pays.",
    trace=TraceProfile(mean_window_h=3.5, p_wind=0.35),
    wan=WanProfile(gbps=10.0, hourly_degrade_prob=0.2, degraded_gbps=0.5,
                   brownout_scope="per-link"),
))

register_scenario(Scenario(
    name="carbon-peaks",
    description="Hard duck curve: evening carbon peaks near 700 gCO2/kWh "
                "over a deep midday solar trough, with windows spread "
                "wide in phase.  Grid kWh are NOT interchangeable here — "
                "a kWh at 19:00 emits 3x one at 13:00 — so signal-aware "
                "planning (park across the peak, throttle through it, "
                "migrate toward the cleanest feasible site) beats "
                "plan-ahead's grid-second minimization on gCO2: the "
                "receding-horizon policy's home turf.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    signals=SignalProfile(carbon_evening=400.0, carbon_morning=150.0,
                          carbon_midday_dip=200.0, carbon_noise=12.0,
                          carbon_site_spread=0.15),
))

register_scenario(Scenario(
    name="price-spread",
    description="Wide per-site wholesale price spread (interconnection "
                "seams: some micro-sites buy at a third of others' rate) "
                "with only mild carbon variation — the scenario where the "
                "grid_cost accounting separates policies the kWh and gCO2 "
                "columns cannot.",
    signals=SignalProfile(price_site_spread=0.6, price_coupling=0.3,
                          carbon_evening=120.0, carbon_midday_dip=60.0,
                          carbon_site_spread=0.05),
    # the price-primary objective is the point of this scenario: bias
    # receding-horizon toward $ (2000 g per $ ~ the scenario's own
    # carbon/price exchange rate) whenever it is resolved by name here
    policy_configs={"receding-horizon": {"price_weight_g_per_usd": 2000.0}},
))

register_scenario(Scenario(
    name="demand-response",
    description="Grid-operator demand response: curtail-request events "
                "published through state.forecast whenever a site's "
                "carbon tops 500 gCO2/kWh (every evening ramp), asking "
                "compute to cap at 40% power.  Requests are advisory — "
                "only signal-aware policies (receding-horizon) honour "
                "them, shifting energy out of exactly the hours the "
                "carbon accounting prices highest.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    signals=SignalProfile(carbon_evening=350.0, carbon_midday_dip=180.0,
                          carbon_noise=12.0, curtail_threshold=500.0,
                          curtail_frac=0.4),
))

register_scenario(Scenario(
    name="battery-bridging",
    description="Prosumer storage over the duck curve: each site carries a "
                "20 kWh / 5 kW battery that charges from curtailed midday "
                "surplus and discharges through the evening carbon peak "
                "(mean dark intensity >= 250 gCO2/kWh), bridging compute "
                "across the dirtiest hours; residual green time exports at "
                "2 kW.  Throttle actions map through the measured DVFS "
                "power->throughput curve.  Identical trajectory to "
                "carbon-peaks-shaped runs without storage — the battery "
                "is pure accounting relief, so the gCO2 delta is the "
                "storage value itself.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    signals=SignalProfile(carbon_evening=400.0, carbon_morning=150.0,
                          carbon_midday_dip=200.0, carbon_noise=12.0,
                          carbon_site_spread=0.15),
    battery=BatteryConfig(capacity_kwh=20.0, max_charge_kw=5.0,
                          max_discharge_kw=5.0, round_trip_efficiency=0.90,
                          discharge_threshold_g=250.0, sellback_kw=2.0),
    throttle_curve=ThrottleCurve(),
))

register_scenario(Scenario(
    name="sellback-spread",
    description="Prosumer economics on the price seams: wide per-site "
                "wholesale spread (as in price-spread) with a small 10 kWh "
                "battery and a 5 kW export line gated at 0.12 $/kWh — "
                "sites sell curtailed green energy only where their own "
                "price clears the floor, so sell-back revenue separates "
                "sites the carbon columns cannot.",
    signals=SignalProfile(price_site_spread=0.6, price_coupling=0.3,
                          carbon_evening=120.0, carbon_midday_dip=60.0,
                          carbon_site_spread=0.05),
    battery=BatteryConfig(capacity_kwh=10.0, max_charge_kw=3.0,
                          max_discharge_kw=3.0, round_trip_efficiency=0.90,
                          discharge_threshold_g=0.0, sellback_kw=5.0,
                          sellback_price_floor=0.12),
    policy_configs={"receding-horizon": {"price_weight_g_per_usd": 2000.0}},
))

register_scenario(Scenario(
    name="inference-diurnal",
    description="Serving-dominated fabric: a light training load (60 jobs) "
                "under an evening-peaked inference request stream (diurnal "
                "Poisson, 0.01 req/s/site at base) routed green-first — "
                "requests chase renewable windows while the peak lands "
                "exactly on the duck-curve carbon ramp.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    jobs=JobMix(n_jobs=60),
    signals=SignalProfile(carbon_evening=350.0, carbon_morning=150.0,
                          carbon_midday_dip=180.0, carbon_noise=10.0,
                          carbon_site_spread=0.15),
    serving=ServingProfile(req_per_s_per_site=0.01),
    serving_router="green-first",
))

register_scenario(Scenario(
    name="train-plus-serve",
    description="The combined fabric: the paper-table6 training load plus "
                "an evening-peaked inference stream (0.004 req/s/site) "
                "routed carbon-slo — training migrations and routed "
                "request batches compete for the same WAN links and green "
                "windows, and the router sheds load away from forecast "
                "carbon peaks under the per-class latency SLOs.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    signals=SignalProfile(carbon_evening=350.0, carbon_morning=150.0,
                          carbon_midday_dip=180.0, carbon_noise=10.0,
                          carbon_site_spread=0.25),
    serving=ServingProfile(req_per_s_per_site=0.004),
    serving_router="carbon-slo",
))

register_scenario(Scenario(
    name="inference-heavy",
    description="The serving plane at the paper's 'millions of users' "
                "scale: no training jobs, five replica pools taking "
                "~1.1M requests over the week (0.3 req/s/site base, "
                "evening-peaked) routed latency-greedy.  The acceptance "
                "scenario for the chunked serving fast path — the "
                "per-event engine ticks once per arrival/close/service "
                "here, the span engine chews through the same stream in "
                "array chunks with bit-identical digits.",
    jobs=JobMix(n_jobs=0),
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    serving=ServingProfile(req_per_s_per_site=0.30),
    serving_router="nearest",
))

register_scenario(Scenario(
    name="chaos-monkey",
    description="All five fault classes at once, mildly: occasional site "
                "blackouts (rollback + requeue), hard link failures that "
                "kill transfers mid-flight (watchdog abort -> backoff -> "
                "re-routed retry), 10% checkpoint corruption on rollback, "
                "replica crashes and straggler throughput dips — rates "
                "tuned so every job still completes, exercising the whole "
                "recovery spine plus both chaos audits on one run.",
    faults=FaultRegime(site_blackout_rate_per_day=0.25,
                       site_blackout_mean_s=1800.0,
                       link_failure_rate_per_day=0.3,
                       link_failure_mean_s=900.0,
                       ckpt_corruption_prob=0.10,
                       replica_crash_rate_per_day=0.5,
                       replica_crash_mean_s=1200.0,
                       straggler_rate_per_day=0.5,
                       straggler_mean_s=3600.0,
                       straggler_factor=0.6),
))

register_scenario(Scenario(
    name="blackout-cascade",
    description="Rolling site blackouts (mean 6 h, ~1/day per site) plus "
                "long hard link failures (mean 14 h, ~3.5/day across the "
                "fabric): blacked-out sites keep advertising free slots and "
                "live windows, so a fault-blind policy herds migrations onto "
                "dark links — and without the watchdog those transfers stall "
                "silently for the life of the outage — while a fault-aware "
                "planner masks down destinations and routes around "
                "soon-to-fail links.  The acceptance scenario for the "
                "recovery subsystem.",
    trace=TraceProfile(mean_window_h=3.0, p_wind=0.3, phase_spread_h=8.0),
    signals=SignalProfile(carbon_evening=400.0, carbon_morning=150.0,
                          carbon_midday_dip=200.0, carbon_noise=12.0,
                          carbon_site_spread=0.15),
    faults=FaultRegime(site_blackout_rate_per_day=1.0,
                       site_blackout_mean_s=6 * 3600.0,
                       link_failure_rate_per_day=3.5,
                       link_failure_mean_s=14 * 3600.0,
                       ckpt_corruption_prob=0.05,
                       stall_timeout_s=2 * 3600.0,
                       retry=RetryPolicy(max_attempts=2,
                                         backoff_base_s=7200.0,
                                         backoff_mult=2.0)),
))

register_scenario(Scenario(
    name="partitioned-wan",
    description="Two island fabrics ({0,1,2} and {3,4}) joined by thin "
                "0.25 Gbps links: intra-partition moves run at the full "
                "10 Gbps NIC while cross-partition migration is class-A "
                "only (a 6 GB checkpoint already takes 192 s) — renewable "
                "windows on the far island are mostly unreachable.",
    wan=WanProfile(gbps=10.0,
                   link_gbps=partitioned_links(((0, 1, 2), (3, 4)),
                                               inter_gbps=0.25)),
))


__all__ = [
    "BatteryConfig", "FailureRegime", "FaultRegime", "ForecastNoise",
    "JobMix", "RetryPolicy", "Scenario", "ServingProfile", "SignalProfile",
    "ThrottleCurve", "TraceProfile", "WanProfile", "WanTopology",
    "available_scenarios", "get_scenario", "hub_spoke_links",
    "partitioned_links", "register_scenario",
]
