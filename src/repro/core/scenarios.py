"""Scenario registry: named, composable experiment setups.

A :class:`Scenario` bundles everything that defines an experiment other
than the policy: the renewable trace profile, the job mix, the WAN
topology/failure behaviour, the node-failure regime and the forecast noise.
The simulator (``ClusterSimulator.from_scenario`` /
``run_policy_comparison(scenario=...)``), the benchmarks and the examples
all consume scenarios by name, so new workloads are added here once instead
of by editing ``SimConfig`` defaults at every call site.

Built-ins:

  paper-table6       the paper's §VII setup (5 sites, 10 Gbps, 240 jobs,
                     7-day CAISO-calibrated trace, A/B/C = 70/20/10)
  flaky-wan          inter-site links randomly degrade to 0.5 Gbps for
                     hour-long episodes — feasibility filtering matters most
  solar-heavy        long midday surplus windows, little night wind
  large-ckpt-classC  half the jobs carry 100–300 GB (class C) checkpoints
  failure-storm      aggressive node failures + checkpoint/restart churn

Register your own:

    from repro.core.scenarios import Scenario, register_scenario
    register_scenario(Scenario(name="my-case", description="...",
                               wan=WanProfile(gbps=1.0)))

Scenarios are frozen dataclasses — derive variants with
``dataclasses.replace`` (composability without mutation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from repro.core.traces import SiteTrace, TraceProfile, generate_trace


@dataclass(frozen=True)
class JobMix:
    """Arrival volume and checkpoint-size classes (paper §VII)."""

    n_jobs: int = 240
    frac_a: float = 0.70
    frac_b: float = 0.20
    size_a_gb: tuple = (1.0, 6.0)
    size_b_gb: tuple = (10.0, 40.0)
    size_c_gb: tuple = (100.0, 300.0)
    mean_compute_h: float = 3.5


@dataclass(frozen=True)
class WanProfile:
    """Per-site NIC rate plus an optional flaky-link regime: each hour,
    with probability ``hourly_degrade_prob``, the whole WAN fabric runs at
    ``degraded_gbps`` for that hour (shared-backbone brownout)."""

    gbps: float = 10.0
    hourly_degrade_prob: float = 0.0
    degraded_gbps: float = 1.0


@dataclass(frozen=True)
class FailureRegime:
    rate_per_slot_hour: float = 0.0
    checkpoint_interval_s: float = 1800.0


@dataclass(frozen=True)
class ForecastNoise:
    sigma_s: float = 900.0  # 15-min 1-sigma error on remaining-window


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    n_sites: int = 5
    slots_per_site: int = 4
    days: int = 7
    dt_s: float = 30.0
    seed: int = 0
    trace: TraceProfile = field(default_factory=TraceProfile)
    jobs: JobMix = field(default_factory=JobMix)
    wan: WanProfile = field(default_factory=WanProfile)
    failures: FailureRegime = field(default_factory=FailureRegime)
    forecast: ForecastNoise = field(default_factory=ForecastNoise)

    def sim_config(self, **overrides):
        """Materialize a ``SimConfig`` for this scenario (overrides win)."""
        from repro.core.simulator import SimConfig

        kw = dict(
            n_sites=self.n_sites,
            slots_per_site=self.slots_per_site,
            days=self.days,
            dt_s=self.dt_s,
            seed=self.seed,
            trace=self.trace,
            wan_gbps=self.wan.gbps,
            wan_degrade_prob=self.wan.hourly_degrade_prob,
            wan_degraded_gbps=self.wan.degraded_gbps,
            n_jobs=self.jobs.n_jobs,
            frac_a=self.jobs.frac_a,
            frac_b=self.jobs.frac_b,
            size_a_gb=self.jobs.size_a_gb,
            size_b_gb=self.jobs.size_b_gb,
            size_c_gb=self.jobs.size_c_gb,
            mean_compute_h=self.jobs.mean_compute_h,
            failure_rate_per_slot_hour=self.failures.rate_per_slot_hour,
            checkpoint_interval_s=self.failures.checkpoint_interval_s,
            forecast_sigma_s=self.forecast.sigma_s,
        )
        kw.update(overrides)
        return SimConfig(**kw)

    def build_traces(self, seed: Optional[int] = None) -> List[SiteTrace]:
        return generate_trace(self.n_sites, self.days,
                              seed=self.seed if seed is None else seed,
                              profile=self.trace)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (also usable as a decorator on a
    zero-arg factory function returning a Scenario)."""
    if callable(scenario) and not isinstance(scenario, Scenario):
        scn = scenario()
        register_scenario(scn)
        return scenario
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: Union[str, Scenario]) -> Scenario:
    if isinstance(name, Scenario):
        return name
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; available: {', '.join(available_scenarios())}"
        )
    return _REGISTRY[name]


def available_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------

register_scenario(Scenario(
    name="paper-table6",
    description="Paper §VII headline setup: 5 sites x 4 slots, 10 Gbps WAN, "
                "240 jobs / 7 days, A:70% 1-6 GB, B:20% 10-40 GB, "
                "C:10% 100-300 GB, CAISO-calibrated windows.",
))

register_scenario(Scenario(
    name="flaky-wan",
    description="Shared-backbone brownouts: every hour the fabric degrades "
                "to 0.5 Gbps with p=0.25. Transfer-time feasibility is the "
                "whole game; energy-only strands class-B checkpoints.",
    wan=WanProfile(gbps=10.0, hourly_degrade_prob=0.25, degraded_gbps=0.5),
))

register_scenario(Scenario(
    name="solar-heavy",
    description="Long midday curtailment (mean 6.5 h), almost no night "
                "wind: windows are wide but synchronized, so migration "
                "targets saturate.",
    trace=TraceProfile(mean_window_h=6.5, p_wind=0.1, phase_spread_h=4.0),
))

register_scenario(Scenario(
    name="large-ckpt-classC",
    description="Checkpoint-heavy mix: 50% class C (100-300 GB). The §VI.D "
                "class gate dominates; most of the fleet must stay put.",
    jobs=JobMix(frac_a=0.20, frac_b=0.30),
))

register_scenario(Scenario(
    name="failure-storm",
    description="Beyond-paper fault sweep: 0.2 node failures per slot-hour "
                "with 15-min checkpoints — rollback churn stresses the "
                "pause/restart accounting.",
    failures=FailureRegime(rate_per_slot_hour=0.2, checkpoint_interval_s=900.0),
))


__all__ = [
    "FailureRegime", "ForecastNoise", "JobMix", "Scenario", "TraceProfile",
    "WanProfile", "available_scenarios", "get_scenario", "register_scenario",
]
