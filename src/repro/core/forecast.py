"""Forecast-and-planning subsystem: the lookahead view of renewable
windows and WAN brownouts (paper §VI.H; cf. XWind's per-farm renewable
horizons and Wiesner et al.'s curtailment-window feasibility study).

The reactive snapshot fields (``SiteView.window_remaining_s``,
``next_window_start_s``, the advertised bandwidth matrix) describe *now*.
:class:`ForecastHorizon` is the *plan-ahead* product attached to every
:class:`~repro.core.state.ClusterState` as ``state.forecast``:

  * per-site sequences of upcoming renewable windows over a lookahead
    ``horizon_s``, derived from :class:`~repro.core.traces.SiteTrace`
    windows with the same Gaussian ``sigma_s`` noise model the
    :class:`~repro.core.traces.Forecaster` applies to remaining-window
    queries (σ=0 reproduces the oracle view), and
  * per-link brownout *outage* forecasts derived from a
    :class:`~repro.core.wan.WanTopology` calendar — brownout calendars are
    schedules (grid-operator curtailment notices, maintenance windows), so
    they are forecast exactly, with the degraded capacity attached, and
  * grid-signal forecasts — the run's :class:`~repro.core.signals.
    GridSignals` carbon/price stacks plus demand-response *curtail-request*
    events.  Day-ahead carbon and price schedules are published by grid
    operators, so (like brownout calendars) they are forecast exactly;
    the planning queries (``grid_carbon_g``, ``carbon_grid``,
    ``curtail_frac_grid``) are what lets the ``receding-horizon`` policy
    score multi-window plans in grams instead of grid-seconds.

Window noise is **hash-deterministic**: each (seed, site) pair seeds its
own stream and jitters that site's windows in trace order, so every
consumer — the simulator's per-tick snapshot, ``dryrun --plan``,
``serve --green-route`` — sees the *same* noisy horizon for a given seed
regardless of when or how often it queries.  That is what lets a policy
compose multi-step plans (Pause now, Resume at the forecast window start)
without the plan shifting under it between ticks.

All queries take an explicit sim-time ``t`` and gate visibility at
``t + horizon_s``: the horizon is a sliding lookahead window, not a fixed
batch, so one ``ForecastHorizon`` (built once per run) serves every
snapshot.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultPlan
from repro.core.signals import CurtailRequest, GridSignals

HOUR = 3600.0
DAY = 24 * HOUR

#: Default lookahead: one diurnal cycle (every site sees its next solar
#: window plus the night wind window that may precede it).
DEFAULT_HORIZON_S = DAY


@dataclass(frozen=True, slots=True)
class WindowForecast:
    """A forecast renewable-surplus window (edges carry the sigma noise)."""

    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    def overlap_s(self, t0: float, t1: float) -> float:
        return max(0.0, min(t1, self.end_s) - max(t0, self.start_s))


@dataclass(frozen=True, slots=True)
class OutageForecast:
    """A forecast WAN brownout span.

    ``src == dst == -1`` marks a fabric-scope outage (every link degrades
    at once — the legacy flaky-WAN regime); otherwise the span applies to
    the single directed link ``(src, dst)``.  ``capacity_bps`` is the
    degraded capacity during the span — combine it with the current
    advertised bandwidth via ``min`` (the calendar degrades, never
    upgrades).
    """

    start_s: float
    end_s: float
    src: int = -1
    dst: int = -1
    capacity_bps: float = 0.0

    @property
    def fabric_wide(self) -> bool:
        return self.src < 0

    def affects(self, src: int, dst: int) -> bool:
        return self.fabric_wide or (self.src == src and self.dst == dst)


def _compress_hours(mask_1d: np.ndarray) -> List[Tuple[int, int]]:
    """Runs of consecutive True hours as [h_start, h_end) pairs."""
    runs: List[Tuple[int, int]] = []
    start = None
    for h, bad in enumerate(mask_1d):
        if bad and start is None:
            start = h
        elif not bad and start is not None:
            runs.append((start, h))
            start = None
    if start is not None:
        runs.append((start, len(mask_1d)))
    return runs


@dataclass(frozen=True)
class ForecastHorizon:
    """Sliding-lookahead forecast of renewable windows and WAN outages.

    Built once per run (:meth:`build`) and attached to every snapshot;
    queries take the current sim-time ``t`` and only reveal entries that
    begin before ``t + horizon_s``.
    """

    horizon_s: float
    sigma_s: float
    site_windows: Tuple[Tuple[WindowForecast, ...], ...]
    outages: Tuple[OutageForecast, ...]  # sorted by start_s
    # grid-signal forecasts (carbon/price stacks + curtail-request events);
    # None when the run carries no signals — every signal query then
    # degrades to the zero-signal answer (0 g/kWh, $0, no DR spans)
    signals: Optional[GridSignals] = None
    # realized fault plan (core/faults.py); pre-materialized spans are
    # exactly forecastable, same precedent as WAN brownout calendars.
    # None (every fault-free run) degrades every fault query to the
    # no-fault answer (inf next-start, 0 repair time) at zero cost.
    faults: Optional[FaultPlan] = None

    @property
    def n_sites(self) -> int:
        return len(self.site_windows)

    # -- renewable-window queries -------------------------------------------
    @cached_property
    def _window_starts(self) -> Tuple[List[float], ...]:
        return tuple([w.start_s for w in wins] for wins in self.site_windows)

    def windows(self, site: int, t: float) -> List[WindowForecast]:
        """Forecast windows still relevant at ``t``: end after ``t``, start
        inside the lookahead."""
        limit = t + self.horizon_s
        return [w for w in self.site_windows[site]
                if w.end_s > t and w.start_s < limit]

    def next_window(self, site: int, t: float) -> Optional[WindowForecast]:
        """The current-or-next forecast window at ``t`` (None when nothing
        begins inside the lookahead)."""
        wins = self.site_windows[site]
        i = bisect.bisect_right(self._window_starts[site], t)
        # wins[i-1] may still be open (covers t)
        if i > 0 and wins[i - 1].end_s > t:
            return wins[i - 1]
        if i < len(wins) and wins[i].start_s < t + self.horizon_s:
            return wins[i]
        return None

    def next_window_start_s(self, site: int, t: float) -> float:
        """Forecast start of the next window strictly after ``t`` (inf if
        none inside the lookahead) — the planning analogue of
        ``SiteView.next_window_start_s``."""
        wins = self.site_windows[site]
        i = bisect.bisect_right(self._window_starts[site], t)
        if i < len(wins) and wins[i].start_s < t + self.horizon_s:
            return wins[i].start_s
        return float("inf")

    def active(self, site: int, t: float) -> bool:
        w = self.next_window(site, t)
        return w is not None and w.start_s <= t

    def green_seconds(self, site: int, t0: float, t1: float) -> float:
        """Forecast renewable seconds overlapping [t0, t1] (t1 capped at
        the lookahead)."""
        t1 = min(t1, t0 + self.horizon_s)
        return sum(w.overlap_s(t0, t1) for w in self.site_windows[site]
                   if w.end_s > t0 and w.start_s < t1)

    # -- grid-signal queries -------------------------------------------------
    #
    # Signals are exact (day-ahead schedules, like brownout calendars);
    # the integrals extend past ``t + horizon_s`` by the stacks' constant
    # extrapolation, but renewable-window *credit* against them is gated
    # at the lookahead like every other window query — beyond the horizon
    # a plan must assume grid power.

    def carbon_value(self, site: int, t: float) -> float:
        """Forecast carbon intensity (gCO2/kWh) at ``t`` (0 w/o signals)."""
        sig = self.signals
        return sig.carbon.value(site, t) if sig is not None else 0.0

    def carbon_grid(self, t: float) -> np.ndarray:
        """(n_sites,) batched :meth:`carbon_value` (read-only view)."""
        sig = self.signals
        if sig is not None:
            return sig.carbon.value_grid(t)
        return np.zeros(self.n_sites)

    def price_value(self, site: int, t: float) -> float:
        """Forecast grid price ($/kWh) at ``t`` (0 w/o signals)."""
        sig = self.signals
        return sig.price.value(site, t) if sig is not None else 0.0

    def price_grid(self, t: float) -> np.ndarray:
        sig = self.signals
        if sig is not None:
            return sig.price.value_grid(t)
        return np.zeros(self.n_sites)

    def carbon_integral(self, site: int, t0: float, t1: float) -> float:
        """``∫ carbon dt`` over the whole span (grams·s/kWh·s — multiply
        by kW/3600 for grams); the transfer-leg cost term (transfer power
        is billed entirely to grid)."""
        sig = self.signals
        return sig.carbon.integral(site, t0, t1) if sig is not None else 0.0

    def price_integral(self, site: int, t0: float, t1: float) -> float:
        """``∫ price dt`` over the whole span — the transfer-leg $ term
        (no renewable credit: transfer power is billed entirely to grid)."""
        sig = self.signals
        return sig.price.integral(site, t0, t1) if sig is not None else 0.0

    def _grid_signal_integral(self, stack, site: int, t0: float,
                              t1: float) -> float:
        """``∫ signal dt`` over the forecast NON-renewable portion of
        ``[t0, t1]``: the total integral minus the overlap with forecast
        windows, window credit gated at ``t0 + horizon_s``."""
        if t1 <= t0:
            return 0.0
        tot = stack.integral(site, t0, t1)
        limit = min(t1, t0 + self.horizon_s)
        for w in self.site_windows[site]:
            if w.end_s > t0 and w.start_s < limit:
                tot -= stack.integral(site, max(t0, w.start_s),
                                      min(limit, w.end_s))
        return tot

    def grid_carbon_g(self, site: int, t0: float, t1: float,
                      p_kw: float) -> float:
        """Forecast gCO2 of drawing ``p_kw`` at ``site`` over ``[t0, t1]``
        with renewable windows covering their overlap for free — the
        planning analogue of the simulator's per-span accounting.  With no
        signals, degrades to ``p_kw``-weighted *grid seconds* (constant
        carbon 1), so signal-free plans still minimize grid time."""
        sig = self.signals
        if sig is None:
            green = self.green_seconds(site, t0, t1)
            return p_kw / HOUR * max(0.0, (t1 - t0) - green)
        return p_kw / HOUR * self._grid_signal_integral(
            sig.carbon, site, t0, t1)

    def grid_price_usd(self, site: int, t0: float, t1: float,
                       p_kw: float) -> float:
        """Forecast $ cost of drawing ``p_kw`` at ``site`` over
        ``[t0, t1]`` net of renewable-window overlap (0 w/o signals)."""
        sig = self.signals
        if sig is None:
            return 0.0
        return p_kw / HOUR * self._grid_signal_integral(
            sig.price, site, t0, t1)

    def battery_cover_g(self, site: int, t0: float, t1: float, p_kw: float,
                        soc_kwh: float, batt) -> float:
        """Forecast gCO2 a battery with ``soc_kwh`` of charge could shave
        off :meth:`grid_carbon_g` for the same span: the grid carbon
        scaled by the fraction of the span's dark energy the battery can
        deliver (bounded by its discharge-rate budget and state of
        charge).  ``batt`` is a :class:`~repro.core.ledger.BatteryConfig`
        (untyped to keep forecast ledger-free); 0 without one.

        A planning *estimate*, deliberately simpler than the ledger's
        posting-time discharge gates — it assumes charge available now
        stays available for this span, which receding-horizon's
        branch-relative comparisons tolerate."""
        if batt is None or soc_kwh <= 0.0:
            return 0.0
        g = self.grid_carbon_g(site, t0, t1, p_kw)
        if g <= 0.0:
            return 0.0
        green = self.green_seconds(site, t0, t1)
        dark = max(0.0, (t1 - t0) - green)
        need = p_kw * dark / HOUR
        if need <= 0.0:
            return 0.0
        avail = min(soc_kwh, batt.max_discharge_kw * dark / HOUR)
        return g * min(1.0, avail / need)

    # -- batched planning-cost rows ------------------------------------------
    #
    # Elementwise mirrors of the scalar cost queries over broadcastable
    # ``(site, t0, t1)`` arrays — the receding-horizon planner's
    # whole-grid branch-cost tensors.  Every mirror repeats the scalar's
    # float operations in the scalar's order (window credits subtract
    # sequentially in window order; masked lanes evaluate on dummy
    # arguments and are then where-masked), so each lane is bit-identical
    # to the corresponding scalar call — the property the
    # action-for-action parity oracle (``decide_scalar``) checks.

    def carbon_integral_rows(self, sites, t0s, t1s) -> np.ndarray:
        """Elementwise :meth:`carbon_integral` (whole-span, no window
        credit — the transfer-leg term)."""
        sig = self.signals
        if sig is None:
            return np.zeros(np.broadcast(
                np.asarray(sites), np.asarray(t0s), np.asarray(t1s)).shape)
        return sig.carbon.integral_rows(sites, t0s, t1s)

    def price_integral_rows(self, sites, t0s, t1s) -> np.ndarray:
        """Elementwise :meth:`price_integral`."""
        sig = self.signals
        if sig is None:
            return np.zeros(np.broadcast(
                np.asarray(sites), np.asarray(t0s), np.asarray(t1s)).shape)
        return sig.price.integral_rows(sites, t0s, t1s)

    def _signal_integral_rows(self, stack, sites, t0s, t1s) -> np.ndarray:
        """Elementwise :meth:`_grid_signal_integral`.  Window credit
        subtracts per window column *sequentially* (``tot - credit_j`` in
        window order) because float subtraction is not associative and
        the scalar subtracts one window at a time; non-qualifying lanes
        subtract exactly ``0.0`` (a bit-exact identity)."""
        sites = np.asarray(sites)
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        sites, t0s, t1s = np.broadcast_arrays(sites, t0s, t1s)
        tot = stack.integral_rows(sites, t0s, t1s)
        limit = np.minimum(t1s, t0s + self.horizon_s)
        starts, ends = self._window_mats
        wsr = starts[sites]
        wer = ends[sites]
        qual = (wer > t0s[..., None]) & (wsr < limit[..., None])
        for j in range(wsr.shape[-1]):
            qj = qual[..., j]
            if not qj.any():
                continue
            a = np.where(qj, np.maximum(t0s, wsr[..., j]), t0s)
            b = np.where(qj, np.minimum(limit, wer[..., j]), t0s)
            tot = tot - np.where(qj, stack.integral_rows(sites, a, b), 0.0)
        return np.where(t1s <= t0s, 0.0, tot)

    def _green_seconds_rows(self, sites, t0s, t1s) -> np.ndarray:
        """Elementwise :meth:`green_seconds` (overlaps accumulate in
        window order, like the scalar's ``sum``)."""
        sites = np.asarray(sites)
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        sites, t0s, t1s = np.broadcast_arrays(sites, t0s, t1s)
        t1c = np.minimum(t1s, t0s + self.horizon_s)
        starts, ends = self._window_mats
        wsr = starts[sites]
        wer = ends[sites]
        qual = (wer > t0s[..., None]) & (wsr < t1c[..., None])
        tot = np.zeros(t0s.shape)
        for j in range(wsr.shape[-1]):
            qj = qual[..., j]
            if not qj.any():
                continue
            ov = np.maximum(0.0, np.minimum(t1c, wer[..., j])
                            - np.maximum(t0s, wsr[..., j]))
            tot = tot + np.where(qj, ov, 0.0)
        return tot

    def grid_carbon_g_rows(self, sites, t0s, t1s, p_kw: float) -> np.ndarray:
        """Elementwise :meth:`grid_carbon_g`."""
        sig = self.signals
        if sig is None:
            sites = np.asarray(sites)
            t0s = np.asarray(t0s, dtype=np.float64)
            t1s = np.asarray(t1s, dtype=np.float64)
            sites, t0s, t1s = np.broadcast_arrays(sites, t0s, t1s)
            green = self._green_seconds_rows(sites, t0s, t1s)
            return p_kw / HOUR * np.maximum(0.0, (t1s - t0s) - green)
        return p_kw / HOUR * self._signal_integral_rows(
            sig.carbon, sites, t0s, t1s)

    def grid_price_usd_rows(self, sites, t0s, t1s, p_kw: float) -> np.ndarray:
        """Elementwise :meth:`grid_price_usd`."""
        sig = self.signals
        if sig is None:
            return np.zeros(np.broadcast(
                np.asarray(sites), np.asarray(t0s), np.asarray(t1s)).shape)
        return p_kw / HOUR * self._signal_integral_rows(
            sig.price, sites, t0s, t1s)

    def battery_cover_g_rows(self, sites, t0s, t1s, p_kw: float,
                             soc_kwh, batt) -> np.ndarray:
        """Elementwise :meth:`battery_cover_g` (``soc_kwh`` broadcasts
        with the span arrays; lanes repeat the scalar's float ops)."""
        sites = np.asarray(sites)
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        soc = np.asarray(soc_kwh, dtype=np.float64)
        sites, t0s, t1s, soc = np.broadcast_arrays(sites, t0s, t1s, soc)
        if batt is None:
            return np.zeros(sites.shape)
        g = self.grid_carbon_g_rows(sites, t0s, t1s, p_kw)
        green = self._green_seconds_rows(sites, t0s, t1s)
        dark = np.maximum(0.0, (t1s - t0s) - green)
        need = p_kw * dark / HOUR
        avail = np.minimum(soc, batt.max_discharge_kw * dark / HOUR)
        with np.errstate(divide="ignore", invalid="ignore"):
            frac = np.where(need > 0.0, avail / need, 0.0)
        out = g * np.minimum(1.0, frac)
        return np.where((soc > 0.0) & (g > 0.0) & (need > 0.0), out, 0.0)

    # -- demand-response curtail requests ------------------------------------
    @cached_property
    def _site_curtails(self) -> Tuple[Tuple[CurtailRequest, ...], ...]:
        by: List[List[CurtailRequest]] = [[] for _ in range(self.n_sites)]
        if self.signals is not None:
            for c in self.signals.curtailments:
                if 0 <= c.site < self.n_sites:
                    by[c.site].append(c)
        return tuple(tuple(sorted(v, key=lambda c: c.start_s)) for v in by)

    def active_curtail(self, site: int, t: float) -> Optional[CurtailRequest]:
        """The demand-response request covering ``t`` at ``site`` (None
        when the operator is not asking for load shed right now)."""
        for c in self._site_curtails[site]:
            if c.start_s <= t < c.end_s:
                return c
            if c.start_s > t:
                break
        return None

    def curtail_frac_grid(self, t: float) -> np.ndarray:
        """(n_sites,) requested power cap at ``t`` (1.0 where no active
        curtail request) — the batched :meth:`active_curtail`.  Cached per
        curtail-edge epoch; treat as read-only."""
        def compute():
            out = np.ones(self.n_sites)
            for s, cs in enumerate(self._site_curtails):
                for c in cs:
                    if c.start_s <= t < c.end_s:
                        out[s] = c.power_frac
                        break
                    if c.start_s > t:
                        break
            return out

        key = ("cf", bisect.bisect_right(self._curtail_edges, t))
        return self._cached_grid(key, compute)

    @cached_property
    def _curtail_edges(self) -> List[float]:
        return sorted({e for cs in self._site_curtails for c in cs
                       for e in (c.start_s, c.end_s)})

    def next_curtail_start_s(self, site: int, t: float) -> float:
        """First curtail-request start strictly after ``t`` at ``site``
        (inf when none inside the lookahead)."""
        limit = t + self.horizon_s
        for c in self._site_curtails[site]:
            if c.start_s > t:
                return c.start_s if c.start_s < limit else float("inf")
        return float("inf")

    # -- WAN outage queries --------------------------------------------------
    @cached_property
    def _link_outages(self) -> Dict[Tuple[int, int], Tuple[OutageForecast, ...]]:
        by: Dict[Tuple[int, int], List[OutageForecast]] = {}
        for o in self.outages:
            by.setdefault((o.src, o.dst), []).append(o)
        return {k: tuple(v) for k, v in by.items()}

    @cached_property
    def _merged_outage_cache(self) -> Dict[Tuple[int, int], Tuple[OutageForecast, ...]]:
        return {}

    def _outages_for(self, src: int, dst: int) -> Tuple[OutageForecast, ...]:
        """Fabric + per-link outages affecting (src, dst), start-sorted.
        Merged once per link and cached — plan-ahead queries every
        (candidate, destination) pair every tick."""
        key = (src, dst)
        got = self._merged_outage_cache.get(key)
        if got is None:
            got = tuple(sorted(
                (*self._link_outages.get((-1, -1), ()),
                 *self._link_outages.get(key, ())),
                key=lambda o: o.start_s))
            self._merged_outage_cache[key] = got
        return got

    def next_outage(self, src: int, dst: int, t: float) -> Optional[OutageForecast]:
        """The first forecast outage affecting link (src, dst) that is
        still open at / begins after ``t``, inside the lookahead."""
        limit = t + self.horizon_s
        for o in self._outages_for(src, dst):
            if o.end_s > t and o.start_s < limit:
                return o
        return None

    def next_outage_start_s(self, src: int, dst: int, t: float) -> float:
        o = self.next_outage(src, dst, t)
        return o.start_s if o is not None else float("inf")

    def next_outage_start_after(self, src: int, dst: int, t: float) -> float:
        """First forecast outage START strictly after ``t`` on (src, dst)
        (inf if none inside the lookahead).  Unlike :meth:`next_outage`,
        an outage already in progress does not mask a later one — this is
        the query arrival checks need: "does anything begin while my
        transfer is still in flight?"."""
        limit = t + self.horizon_s
        for o in self._outages_for(src, dst):
            if o.start_s > t:
                return o.start_s if o.start_s < limit else float("inf")
        return float("inf")

    def next_uplink_outage_start_s(self, src: int, t: float) -> float:
        """Earliest forecast outage start affecting ANY link out of
        ``src`` (inf if none inside the lookahead) — the evacuation
        trigger: after this instant the site's checkpoints may no longer
        drain at full rate."""
        limit = t + self.horizon_s
        best = float("inf")
        for (s, _d), outs in self._link_outages.items():
            if s != -1 and s != src:
                continue
            for o in outs:
                if o.end_s > t and o.start_s < limit:
                    best = min(best, max(o.start_s, t))
                    break
        return best

    # -- batched grids (one numpy pass instead of n^2 scalar queries) --------
    @cached_property
    def _window_mats(self) -> Tuple[np.ndarray, np.ndarray]:
        """Padded (n_sites, Kw) window start/end matrices (+inf padded; Kw
        = max window count + 1 so searchsorted indices always gather)."""
        k = max((len(w) for w in self.site_windows), default=0) + 1
        n = self.n_sites
        starts = np.full((n, k), np.inf)
        ends = np.full((n, k), np.inf)
        for i, wins in enumerate(self.site_windows):
            for j, w in enumerate(wins):
                starts[i, j] = w.start_s
                ends[i, j] = w.end_s
        return starts, ends

    @cached_property
    def _outage_mats(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Padded (n, n, Ko) per-link merged-outage start/end/capacity
        matrices (fabric spans folded into every link, start-sorted — the
        array form of :meth:`_outages_for`).  Pads: start=+inf, end=-inf,
        cap=+inf."""
        n = self.n_sites
        k = 1
        per_link = {}
        for s in range(n):
            for d in range(n):
                outs = self._outages_for(s, d)
                per_link[(s, d)] = outs
                k = max(k, len(outs) + 1)
        starts = np.full((n, n, k), np.inf)
        ends = np.full((n, n, k), -np.inf)
        caps = np.full((n, n, k), np.inf)
        for (s, d), outs in per_link.items():
            for j, o in enumerate(outs):
                starts[s, d, j] = o.start_s
                ends[s, d, j] = o.end_s
                caps[s, d, j] = o.capacity_bps
        return starts, ends, caps

    # The grids below cache only quantities that are piecewise-constant in
    # ``t`` between breakpoints, and apply every comparison that involves
    # the live ``t`` (window-still-open checks, the ``t + horizon_s``
    # reveal limit) per call on the cached gathers — like
    # ``TraceStack.point``.  Caching comparison *results* would be wrong
    # at the breakpoints themselves: a predicate like
    # ``start < t + horizon`` is False exactly at ``t = start - horizon``
    # but True just after, so a value computed at the edge must not be
    # reused for the epoch's interior (orchestrator ticks land exactly on
    # hour-aligned edges all the time).
    @cached_property
    def _grid_cache(self) -> dict:
        return {}

    @staticmethod
    def _breaks(*arrays: np.ndarray) -> List[float]:
        vals = np.unique(np.concatenate([np.asarray(a).ravel()
                                         for a in arrays]))
        return [float(v) for v in vals if np.isfinite(v)]

    @cached_property
    def _outage_end_breaks(self) -> List[float]:
        _, ends, _ = self._outage_mats
        return self._breaks(ends)

    @cached_property
    def _outage_reveal_breaks(self) -> List[float]:
        starts, _, _ = self._outage_mats
        return self._breaks(starts - self.horizon_s)

    @cached_property
    def _outage_start_breaks(self) -> List[float]:
        starts, _, _ = self._outage_mats
        return self._breaks(starts)

    @cached_property
    def _window_start_breaks(self) -> List[float]:
        starts, _ = self._window_mats
        return self._breaks(starts)

    def _cached_grid(self, key: tuple, compute):
        got = self._grid_cache.get(key)
        if got is None:
            got = self._grid_cache[key] = compute()
        return got

    def next_outage_grid(self, t: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(start, end, capacity) ``(n, n)`` grids of the first forecast
        outage per link still open at / beginning after ``t`` inside the
        lookahead — the batched :meth:`next_outage` (start=+inf, cap=+inf
        where there is none).  Treat the returned arrays as read-only
        (cached per breakpoint epoch).

        The qualifying mask mixes two edge semantics: expiry flips
        (``end > t``) become False *at* the edge (``bisect_right``
        epochs), reveal flips (``start < t + horizon``) become True just
        *after* theirs (``bisect_left`` epochs) — the cache key combines
        both, so every ``t`` sharing a key evaluates to the same mask."""
        def compute():
            starts, ends, caps = self._outage_mats
            qual = (ends > t) & (starts < t + self.horizon_s)
            first = qual.argmax(axis=2)[:, :, None]
            any_ = np.take_along_axis(qual, first, axis=2)[:, :, 0]
            o_start = np.where(
                any_, np.take_along_axis(starts, first, axis=2)[:, :, 0],
                np.inf)
            o_end = np.where(
                any_, np.take_along_axis(ends, first, axis=2)[:, :, 0],
                np.inf)
            o_cap = np.where(
                any_, np.take_along_axis(caps, first, axis=2)[:, :, 0],
                np.inf)
            return o_start, o_end, o_cap

        key = ("no", bisect.bisect_right(self._outage_end_breaks, t),
               bisect.bisect_left(self._outage_reveal_breaks, t))
        return self._cached_grid(key, compute)

    def next_outage_start_after_grid(self, t: float) -> np.ndarray:
        """(n, n) grid of the first outage START strictly after ``t`` per
        link (inf when none inside the lookahead) — the batched
        :meth:`next_outage_start_after`.  Read-only; the reveal limit is
        applied with the live ``t``."""
        def compute():
            starts, _, _ = self._outage_mats
            after = np.where(starts > t, starts, np.inf)
            return after.min(axis=2)

        # ``starts > t`` flips False at the start itself: bisect_right
        first = self._cached_grid(
            ("na", bisect.bisect_right(self._outage_start_breaks, t)),
            compute)
        return np.where(first < t + self.horizon_s, first, np.inf)

    def next_uplink_outage_grid(self, t: float) -> np.ndarray:
        """(n_sites,) batched :meth:`next_uplink_outage_start_s`: earliest
        forecast outage start affecting any link out of each site.  (The
        clamp uses the live ``t`` — an outage already open clamps to
        ``t``.)"""
        o_start, _, _ = self.next_outage_grid(t)
        return np.maximum(o_start, t).min(axis=1)

    def next_window_start_grid(self, t: float) -> np.ndarray:
        """(n_sites,) batched :meth:`next_window_start_s`.  Read-only;
        the reveal limit is applied with the live ``t``."""
        def compute():
            starts, _ = self._window_mats
            j = (starts <= t).sum(axis=1)
            return starts[np.arange(self.n_sites), j]

        # ``starts <= t`` flips True at the start itself: bisect_right
        nxt = self._cached_grid(
            ("nw", bisect.bisect_right(self._window_start_breaks, t)),
            compute)
        return np.where(nxt < t + self.horizon_s, nxt, np.inf)

    def window_open_or_next_start_grid(self, t: float) -> np.ndarray:
        """(n_sites,) start of the current-or-next forecast window — the
        batched ``next_window(site, t).start_s`` (+inf when
        :meth:`next_window` would return None).  Read-only; the
        still-open and reveal checks use the live ``t``."""
        def compute():
            starts, ends = self._window_mats
            r = np.arange(self.n_sites)
            j = (starts <= t).sum(axis=1)
            jm = np.maximum(j - 1, 0)
            return j > 0, starts[r, jm], ends[r, jm], starts[r, j]

        has_prev, prev_start, prev_end, nxt = self._cached_grid(
            ("cn", bisect.bisect_right(self._window_start_breaks, t)),
            compute)
        open_ = has_prev & (prev_end > t)
        return np.where(open_, prev_start,
                        np.where(nxt < t + self.horizon_s, nxt, np.inf))

    def capacity_floor_bps(self, src: int, dst: int, t0: float, t1: float) -> float:
        """Minimum forecast degraded capacity on (src, dst) over [t0, t1]
        (inf when no outage overlaps — i.e. the calendar forecasts no
        degradation; combine with the advertised bandwidth via min)."""
        t1 = min(t1, t0 + self.horizon_s)
        floor = float("inf")
        for o in self._outages_for(src, dst):
            if o.end_s > t0 and o.start_s < t1:
                floor = min(floor, o.capacity_bps)
        return floor

    # -- fault-plan queries (core/faults.py) ---------------------------------
    # A realized FaultPlan is pre-materialized data, so (like brownout
    # calendars) it is forecast exactly.  Next-start queries gate at the
    # same ``t + horizon_s`` reveal limit as outage queries; repair-time
    # queries describe an outage already in progress, so no limit applies.
    def next_fault_start_after(self, src: int, dst: int, t: float) -> float:
        """First hard-fault START strictly after ``t`` that would kill
        link (src, dst) — a blackout at either endpoint or a hard link
        failure (inf when no plan / none inside the lookahead).  The
        fault analogue of :meth:`next_outage_start_after`."""
        if self.faults is None:
            return float("inf")
        s = self.faults.next_fault_start_after(src, dst, t)
        return s if s < t + self.horizon_s else float("inf")

    def next_fault_start_grid(self, t: float) -> Optional[np.ndarray]:
        """(n, n) batched :meth:`next_fault_start_after` (None when no
        plan — callers skip the masking pass entirely; inf diagonal)."""
        if self.faults is None:
            return None
        g = self.faults.next_fault_start_grid(t)
        return np.where(g < t + self.horizon_s, g, np.inf)

    def site_repair_s(self, site: int, t: float) -> float:
        """Remaining blackout time at ``site`` (0 when the site is up) —
        the repair-time estimate fault-aware policies weigh against a
        destination's queue."""
        if self.faults is None:
            return 0.0
        return self.faults.repair_time_s(site, t)

    def site_repair_grid(self, t: float) -> Optional[np.ndarray]:
        """(n_sites,) batched :meth:`site_repair_s` (None when no plan)."""
        if self.faults is None:
            return None
        return self.faults.repair_time_vec(t)

    # -- builder -------------------------------------------------------------
    @classmethod
    def build(
        cls,
        traces: Sequence,
        *,
        wan=None,
        signals: Optional[GridSignals] = None,
        horizon_s: float = DEFAULT_HORIZON_S,
        sigma_s: float = 0.0,
        seed: int = 0,
        faults: Optional[FaultPlan] = None,
    ) -> "ForecastHorizon":
        """Materialize the forecast from site traces (+ optionally a
        :class:`~repro.core.wan.WanTopology` brownout calendar and the
        run's :class:`~repro.core.signals.GridSignals` — signal forecasts
        are exact day-ahead schedules, attached as-is).

        Window edges get i.i.d. Gaussian jitter N(0, sigma_s²) from a
        per-(seed, site) stream drawn in trace order — deterministic and
        query-order-independent.  Windows whose noisy duration collapses
        below 60 s are dropped (the forecaster "missed" them), and
        windows the jitter pushed into overlap are merged — the query
        surface (bisect coverage in :meth:`next_window`, the overlap sum
        in :meth:`green_seconds`) assumes disjoint windows.  Outage spans
        are exact (calendars are schedules); the per-span
        ``capacity_bps`` is the calendar's degraded rate.
        """
        site_windows: List[Tuple[WindowForecast, ...]] = []
        for s, tr in enumerate(traces):
            rng = np.random.default_rng([seed, 97, s]) if sigma_s > 0 else None
            noisy: List[Tuple[float, float]] = []
            for w in tr.windows:
                if rng is not None:
                    ds, de = rng.normal(0.0, sigma_s, 2)
                else:
                    ds = de = 0.0
                a, b = max(0.0, w.start_s + ds), w.end_s + de
                if b - a >= 60.0:
                    noisy.append((a, b))
            noisy.sort()
            merged: List[List[float]] = []
            for a, b in noisy:
                if merged and a <= merged[-1][1]:
                    merged[-1][1] = max(merged[-1][1], b)
                else:
                    merged.append([a, b])
            site_windows.append(tuple(WindowForecast(a, b)
                                      for a, b in merged))

        outages: List[OutageForecast] = []
        mask = getattr(wan, "brownout_mask", None)
        if mask is not None:
            degraded = wan.degraded_bps
            if mask.ndim == 1:  # fabric scope
                for h0, h1 in _compress_hours(mask):
                    outages.append(OutageForecast(
                        h0 * HOUR, h1 * HOUR, -1, -1, degraded))
            else:  # per-link scope: (n_hours, n, n)
                n = mask.shape[1]
                for src in range(n):
                    for dst in range(n):
                        if src == dst or not mask[:, src, dst].any():
                            continue
                        cap = float(min(degraded, wan.link_bps[src, dst]))
                        for h0, h1 in _compress_hours(mask[:, src, dst]):
                            outages.append(OutageForecast(
                                h0 * HOUR, h1 * HOUR, src, dst, cap))
        outages.sort(key=lambda o: (o.start_s, o.src, o.dst))
        return cls(horizon_s=float(horizon_s), sigma_s=float(sigma_s),
                   site_windows=tuple(site_windows), outages=tuple(outages),
                   signals=signals, faults=faults)


__all__ = [
    "DEFAULT_HORIZON_S", "CurtailRequest", "ForecastHorizon",
    "OutageForecast", "WindowForecast",
]
