"""ClusterState: the one snapshot type every control surface shares.

``ClusterSimulator`` (per orchestrator tick), the ``launch.dryrun`` plan
preview and the ``launch.serve`` green router all build their view of the
cluster through :meth:`ClusterState.build` instead of hand-rolling context
objects.  The snapshot is immutable; policies read it and return typed
:mod:`repro.core.actions`.

The advertised bandwidth matrix is derived from the *same* per-NIC share
counts the simulator's transfer loop uses (``min(nic/src_flows,
nic/dst_flows)`` per link with the *current* in-flight flows), so the
policy's view agrees with what the transfer loop is granting right now —
the seed implementation halved rows/columns once per in-flight transfer,
under-advertising a doubly-loaded uplink as bw/4 when the transfer loop
actually grants bw/2. Note the advertisement is of current shares, not the
post-admission share a new transfer would dilute to (nic/(flows+1)); the
alpha safety margin in Algorithm 1 absorbs that optimism.  Callers that
cannot lean on alpha — admission checks in ``serve --green-route`` and
``dryrun --plan``, and the ``plan-ahead`` policy's arrival estimates —
use :meth:`ClusterState.post_admission_bps` instead, which includes the
new flow in the share counts.

The snapshot also carries ``state.forecast`` — a
:class:`~repro.core.forecast.ForecastHorizon` with the per-site upcoming
renewable windows and per-link WAN outage forecasts — built by
:meth:`ClusterState.build` whenever the caller passes its traces (the
simulator reuses one prebuilt horizon across ticks).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import (
    Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union,
)

import numpy as np

from repro.core import feasibility as fz
from repro.core.forecast import DEFAULT_HORIZON_S, ForecastHorizon
from repro.core.wan import WanTopology


@dataclass(slots=True)
class JobView:
    """Policy-visible job facts (checkpoint size is the *measured* bytes)."""

    jid: int
    site: int
    ckpt_bytes: float
    remaining_compute_s: float
    t_load_s: float = fz.T_LOAD_S
    state: str = "running"  # queued|running|paused
    eligible: bool = True  # migration cooldown has elapsed
    power_frac: float = 1.0  # current Throttle level
    # Defer hold: the job is not schedulable before this sim-time.  Policies
    # MUST consult it before re-issuing Defer — a held job re-deferred every
    # tick is pure action noise (one Defer per (job, window)).
    defer_until_s: float = -1e18

    def held(self, t: float) -> bool:
        """Whether a Defer hold is still active at sim-time ``t``."""
        return self.defer_until_s > t


# JobSoA state codes (order matters: queued < running < paused mirrors the
# snapshot's bucket walk; names map 1:1 onto JobView.state strings)
STATE_QUEUED, STATE_RUNNING, STATE_PAUSED = 0, 1, 2
_STATE_NAMES = ("queued", "running", "paused")
_STATE_CODES = {n: c for c, n in enumerate(_STATE_NAMES)}


@dataclass(frozen=True, eq=False)
class JobSoA:
    """Structure-of-arrays view of every live job, jid-sorted.

    The vectorized policy kernels read these columns directly; the
    ``JobView`` tuple is materialized from them lazily only when a scalar
    consumer (the parity oracles, tests, examples) touches ``state.jobs``.
    All arrays share length ``m`` (live job count).
    """

    jids: np.ndarray  # (m,) int64 (jid-sorted on the simulator path)
    site: np.ndarray  # (m,) int64
    ckpt_bytes: np.ndarray  # (m,) float64
    remaining_s: np.ndarray  # (m,) float64 remaining compute
    t_load_s: np.ndarray  # (m,) float64
    state: np.ndarray  # (m,) int8: STATE_QUEUED/RUNNING/PAUSED
    eligible: np.ndarray  # (m,) bool (migration cooldown elapsed)
    power_frac: np.ndarray  # (m,) float64
    defer_until_s: np.ndarray  # (m,) float64
    # per-state counts (zero-op emptiness checks for the policy kernels;
    # -1 = unknown, derive from `state`)
    n_queued: int = -1
    n_running: int = -1
    n_paused: int = -1

    def __len__(self) -> int:
        return len(self.jids)

    def count(self, code: int) -> int:
        n = (self.n_queued, self.n_running, self.n_paused)[code]
        if n < 0:
            n = int((self.state == code).sum())
        return n

    @classmethod
    def from_views(cls, views: Sequence["JobView"]) -> "JobSoA":
        """Column-ize ``views`` preserving their order (the scalar decide
        paths iterate ``state.jobs`` in snapshot order; parity between the
        vectorized and scalar kernels needs the same order here)."""
        return cls(
            jids=np.array([v.jid for v in views], dtype=np.int64),
            site=np.array([v.site for v in views], dtype=np.int64),
            ckpt_bytes=np.array([v.ckpt_bytes for v in views]),
            remaining_s=np.array([v.remaining_compute_s for v in views]),
            t_load_s=np.array([v.t_load_s for v in views]),
            state=np.array([_STATE_CODES[v.state] for v in views],
                           dtype=np.int8),
            eligible=np.array([v.eligible for v in views], dtype=bool),
            power_frac=np.array([v.power_frac for v in views]),
            defer_until_s=np.array([v.defer_until_s for v in views]),
        )

    def views(self) -> Tuple["JobView", ...]:
        return tuple(
            JobView(int(j), int(s), float(cb), float(r), float(tl),
                    state=_STATE_NAMES[st], eligible=bool(el),
                    power_frac=float(pf), defer_until_s=float(du))
            for j, s, cb, r, tl, st, el, pf, du in zip(
                self.jids, self.site, self.ckpt_bytes, self.remaining_s,
                self.t_load_s, self.state, self.eligible, self.power_frac,
                self.defer_until_s))


@dataclass(slots=True)
class SiteView:
    sid: int
    slots: int
    busy: int  # running jobs
    queued: int
    renewable_active: bool
    window_remaining_s: float  # forecast
    incoming: int = 0  # in-flight migrations committed to this site
    next_window_start_s: float = float("inf")  # start of the next window

    @property
    def load(self) -> float:
        return (self.busy + self.queued + self.incoming) / max(self.slots, 1)

    @property
    def free_slots(self) -> int:
        return max(0, self.slots - self.busy - self.incoming)


@dataclass(frozen=True, eq=False)
class ClusterState:
    """Immutable cluster snapshot handed to ``Policy.decide``.

    ``jobs`` holds every live (queued/running/paused) job; policies that only
    migrate should iterate :meth:`migratable`, which reproduces the classic
    "running jobs whose cooldown elapsed" view.

    Job facts live in one of two primary representations and the other is
    materialized lazily on first access: the array-of-structs ``JobView``
    tuple (:meth:`build`, the test/dryrun/serve path) or the
    structure-of-arrays :class:`JobSoA` (:meth:`build_soa`, the simulator's
    per-tick path — the vectorized policy kernels consume ``state.soa``
    without ever constructing per-job objects).  Vectorized numpy views
    over jobs and sites are likewise lazy and cached.
    """

    t: float
    bandwidth_bps: np.ndarray  # (n_sites, n_sites) advertised effective bw
    # the topology the matrix was derived from (None when an explicit
    # matrix or the legacy uniform nic_bps path was used)
    wan: Optional["WanTopology"] = None
    # the in-flight (src, dst) flow set the matrix was derived under —
    # what post_admission_bps dilutes against
    transfers: Tuple[Tuple[int, int], ...] = ()
    # the uniform NIC rate when the legacy nic_bps path built the matrix
    # (None on the wan / explicit-matrix paths)
    nic_bps: Optional[float] = None
    # lookahead forecast (upcoming windows + WAN outages); None when the
    # caller had no traces to forecast from
    forecast: Optional[ForecastHorizon] = None
    # exactly one of these is set by the constructors; the other derives
    jobs_aos: Optional[Tuple[JobView, ...]] = None
    jobs_soa: Optional[JobSoA] = None
    # SiteView tuple, or a zero-arg factory materialized lazily (the
    # simulator's fast path defers SiteView construction to the rare
    # scalar consumers)
    sites_in: Union[Tuple[SiteView, ...], Callable[[], Tuple[SiteView, ...]]] = ()
    # per-site serving-plane summary (replica pools, queue depths); None
    # when the run carries no serving plane.  String-annotated: no
    # runtime import of repro.core.serving (it imports nothing from
    # state, but keeping state serving-free avoids a cycle if routers
    # ever grow state helpers).
    serving: Optional["ServingView"] = None  # noqa: F821
    # the run's per-site BatteryConfig (core/ledger.py), or None when
    # storage is off.  Untyped for the same no-cycle reason as serving;
    # battery-aware policies read it together with site_battery_soc.
    battery: Optional[object] = None

    @cached_property
    def sites(self) -> Tuple[SiteView, ...]:
        if callable(self.sites_in):
            return tuple(self.sites_in())
        return self.sites_in

    @cached_property
    def jobs(self) -> Tuple[JobView, ...]:
        """Live jobs as ``JobView`` objects, jid-sorted (materialized from
        the SoA columns when the snapshot was built via :meth:`build_soa`)."""
        if self.jobs_aos is not None:
            return self.jobs_aos
        return self.jobs_soa.views()

    @cached_property
    def soa(self) -> JobSoA:
        """Live jobs as jid-sorted :class:`JobSoA` columns (derived from
        the ``JobView`` tuple when the snapshot was built via
        :meth:`build`)."""
        if self.jobs_soa is not None:
            return self.jobs_soa
        return JobSoA.from_views(self.jobs_aos)

    def site(self, sid: int) -> SiteView:
        return self.sites[sid]

    def post_admission_bps(
        self, src: int, dst: int,
        flows: Optional[Sequence[Tuple[int, int]]] = None,
    ) -> float:
        """The rate a NEW ``src -> dst`` transfer would be granted, with
        the new flow included in the share counts (``flows+1`` dilution).
        ``bandwidth_bps`` advertises *current* grants and is systematically
        optimistic for exactly this query; admission checks belong here.

        ``flows`` overrides the snapshot's in-flight set — callers that
        admit several transfers in one pass (the serve router, the
        dry-run plan validator, plan-ahead's per-tick migrations) thread
        their growing list through so each admission sees the dilution of
        the ones before it."""
        if flows is None:
            flows = self.transfers
        if self.wan is not None:
            return self.wan.post_admission_rate(src, dst, flows, self.t)
        # legacy uniform-NIC fallback: use the recorded NIC rate (the
        # matrix maximum underestimates it whenever every entry is
        # diluted by flows) and re-count with the new flow included.
        # Capped by the pair's own advertised entry so an explicit
        # NON-uniform matrix (tests/replay path) never advertises the
        # fabric's fastest link for a slower pair — post-admission can
        # only be at or below the current grant.
        bw = np.asarray(self.bandwidth_bps)
        nic = (self.nic_bps if self.nic_bps is not None
               else float(bw.max()))
        src_n, dst_n = nic_share_counts(flows)
        return min(float(bw[src, dst]),
                   nic / (src_n.get(src, 0) + 1),
                   nic / (dst_n.get(dst, 0) + 1))

    @property
    def n_sites(self) -> int:
        return self.bandwidth_bps.shape[0]

    def migratable(self) -> List[JobView]:
        """Running jobs past their migration cooldown, in jid order."""
        return [j for j in self.jobs if j.state == "running" and j.eligible]

    def running(self) -> List[JobView]:
        return [j for j in self.jobs if j.state == "running"]

    def queued(self) -> List[JobView]:
        return [j for j in self.jobs if j.state == "queued"]

    def paused(self) -> List[JobView]:
        return [j for j in self.jobs if j.state == "paused"]

    # ---- vectorized views (lazy, cached) ----------------------------------
    @cached_property
    def job_sites(self) -> np.ndarray:
        return self.soa.site

    @cached_property
    def job_ckpt_bytes(self) -> np.ndarray:
        return self.soa.ckpt_bytes

    @cached_property
    def job_remaining_s(self) -> np.ndarray:
        return self.soa.remaining_s

    # (the site_* views are seeded directly by ClusterState.build_soa when
    # the caller already holds the arrays — cached_property is a non-data
    # descriptor, so a pre-set instance __dict__ entry wins)
    @cached_property
    def site_window_s(self) -> np.ndarray:
        return np.array([s.window_remaining_s for s in self.sites], dtype=np.float64)

    @cached_property
    def site_renewable(self) -> np.ndarray:
        return np.array([s.renewable_active for s in self.sites], dtype=bool)

    @cached_property
    def site_load(self) -> np.ndarray:
        return np.array([s.load for s in self.sites], dtype=np.float64)

    @cached_property
    def site_free_slots(self) -> np.ndarray:
        return np.array([s.free_slots for s in self.sites], dtype=np.int64)

    @cached_property
    def site_next_window_s(self) -> np.ndarray:
        return np.array([s.next_window_start_s for s in self.sites],
                        dtype=np.float64)

    @cached_property
    def site_slots(self) -> np.ndarray:
        return np.array([s.slots for s in self.sites], dtype=np.int64)

    @cached_property
    def site_busy(self) -> np.ndarray:
        return np.array([s.busy for s in self.sites], dtype=np.int64)

    @cached_property
    def site_bq_load(self) -> np.ndarray:
        """(busy + queued) / max(slots, 1) per site — the reservation-free
        destination-load term of the Algorithm-1 benefit."""
        return np.array(
            [(s.busy + s.queued) / max(s.slots, 1) for s in self.sites],
            dtype=np.float64)

    @cached_property
    def site_bq_raw(self) -> np.ndarray:
        """busy + queued per site (ints) — the un-normalized numerator of
        :attr:`site_bq_load`, for reservation-aware re-scoring (the
        same-tick slot reservations add to this count)."""
        return np.array([s.busy + s.queued for s in self.sites],
                        dtype=np.int64)

    @cached_property
    def site_battery_soc(self) -> np.ndarray:
        """(n_sites,) battery state of charge in kWh at snapshot time
        (zeros when the run carries no storage).  Seeded from the
        simulator's PowerLedger via ``site_arrays``; the default here
        covers snapshots built outside a storage-enabled run."""
        return np.zeros(self.n_sites)

    # ---- fault views (core/faults.py) --------------------------------------
    @cached_property
    def site_up(self) -> np.ndarray:
        """(n_sites,) bool — False while a site is blacked out (all slots
        down, NICs dark).  Seeded from the simulator's FaultPlan via
        ``site_arrays`` only when a fault regime is active; the all-up
        default covers every fault-free run at zero cost."""
        return np.ones(self.n_sites, dtype=bool)

    @cached_property
    def link_up(self) -> np.ndarray:
        """(n_sites, n_sites) bool — False while the src→dst path is down
        to a hard link failure or an endpoint blackout (distinct from the
        *scheduled* brownout calendar, which only degrades capacity).
        Seeded like :attr:`site_up`; all-up default otherwise."""
        return np.ones((self.n_sites, self.n_sites), dtype=bool)

    # ---- grid-signal views (from the forecast's signal stacks) -------------
    @cached_property
    def site_carbon(self) -> np.ndarray:
        """(n_sites,) current carbon intensity (gCO2/kWh); zeros when the
        run carries no signals.  Read-only (epoch-cached stack view)."""
        fc = self.forecast
        if fc is None:
            return np.zeros(self.n_sites)
        return fc.carbon_grid(self.t)

    @cached_property
    def site_price(self) -> np.ndarray:
        """(n_sites,) current grid price ($/kWh); zeros w/o signals."""
        fc = self.forecast
        if fc is None:
            return np.zeros(self.n_sites)
        return fc.price_grid(self.t)

    @cached_property
    def site_curtail_frac(self) -> np.ndarray:
        """(n_sites,) active demand-response power cap (1.0 = no request)."""
        fc = self.forecast
        if fc is None:
            return np.ones(self.n_sites)
        return fc.curtail_frac_grid(self.t)

    @cached_property
    def job_carbon(self) -> np.ndarray:
        """(m,) current carbon intensity at each live job's site — the
        per-job signal column the vectorized decide kernels score against."""
        return self.site_carbon[self.soa.site]

    # ---- the one constructor ----------------------------------------------
    @classmethod
    def build(
        cls,
        t: float,
        jobs: Iterable[JobView],
        sites: Sequence[SiteView],
        *,
        wan: Optional["WanTopology"] = None,
        nic_bps: Optional[float] = None,
        transfers: Sequence[Tuple[int, int]] = (),
        bandwidth_bps: Optional[np.ndarray] = None,
        traces: Optional[Sequence] = None,
        forecast: Optional[ForecastHorizon] = None,
        signals=None,
        forecast_sigma_s: float = 0.0,
        forecast_seed: int = 0,
        forecast_horizon_s: float = DEFAULT_HORIZON_S,
        serving=None,
        battery=None,
    ) -> "ClusterState":
        """Assemble a snapshot.

        Pass a :class:`~repro.core.wan.WanTopology` plus the in-flight
        ``transfers`` as ``(src, dst)`` pairs and the advertised matrix is
        its per-resource fair share under the current flow set; or the
        legacy uniform per-site NIC rate ``nic_bps`` (same share model,
        uncapped links); or an explicit ``bandwidth_bps`` matrix (tests,
        replay).

        The forecast horizon: pass a prebuilt ``forecast`` (the simulator
        builds one per run and reuses it across ticks — window noise is
        hash-deterministic, so rebuilding would give the identical
        object), or the site ``traces`` and one is built here with the
        ``forecast_*`` knobs (the dry-run planner and serve router path).
        With neither, ``state.forecast`` is None and plan-ahead consumers
        degrade to reactive behaviour.
        """
        sites = tuple(sites)
        transfers = tuple(transfers)
        if bandwidth_bps is None:
            if wan is not None:
                bandwidth_bps = wan.advertised_matrix(t, transfers)
            elif nic_bps is not None:
                bandwidth_bps = advertised_bandwidth(len(sites), nic_bps, transfers)
            else:
                raise ValueError(
                    "need wan, nic_bps (with transfers) or bandwidth_bps")
        if forecast is None and traces is not None:
            forecast = ForecastHorizon.build(
                traces, wan=wan, signals=signals,
                horizon_s=forecast_horizon_s,
                sigma_s=forecast_sigma_s, seed=forecast_seed)
        return cls(t=t, jobs_aos=tuple(jobs), sites_in=sites,
                   bandwidth_bps=np.asarray(bandwidth_bps, dtype=np.float64),
                   wan=wan, transfers=transfers, forecast=forecast,
                   nic_bps=nic_bps, serving=serving, battery=battery)

    @classmethod
    def build_soa(
        cls,
        t: float,
        soa: JobSoA,
        sites: Union[Sequence[SiteView], Callable[[], Sequence[SiteView]]],
        *,
        n_sites: Optional[int] = None,
        wan: Optional["WanTopology"] = None,
        nic_bps: Optional[float] = None,
        transfers: Sequence[Tuple[int, int]] = (),
        bandwidth_bps: Optional[np.ndarray] = None,
        forecast: Optional[ForecastHorizon] = None,
        site_arrays: Optional[Dict[str, np.ndarray]] = None,
        serving=None,
        battery=None,
    ) -> "ClusterState":
        """Assemble a snapshot from :class:`JobSoA` columns (the simulator's
        per-tick fast path — no per-job or per-site objects are
        constructed unless a scalar consumer later touches ``state.jobs``
        / ``state.sites``).  ``sites`` may be a zero-arg factory (then
        pass ``n_sites``); bandwidth sources as in :meth:`build`.
        ``site_arrays`` pre-seeds the cached ``site_*`` vector views
        (keys = property names) for callers that already hold them as
        arrays."""
        transfers = tuple(transfers)
        if callable(sites):
            sites_in = sites
            if n_sites is None:
                raise ValueError("a sites factory needs explicit n_sites")
        else:
            sites_in = tuple(sites)
            n_sites = len(sites_in)
        if bandwidth_bps is None:
            if wan is not None:
                bandwidth_bps = wan.advertised_matrix(t, transfers)
            elif nic_bps is not None:
                bandwidth_bps = advertised_bandwidth(
                    n_sites, nic_bps, transfers)
            else:
                raise ValueError(
                    "need wan, nic_bps (with transfers) or bandwidth_bps")
        st = cls(t=t, jobs_soa=soa, sites_in=sites_in,
                 bandwidth_bps=np.asarray(bandwidth_bps, dtype=np.float64),
                 wan=wan, transfers=transfers, forecast=forecast,
                 nic_bps=nic_bps, serving=serving, battery=battery)
        if site_arrays:
            st.__dict__.update(site_arrays)
        return st


def site_views_from_traces(
    traces, t: float, *, slots: int, busy: Optional[Sequence[int]] = None,
    queued: Optional[Sequence[int]] = None,
) -> List[SiteView]:
    """SiteViews for a point-in-time look at a set of traces (no noise, no
    in-flight state) — the assembly shared by the dry-run planner and the
    serve router. The simulator builds richer views itself (forecast noise,
    incoming transfers)."""
    views = []
    for s, tr in enumerate(traces):
        nw = tr.next_window(t)
        views.append(SiteView(
            sid=s,
            slots=slots,
            busy=busy[s] if busy is not None else 0,
            queued=queued[s] if queued is not None else 0,
            renewable_active=tr.active(t),
            window_remaining_s=tr.remaining(t),
            next_window_start_s=nw.start_s if nw else float("inf"),
        ))
    return views


def nic_share_counts(
    transfers: Sequence[Tuple[int, int]],
) -> Tuple[Dict[int, int], Dict[int, int]]:
    """Flows per source / destination NIC — the transfer loop's share model."""
    src: Dict[int, int] = {}
    dst: Dict[int, int] = {}
    for s, d in transfers:
        src[s] = src.get(s, 0) + 1
        dst[d] = dst.get(d, 0) + 1
    return src, dst


def advertised_bandwidth(
    n_sites: int, nic_bps: float, transfers: Sequence[Tuple[int, int]] = ()
) -> np.ndarray:
    """Effective (src, dst) bandwidth matrix under per-NIC fair sharing:
    ``min(nic/flows(src), nic/flows(dst))`` with idle NICs at full rate."""
    bw = np.full((n_sites, n_sites), nic_bps, dtype=np.float64)
    if transfers:
        src, dst = nic_share_counts(transfers)
        for s, k in src.items():
            bw[s, :] = np.minimum(bw[s, :], nic_bps / k)
        for d, k in dst.items():
            bw[:, d] = np.minimum(bw[:, d], nic_bps / k)
    return bw


__all__ = [
    "ClusterState", "JobSoA", "JobView", "SiteView", "advertised_bandwidth",
    "nic_share_counts", "site_views_from_traces",
    "STATE_PAUSED", "STATE_QUEUED", "STATE_RUNNING",
]
