"""Fused, batched policy decide kernels (the PR 7 compiled decide path).

The per-tick hot loop of every migration policy is the fused
feasibility + benefit + lexicographic-argbest pass of
:func:`repro.core.orchestrator.score_migrations` — a ``(jobs × sites)``
grid evaluated once per simulator tick.  At fleet scale
(O(100) sites × O(100k) jobs) and at sweep scale (thousands of
concurrent Monte-Carlo cells) that pass is numpy-*dispatch*-bound: ~40
small elementwise kernels per cell per tick.  This module collapses it
three ways:

* **batching** — many cells' candidate rows are stacked into one padded
  ``(cells × jobs × sites)`` tensor and scored in a single pass
  (:func:`score_rows`), so dispatch cost amortizes over the whole batch;
* **bucketed padding** — job counts are padded to the next power of two
  (min 8) and site counts to a multiple of 8, so job-count drift between
  ticks reuses a handful of shapes instead of recompiling/reallocating
  per tick (``pad_jobs`` / ``pad_sites``);
* **compilation** — the same fused math is available as one
  ``jax.jit``-compiled XLA program and as a pallas kernel following the
  repo's ``kernels/flash_attention.py`` idiom (VMEM-tiled over the sites
  axis, masked padding lanes, running lexicographic argbest across site
  tiles).

Backend selection (:func:`backend` / :func:`set_backend`):

* ``numpy`` — the default everywhere except TPU.  Batched numpy mirrors
  ``score_migrations`` op for op with a leading batch axis, so action
  lists are **bit-identical** to the per-cell grids and to the
  ``decide_scalar`` oracles; every gated benchmark digit is produced by
  this backend.
* ``jit`` — the fused kernel as one jitted XLA call in float64
  (``jax.experimental.enable_x64``): same math, one dispatch.
* ``pallas`` — the tiled kernel (float32 accumulation, ``interpret=True``
  off-TPU); auto-selected on TPU.

The ``REPRO_DECIDE_BACKEND`` environment variable overrides the default.
Compiled backends return only the argbest destination per row; the rare
reserved-aware fallback path recomputes the numpy feasibility grids
lazily (see ``FeasibilityAwarePolicy._commit``).

Padding-lane invariants (why masked lanes can never win):  padded site
columns carry ``bw == 0`` and ``window == 0`` so ``t_transfer = inf``
fails every feasibility gate; padded job rows carry ``bw == 0`` across
all sites (and ``ckpt == 1.0``, never 0, so no ``0/0`` NaN) and resolve
to destination ``-1``.  All reductions use exact neutral elements
(``-inf`` for max, ``+inf`` for min), and ``argmax`` keeps numpy's
first-occurrence rule, preserving the scalar tie-break key
``(-benefit, t_transfer, sid)``.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import feasibility as fz

# ---------------------------------------------------------------------------
# Shared scalar helpers
# ---------------------------------------------------------------------------

_PPF_CACHE: Dict[float, float] = {}


def _norm_ppf_cached(eps: float) -> float:
    """Standard-normal inverse CDF, memoized (the stochastic gate's
    eps-quantile; moved here from orchestrator so kernels never import
    the policy module)."""
    got = _PPF_CACHE.get(eps)
    if got is None:
        import statistics

        got = _PPF_CACHE[eps] = statistics.NormalDist().inv_cdf(eps)
    return got


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------

_BACKENDS = ("numpy", "jit", "pallas")
_backend: Optional[str] = None


def backend() -> str:
    """The active decide backend: ``REPRO_DECIDE_BACKEND`` env override,
    else ``pallas`` on TPU, else ``numpy``."""
    global _backend
    if _backend is None:
        env = os.environ.get("REPRO_DECIDE_BACKEND", "").strip().lower()
        if env:
            if env not in _BACKENDS:
                raise ValueError(
                    f"REPRO_DECIDE_BACKEND must be one of {_BACKENDS}, "
                    f"not {env!r}")
            _backend = env
        else:
            _backend = "numpy"
            try:
                import jax

                if jax.default_backend() == "tpu":
                    _backend = "pallas"
            except Exception:  # pragma: no cover - jax always importable here
                pass
    return _backend


def set_backend(name: Optional[str]) -> None:
    """Force a backend (tests/benchmarks); ``None`` re-derives the
    default on next use."""
    global _backend
    if name is not None and name not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, not {name!r}")
    _backend = name


# ---------------------------------------------------------------------------
# Row extraction + padded batching
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScoreParams:
    """The scalar knobs of the fused kernel (one immutable bundle so a
    batch group can assert every cell shares them)."""

    alpha: float
    gamma: float
    beta: float
    queue_penalty_s: float
    min_benefit_s: float
    eps: float = 0.0
    forecast_sigma_s: float = 0.0

    @property
    def use_stoch(self) -> bool:
        return self.eps > 0.0 and self.forecast_sigma_s > 0.0

    @property
    def ppf_sigma(self) -> float:
        return (_norm_ppf_cached(self.eps) * self.forecast_sigma_s
                if self.use_stoch else 0.0)


@dataclass
class StateRows:
    """One cell's candidate rows, gathered from the SoA columns — the
    exact inputs :func:`score_migrations` reads, params-free so one
    extraction serves every backend.  ``k`` jobs × ``n`` sites."""

    sizes: np.ndarray      # (k,)  ckpt_bytes
    t_loads: np.ndarray    # (k,)
    rem: np.ndarray        # (k,)  remaining_s
    cur_green: np.ndarray  # (k,)  renewable window at the source, else 0
    load_src: np.ndarray   # (k,)  site_load at the source
    s_i: np.ndarray        # (k,)  source sid
    bw: np.ndarray         # (k, n) bandwidth_bps rows
    W: np.ndarray          # (n,)  site_window_s
    bq_load: np.ndarray    # (n,)
    free_slots: np.ndarray  # (n,)
    # (n,) battery state-of-charge kWh when the cell reports storage,
    # else None.  Carried for battery-aware compiled scoring; the
    # numpy scorer ignores it, so scores stay bit-identical either way.
    soc: Optional[np.ndarray] = None

    @property
    def k(self) -> int:
        return len(self.sizes)

    @property
    def n(self) -> int:
        return len(self.W)


def rows_from_state(state, cand: np.ndarray,
                    bw_grid: Optional[np.ndarray] = None) -> StateRows:
    """Gather one cell's :class:`StateRows` from a ``ClusterState`` and
    its candidate index array."""
    soa = state.soa
    W = state.site_window_s
    s_i = soa.site[cand]
    if bw_grid is None:
        bw_grid = state.bandwidth_bps[s_i, :]
    return StateRows(
        sizes=soa.ckpt_bytes[cand], t_loads=soa.t_load_s[cand],
        rem=soa.remaining_s[cand],
        cur_green=np.where(state.site_renewable[s_i], W[s_i], 0.0),
        load_src=state.site_load[s_i], s_i=s_i, bw=bw_grid, W=W,
        bq_load=state.site_bq_load, free_slots=state.site_free_slots,
        soc=(state.site_battery_soc if state.battery is not None else None))


def pad_jobs(k: int) -> int:
    """Job-axis padding bucket: next power of two, floor 8."""
    p = 8
    while p < k:
        p <<= 1
    return p


def pad_sites(n: int) -> int:
    """Site-axis padding bucket: next multiple of 8 (the pallas wrapper
    re-pads to its 128-lane tile internally)."""
    return ((n + 7) // 8) * 8


@dataclass
class ScoreBatch:
    """Padded, stacked rows for ``B`` cells: ``(B, K)`` job columns,
    ``(B, S)`` site columns, ``(B, K, S)`` bandwidth.  Padding values are
    chosen so masked lanes are infeasible (see module docstring)."""

    sizes: np.ndarray      # (B, K) pad 1.0
    t_loads: np.ndarray    # (B, K) pad 0.0
    rem: np.ndarray        # (B, K) pad 0.0
    cur_green: np.ndarray  # (B, K) pad 0.0
    load_src: np.ndarray   # (B, K) pad 0.0
    s_i: np.ndarray        # (B, K) int32, pad 0
    bw: np.ndarray         # (B, K, S) pad 0.0
    W: np.ndarray          # (B, S) pad 0.0
    bq_load: np.ndarray    # (B, S) pad 0.0
    free_slots: np.ndarray  # (B, S) pad 1
    n_jobs: Tuple[int, ...]
    n_sites: Tuple[int, ...]
    # (B, S) battery SoC kWh, pad 0.0 — None unless some cell reports
    # storage (reserved for battery-aware compiled scoring; unused by
    # the numpy scorer so batch scores never depend on it)
    soc: Optional[np.ndarray] = None


def _ragged_idx(lens: np.ndarray, stride: int) -> np.ndarray:
    """Flat scatter positions for ragged rows: row ``b``'s ``lens[b]``
    elements land at ``b*stride + [0..lens[b])``."""
    total = int(lens.sum())
    within = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
    return np.repeat(np.arange(len(lens)) * stride, lens) + within


def build_batch(rows: Sequence[StateRows]) -> ScoreBatch:
    """Stack cells into one bucket-padded :class:`ScoreBatch`.

    Ragged rows are placed with one concatenate + one flat scatter per
    column (constant dispatch count per batch) rather than B slice
    assignments per column — at sweep scale (B ~ 1000 tiny cells) the
    python stacking loop would otherwise dominate the fused kernel.
    """
    B = len(rows)
    ks = np.fromiter((r.k for r in rows), np.int64, B)
    ns = np.fromiter((r.n for r in rows), np.int64, B)
    K = pad_jobs(int(ks.max()))
    S = pad_sites(int(ns.max()))
    jidx = _ragged_idx(ks, K)
    sidx = _ragged_idx(ns, S)

    def jcol(vals, fill, dtype=np.float64):
        out = np.full(B * K, fill, dtype=dtype)
        out[jidx] = np.concatenate(vals)
        return out.reshape(B, K)

    def scol(vals, fill, dtype=np.float64):
        out = np.full(B * S, fill, dtype=dtype)
        out[sidx] = np.concatenate(vals)
        return out.reshape(B, S)

    # bw is ragged in both axes: element (b, j, s) lives at flat
    # (b*K + j)*S + s — jidx already enumerates (b*K + j) per real job
    widths = np.repeat(ns, ks)  # sites per (cell, job) row
    bw = np.zeros(B * K * S)
    bw[np.repeat(jidx * S, widths)
       + _ragged_idx(widths, 0)] = np.concatenate(
           [r.bw.ravel() for r in rows])
    return ScoreBatch(
        sizes=jcol([r.sizes for r in rows], 1.0),
        t_loads=jcol([r.t_loads for r in rows], 0.0),
        rem=jcol([r.rem for r in rows], 0.0),
        cur_green=jcol([r.cur_green for r in rows], 0.0),
        load_src=jcol([r.load_src for r in rows], 0.0),
        s_i=jcol([r.s_i for r in rows], 0, np.int32),
        bw=bw.reshape(B, K, S),
        W=scol([r.W for r in rows], 0.0),
        bq_load=scol([r.bq_load for r in rows], 0.0),
        free_slots=scol([r.free_slots for r in rows], 1, np.int64),
        n_jobs=tuple(int(k) for k in ks),
        n_sites=tuple(int(n) for n in ns),
        soc=(scol([(r.soc if r.soc is not None else np.zeros(r.n))
                   for r in rows], 0.0)
             if any(r.soc is not None for r in rows) else None))


def batch_from_states(states: Sequence, cands: Sequence[np.ndarray],
                      bw_grids: Optional[Sequence[np.ndarray]] = None,
                      ) -> ScoreBatch:
    """Build a :class:`ScoreBatch` straight from many ``ClusterState``
    snapshots with CROSS-CELL vectorized gathers: one concatenate + one
    fancy-index per column over all cells at once, instead of ~9 tiny
    numpy dispatches per cell (:func:`rows_from_state`) — at sweep scale
    the per-cell dispatch cost would dominate the fused kernel itself.
    Values are gathered with the exact same index arithmetic, so the
    resulting batch is element-identical to the per-cell path.

    ``bw_grids`` optionally carries per-cell pre-hardened bandwidth rows
    (plan-ahead's forecast-outage hardening); otherwise rows are gathered
    from each state's advertised ``bandwidth_bps`` matrix.
    """
    B = len(states)
    ks = np.fromiter((len(c) for c in cands), np.int64, B)
    ns = np.fromiter((s.n_sites for s in states), np.int64, B)
    K = pad_jobs(int(ks.max()))
    S = pad_sites(int(ns.max()))
    job_lens = np.fromiter((len(s.soa.jids) for s in states), np.int64, B)
    job_offs = np.cumsum(job_lens) - job_lens
    site_offs = np.cumsum(ns) - ns
    cand_g = np.concatenate(cands) + np.repeat(job_offs, ks)
    sizes = np.concatenate([s.soa.ckpt_bytes for s in states])[cand_g]
    t_loads = np.concatenate([s.soa.t_load_s for s in states])[cand_g]
    rem = np.concatenate([s.soa.remaining_s for s in states])[cand_g]
    s_i = np.concatenate([s.soa.site for s in states])[cand_g]
    W_cat = np.concatenate([s.site_window_s for s in states])
    s_g = s_i + np.repeat(site_offs, ks)
    cur_green = np.where(
        np.concatenate([s.site_renewable for s in states])[s_g],
        W_cat[s_g], 0.0)
    load_src = np.concatenate([s.site_load for s in states])[s_g]

    widths = np.repeat(ns, ks)  # destination count per (cell, job) row
    if bw_grids is not None:
        bw_vals = np.concatenate([g.ravel() for g in bw_grids])
    else:
        # gather each job's bandwidth row out of the cells' flattened
        # (n, n) matrices: row base = cell offset + s_i * n
        mat_lens = ns * ns
        row_base = (np.repeat(np.cumsum(mat_lens) - mat_lens, ks)
                    + s_i * widths)
        bw_vals = np.concatenate(
            [np.asarray(s.bandwidth_bps).ravel() for s in states])[
                np.repeat(row_base, widths) + _ragged_idx(widths, 0)]

    jidx = _ragged_idx(ks, K)
    sidx = _ragged_idx(ns, S)

    def jcol(vals, fill, dtype=np.float64):
        out = np.full(B * K, fill, dtype=dtype)
        out[jidx] = vals
        return out.reshape(B, K)

    def scol(vals, fill, dtype=np.float64):
        out = np.full(B * S, fill, dtype=dtype)
        out[sidx] = np.concatenate(vals)
        return out.reshape(B, S)

    bw = np.zeros(B * K * S)
    bw[np.repeat(jidx * S, widths) + _ragged_idx(widths, 0)] = bw_vals
    return ScoreBatch(
        sizes=jcol(sizes, 1.0), t_loads=jcol(t_loads, 0.0),
        rem=jcol(rem, 0.0), cur_green=jcol(cur_green, 0.0),
        load_src=jcol(load_src, 0.0), s_i=jcol(s_i, 0, np.int32),
        bw=bw.reshape(B, K, S),
        W=scol([s.site_window_s for s in states], 0.0),
        bq_load=scol([s.site_bq_load for s in states], 0.0),
        free_slots=scol([s.site_free_slots for s in states], 1, np.int64),
        n_jobs=tuple(int(k) for k in ks),
        n_sites=tuple(int(n) for n in ns),
        soc=(scol([s.site_battery_soc for s in states], 0.0)
             if any(s.battery is not None for s in states) else None))


def score_states(states: Sequence, cands: Sequence[np.ndarray],
                 params: ScoreParams,
                 bw_grids: Optional[Sequence[np.ndarray]] = None,
                 backend_name: Optional[str] = None) -> List[np.ndarray]:
    """Batch + score many cells' candidate rows in one fused pass;
    returns one un-padded ``(k_i,)`` destination array per cell — or
    ``None`` for a cell where no row found a destination, so callers
    skip their commit path without even a per-cell ``any()`` (the
    no-migration tick is the overwhelmingly common case at sweep
    scale, and the check is one batched reduction here)."""
    if not states:
        return []
    dest = score_batch(batch_from_states(states, cands, bw_grids),
                       params, backend_name)
    live = (dest >= 0).any(axis=1)
    return [dest[b, :len(c)] if live[b] else None
            for b, c in enumerate(cands)]


# ---------------------------------------------------------------------------
# numpy backend — the parity oracle for the compiled variants
# ---------------------------------------------------------------------------


def _score_numpy(batch: ScoreBatch, params: ScoreParams) -> np.ndarray:
    """The fused kernel with a leading batch axis, op-for-op identical to
    per-cell :func:`score_migrations` (every operation is elementwise or
    a per-lane reduction with exact neutral elements, so real lanes are
    bit-identical to the unbatched pass).  Returns ``(B, K)`` argbest
    destinations, ``-1`` where no destination is valid."""
    with np.errstate(divide="ignore"):
        tt = 8.0 * batch.sizes[:, :, None] / batch.bw
    W = batch.W[:, None, :]
    t_cost = tt + batch.t_loads[:, :, None] + fz.T_DOWNTIME_S
    energy_ok = (fz.P_SYS_KW / fz.P_NODE_KW) * tt < W
    not_c = tt < fz.CLASS_B_MAX_S
    if params.use_stoch:
        window_lo = W + params.ppf_sigma
        time_ok = t_cost < params.alpha * np.maximum(window_lo, 0.0)
    else:
        time_ok = t_cost < params.alpha * W
    ok = time_ok & energy_ok & not_c
    rem = batch.rem[:, :, None]
    avoided = np.maximum(
        0.0, np.minimum(W, rem) - np.minimum(batch.cur_green[:, :, None], rem))
    benefit = (params.gamma * avoided
               - (params.beta * params.queue_penalty_s)
               * (batch.bq_load[:, None, :] - batch.load_src[:, :, None]))
    benefit = benefit + np.where(batch.free_slots <= 0,
                                 -params.queue_penalty_s, 0.0)[:, None, :]
    sid = np.arange(batch.W.shape[1])
    valid = (ok
             & (sid[None, None, :] != batch.s_i[:, :, None])
             & (benefit > np.maximum(t_cost, params.min_benefit_s)))
    b = np.where(valid, benefit, -np.inf)
    mb = b.max(axis=2)
    tie = valid & (b == mb[..., None])
    ttm = np.where(tie, tt, np.inf)
    tie = tie & (ttm == ttm.min(axis=2)[..., None])
    return np.where(np.isfinite(mb), tie.argmax(axis=2), -1)


# ---------------------------------------------------------------------------
# jit backend — the same math as one compiled XLA program (float64)
# ---------------------------------------------------------------------------

_JIT_FN = None


def _jit_fn():
    global _JIT_FN
    if _JIT_FN is None:
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("use_stoch",))
        def fn(sizes, t_loads, rem, cur_green, load_src, s_i, bw, W,
               bq_load, free_slots, alpha, gamma, betaqp, queue_penalty_s,
               min_benefit_s, ppf_sigma, use_stoch):
            tt = 8.0 * sizes[:, :, None] / bw
            Wn = W[:, None, :]
            t_cost = tt + t_loads[:, :, None] + fz.T_DOWNTIME_S
            energy_ok = (fz.P_SYS_KW / fz.P_NODE_KW) * tt < Wn
            not_c = tt < fz.CLASS_B_MAX_S
            if use_stoch:
                time_ok = t_cost < alpha * jnp.maximum(Wn + ppf_sigma, 0.0)
            else:
                time_ok = t_cost < alpha * Wn
            ok = time_ok & energy_ok & not_c
            remn = rem[:, :, None]
            avoided = jnp.maximum(
                0.0, jnp.minimum(Wn, remn)
                - jnp.minimum(cur_green[:, :, None], remn))
            benefit = (gamma * avoided
                       - betaqp * (bq_load[:, None, :]
                                   - load_src[:, :, None]))
            benefit = benefit + jnp.where(
                free_slots <= 0, -queue_penalty_s, 0.0)[:, None, :]
            sid = jax.lax.broadcasted_iota(jnp.int32, tt.shape, 2)
            valid = (ok
                     & (sid != s_i[:, :, None])
                     & (benefit > jnp.maximum(t_cost, min_benefit_s)))
            b = jnp.where(valid, benefit, -jnp.inf)
            mb = b.max(axis=2)
            tie = valid & (b == mb[..., None])
            ttm = jnp.where(tie, tt, jnp.inf)
            tie = tie & (ttm == ttm.min(axis=2)[..., None])
            return jnp.where(jnp.isfinite(mb), tie.argmax(axis=2), -1)

        _JIT_FN = fn
    return _JIT_FN


def _score_jit(batch: ScoreBatch, params: ScoreParams) -> np.ndarray:
    """One fused XLA dispatch in float64 (scalar knobs are traced, so
    value changes never recompile; only padding-bucket shape changes
    do)."""
    import jax

    with jax.experimental.enable_x64():
        out = _jit_fn()(
            batch.sizes, batch.t_loads, batch.rem, batch.cur_green,
            batch.load_src, batch.s_i, batch.bw, batch.W, batch.bq_load,
            batch.free_slots, params.alpha, params.gamma,
            params.beta * params.queue_penalty_s, params.queue_penalty_s,
            params.min_benefit_s, params.ppf_sigma,
            use_stoch=params.use_stoch)
    return np.asarray(out)


# ---------------------------------------------------------------------------
# pallas backend — VMEM-tiled over the sites axis (flash_attention idiom)
# ---------------------------------------------------------------------------

NEG_INF = -2.0e38  # large-but-finite f32 sentinels (flash_attention idiom)
POS_INF = 2.0e38
BIG_IDX = 2 ** 30

_BLOCK_J = 8
_BLOCK_S = 128


def _dest_kernel(sizes_ref, t_loads_ref, rem_ref, cur_green_ref,
                 load_src_ref, s_i_ref, bw_ref, W_ref, bq_load_ref,
                 free_pen_ref, dest_ref, mb_scr, mtt_scr, mdest_scr, *,
                 alpha, gamma, betaqp, min_benefit_s, ppf_sigma, use_stoch,
                 block_j, block_s, n_s_blocks):
    """One (batch, job-tile, site-tile) grid step: score the tile, fold
    it into the running lexicographic argbest held in VMEM scratch, and
    emit destinations after the last site tile.

    The cross-tile update keeps the *earlier* tile on exact
    (benefit, t_transfer) ties, and the within-tile reduction takes the
    lowest sid among tied lanes — together reproducing numpy argmax's
    first-occurrence (lowest-sid) rule globally.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        mb_scr[...] = jnp.full((block_j,), NEG_INF, jnp.float32)
        mtt_scr[...] = jnp.full((block_j,), POS_INF, jnp.float32)
        mdest_scr[...] = jnp.full((block_j,), -1, jnp.int32)

    sizes = sizes_ref[0, :]          # (bj,)
    bw = bw_ref[0, :, :]             # (bj, bs)
    W = W_ref[0, :][None, :]         # (1, bs)
    tt = 8.0 * sizes[:, None] / bw   # 0-bandwidth lanes -> inf -> infeasible
    t_cost = tt + t_loads_ref[0, :][:, None] + fz.T_DOWNTIME_S
    energy_ok = (fz.P_SYS_KW / fz.P_NODE_KW) * tt < W
    not_c = tt < fz.CLASS_B_MAX_S
    if use_stoch:
        time_ok = t_cost < alpha * jnp.maximum(W + ppf_sigma, 0.0)
    else:
        time_ok = t_cost < alpha * W
    ok = time_ok & energy_ok & not_c
    rem = rem_ref[0, :][:, None]
    avoided = jnp.maximum(
        0.0, jnp.minimum(W, rem)
        - jnp.minimum(cur_green_ref[0, :][:, None], rem))
    benefit = (gamma * avoided
               - betaqp * (bq_load_ref[0, :][None, :]
                           - load_src_ref[0, :][:, None]))
    benefit = benefit + free_pen_ref[0, :][None, :]
    sid = (jax.lax.broadcasted_iota(jnp.int32, (block_j, block_s), 1)
           + si * block_s)
    valid = (ok
             & (sid != s_i_ref[0, :][:, None])
             & (benefit > jnp.maximum(t_cost, min_benefit_s)))
    b = jnp.where(valid, benefit, NEG_INF)
    mb_tile = b.max(axis=1)
    tie = valid & (b == mb_tile[:, None])
    ttm = jnp.where(tie, tt, POS_INF)
    mtt_tile = ttm.min(axis=1)
    tie = tie & (ttm == mtt_tile[:, None])
    dest_tile = jnp.where(tie, sid, BIG_IDX).min(axis=1).astype(jnp.int32)

    mb_prev = mb_scr[...]
    mtt_prev = mtt_scr[...]
    # strict lexicographic improvement only: exact ties keep the earlier
    # (lower-sid) tile, matching global first-occurrence argmax
    better = (mb_tile > mb_prev) | ((mb_tile == mb_prev)
                                    & (mtt_tile < mtt_prev))
    mb_scr[...] = jnp.where(better, mb_tile, mb_prev)
    mtt_scr[...] = jnp.where(better, mtt_tile, mtt_prev)
    mdest_scr[...] = jnp.where(better, dest_tile, mdest_scr[...])

    @pl.when(si == n_s_blocks - 1)
    def _done():
        # no-valid rows never improved on the init state -> stay -1
        dest_ref[0, :] = mdest_scr[...]


@functools.lru_cache(maxsize=64)
def _pallas_fn(B: int, K: int, S: int, alpha: float, gamma: float,
               betaqp: float, min_benefit_s: float, ppf_sigma: float,
               use_stoch: bool, interpret: bool):
    """Build + jit one pallas_call for a padded batch shape (lru-cached
    so padding buckets, not raw job counts, bound the compile count)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    block_j, block_s = _BLOCK_J, _BLOCK_S
    n_j, n_s = K // block_j, S // block_s
    kernel = functools.partial(
        _dest_kernel, alpha=alpha, gamma=gamma, betaqp=betaqp,
        min_benefit_s=min_benefit_s, ppf_sigma=ppf_sigma,
        use_stoch=use_stoch, block_j=block_j, block_s=block_s,
        n_s_blocks=n_s)
    job_spec = pl.BlockSpec((1, block_j), lambda b, j, s: (b, j))
    site_spec = pl.BlockSpec((1, block_s), lambda b, j, s: (b, s))
    call = pl.pallas_call(
        kernel,
        grid=(B, n_j, n_s),
        in_specs=[job_spec, job_spec, job_spec, job_spec, job_spec,
                  job_spec,
                  pl.BlockSpec((1, block_j, block_s),
                               lambda b, j, s: (b, j, s)),
                  site_spec, site_spec, site_spec],
        out_specs=job_spec,
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_j,), jnp.float32),
                        pltpu.VMEM((block_j,), jnp.float32),
                        pltpu.VMEM((block_j,), jnp.int32)],
        interpret=interpret,
    )
    return jax.jit(call)


def _score_pallas(batch: ScoreBatch, params: ScoreParams) -> np.ndarray:
    """The tiled kernel (float32; ``interpret=True`` off-TPU).  The site
    axis is re-padded from the 8-bucket to the 128-lane tile — the extra
    lanes carry the same infeasible padding values."""
    import jax
    import jax.numpy as jnp

    B, K = batch.sizes.shape
    S = batch.bw.shape[2]
    S_pad = ((S + _BLOCK_S - 1) // _BLOCK_S) * _BLOCK_S
    f32 = jnp.float32

    def site_pad(a, fill=0.0):
        if S_pad == S:
            return jnp.asarray(a, f32)
        out = np.full(a.shape[:-1] + (S_pad,), fill, dtype=np.float32)
        out[..., :S] = a
        return jnp.asarray(out)

    free_pen = np.where(batch.free_slots <= 0,
                        -params.queue_penalty_s, 0.0)
    interpret = jax.default_backend() != "tpu"
    fn = _pallas_fn(B, K, S_pad, float(params.alpha), float(params.gamma),
                    float(params.beta * params.queue_penalty_s),
                    float(params.min_benefit_s), float(params.ppf_sigma),
                    params.use_stoch, interpret)
    out = fn(jnp.asarray(batch.sizes, f32), jnp.asarray(batch.t_loads, f32),
             jnp.asarray(batch.rem, f32), jnp.asarray(batch.cur_green, f32),
             jnp.asarray(batch.load_src, f32),
             jnp.asarray(batch.s_i, jnp.int32), site_pad(batch.bw),
             site_pad(batch.W), site_pad(batch.bq_load), site_pad(free_pen))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

_SCORE_FNS = {"numpy": _score_numpy, "jit": _score_jit,
              "pallas": _score_pallas}


def score_batch(batch: ScoreBatch, params: ScoreParams,
                backend_name: Optional[str] = None) -> np.ndarray:
    """Score a padded batch on the selected backend; ``(B, K)`` argbest
    destinations (``-1`` = stay put), padded job rows included."""
    return _SCORE_FNS[backend_name or backend()](batch, params)


def score_rows(rows: Sequence[StateRows], params: ScoreParams,
               backend_name: Optional[str] = None) -> List[np.ndarray]:
    """Batch + score many cells' rows in one fused pass; returns one
    un-padded ``(k_i,)`` destination array per cell."""
    if not rows:
        return []
    dest = score_batch(build_batch(rows), params, backend_name)
    return [dest[b, :r.k] for b, r in enumerate(rows)]


__all__ = [
    "ScoreBatch", "ScoreParams", "StateRows", "backend", "build_batch",
    "pad_jobs", "pad_sites", "rows_from_state", "score_batch", "score_rows",
    "set_backend",
]
