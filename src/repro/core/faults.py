"""Deterministic fault injection + recovery machinery.

The paper's feasibility model admits a migration and assumes it runs to
completion, but §VII.E names stalled transfers, congestion and retries
as the operational failure mode of WAN-migrated training.  This module
makes faults a first-class, *pre-materialized* input to the simulator:

``FaultRegime``
    The scenario-composable spec — rates and mean durations for five
    fault classes (site blackouts, hard WAN link failures, checkpoint
    corruption on rollback, serving replica crashes, straggler
    degradation) plus the recovery knobs (transfer-stall watchdog
    timeout and a bounded-retry ``RetryPolicy``).  All fields default to
    *off*; an unset/inactive regime draws **zero** RNG numbers and adds
    zero float ops, so every faults-off digit stays byte-identical.

``FaultPlan``
    The regime *realized* against a concrete ``(n_sites, horizon_s,
    seed)``: every fault span is sampled up front from its own
    ``default_rng([seed, 173, k])`` stream (the repo-wide list-seed
    convention — enabling faults never perturbs job, trace, serving or
    forecast streams).  The plan is pure data — sorted non-overlapping
    ``(start, end)`` span arrays per site / link — and answers point
    queries (``site_up``, ``link_up_mat``, ``tput_factor``) and
    event-scheduling queries (``next_edge_after``).  Because the plan is
    materialized before the run, the forecast layer can treat it as
    exactly forecastable (the same precedent as WAN brownout calendars):
    ``repair_time_s`` and ``next_fault_start_after`` feed the
    fault-aware policies.

``RetryPolicy``
    Bounded attempts with exponential backoff for aborted migrations —
    the watchdog replaces today's silent infinite stall with
    abort → requeue at source → cooldown → (possibly re-routed) retry.

Nothing here touches the event loop; the simulator consults the plan at
fault-span edges it schedules like any other event source.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_TAG = 173  # fault-stream RNG tag (serving=151, forecast=97, signals=131)

_DAY_S = 86400.0


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for aborted/failed migrations.

    Attempt ``n`` (1-based) that fails parks the job at its source for
    ``backoff_base_s * backoff_mult**(n-1)`` seconds before it becomes
    schedulable/migratable again; after ``max_attempts`` aborted
    transfers the job stops being offered retries and simply requeues
    (it can still run locally — no job is ever lost to the retry
    ladder).
    """

    max_attempts: int = 3
    backoff_base_s: float = 600.0
    backoff_mult: float = 2.0

    def backoff_s(self, attempt: int) -> float:
        """Cooldown after the ``attempt``-th (1-based) failed try."""
        return self.backoff_base_s * self.backoff_mult ** max(
            0, attempt - 1)


@dataclass(frozen=True)
class FaultRegime:
    """Scenario-level fault spec (all classes default to *off*).

    Rates are Poisson arrivals per simulated day; durations are sampled
    exponentially around the given means.  ``checkpoint_interval_s``
    optionally overrides ``SimConfig.checkpoint_interval_s`` so a
    scenario can carry its whole fault story in one object.
    """

    # site blackouts: every slot down; running jobs roll back to their
    # last checkpoint and requeue; the site is unschedulable (and its
    # NICs dark — links touching it carry zero traffic) until repair
    site_blackout_rate_per_day: float = 0.0
    site_blackout_mean_s: float = 3600.0
    # hard WAN link failures: capacity -> 0 mid-transfer (distinct from
    # the *scheduled* brownout calendar the forecast already knows)
    link_failure_rate_per_day: float = 0.0
    link_failure_mean_s: float = 1800.0
    # checkpoint corruption: with this probability a rollback's target
    # checkpoint is unreadable and the job falls back one more interval
    ckpt_corruption_prob: float = 0.0
    # serving replica crashes: one replica down for the repair span;
    # queued requests re-drain, the in-flight batch re-routes
    replica_crash_rate_per_day: float = 0.0
    replica_crash_mean_s: float = 1800.0
    # stragglers: site throughput multiplied by ``straggler_factor``
    straggler_rate_per_day: float = 0.0
    straggler_mean_s: float = 7200.0
    straggler_factor: float = 0.5
    # legacy per-job Poisson rollback (the old
    # ``SimConfig.failure_rate_per_slot_hour`` — kept there as an alias)
    job_failure_rate_per_slot_hour: float = 0.0
    ckpt_corruption_extra_intervals: int = 1
    checkpoint_interval_s: Optional[float] = None
    # recovery machinery
    stall_timeout_s: float = 1800.0
    retry: RetryPolicy = field(default_factory=RetryPolicy)

    def any_active(self) -> bool:
        """True when any fault class can actually fire — the gate the
        simulator uses to keep the faults-off path draw- and op-free."""
        return (self.site_blackout_rate_per_day > 0.0
                or self.link_failure_rate_per_day > 0.0
                or self.ckpt_corruption_prob > 0.0
                or self.replica_crash_rate_per_day > 0.0
                or self.straggler_rate_per_day > 0.0
                or self.job_failure_rate_per_slot_hour > 0.0)


def _sample_spans(rng: np.random.Generator, rate_per_day: float,
                  mean_s: float, t_end: float) -> np.ndarray:
    """Poisson-process ``(k, 2)`` span array over ``[0, t_end]`` —
    exponential inter-arrival gaps at ``rate_per_day``, exponential
    durations around ``mean_s``, merged to sorted non-overlapping form
    (so ``searchsorted`` point queries below stay O(log k))."""
    if rate_per_day <= 0.0 or t_end <= 0.0:
        return np.empty((0, 2))
    scale = _DAY_S / rate_per_day
    starts: List[float] = []
    durs: List[float] = []
    t = float(rng.exponential(scale))
    while t < t_end:
        starts.append(t)
        durs.append(float(rng.exponential(mean_s)))
        t += float(rng.exponential(scale))
    if not starts:
        return np.empty((0, 2))
    spans = np.column_stack([starts, np.asarray(starts) + np.asarray(durs)])
    spans[:, 1] = np.minimum(spans[:, 1], t_end)
    merged: List[List[float]] = []
    for s0, e0 in spans:
        if merged and s0 <= merged[-1][1]:
            merged[-1][1] = max(merged[-1][1], e0)
        else:
            merged.append([float(s0), float(e0)])
    return np.asarray(merged)


def _in_span(spans: np.ndarray, t: float) -> bool:
    """Point-in-span for a sorted non-overlapping ``(k, 2)`` array
    (half-open ``[start, end)`` — at the repair instant the fault is
    over, matching the simulator's edge processing order)."""
    if len(spans) == 0:
        return False
    i = int(np.searchsorted(spans[:, 0], t, side="right")) - 1
    return i >= 0 and t < spans[i, 1]


def _next_start_after(spans: np.ndarray, t: float) -> float:
    """First span start strictly after ``t`` (``inf`` when none)."""
    if len(spans) == 0:
        return float("inf")
    i = int(np.searchsorted(spans[:, 0], t, side="right"))
    return float(spans[i, 0]) if i < len(spans) else float("inf")


def _span_end(spans: np.ndarray, t: float) -> float:
    """End of the span covering ``t`` (``t`` itself when uncovered) —
    the repair-time estimate the forecast layer exposes."""
    if len(spans) == 0:
        return t
    i = int(np.searchsorted(spans[:, 0], t, side="right")) - 1
    if i >= 0 and t < spans[i, 1]:
        return float(spans[i, 1])
    return t


@dataclass(frozen=True)
class FaultPlan:
    """A :class:`FaultRegime` realized against one cluster + seed.

    All arrays are sorted, non-overlapping ``(k, 2)`` ``(start, end)``
    spans.  ``link_spans`` holds *hard link failures* keyed by the
    unordered ``(min, max)`` site pair (failures take out both
    directions); site-blackout NIC darkness is composed on top by
    :meth:`link_up_mat` / :meth:`next_fault_start_after`, so callers see
    one effective up/down truth.
    """

    regime: FaultRegime
    n_sites: int
    horizon_s: float
    seed: int
    site_spans: Tuple[np.ndarray, ...]
    link_spans: Dict[Tuple[int, int], np.ndarray]
    replica_spans: Tuple[np.ndarray, ...]
    straggler_spans: Tuple[np.ndarray, ...]
    edges: np.ndarray  # unique sorted span boundaries (event sources)

    # ---- construction ------------------------------------------------------
    @classmethod
    def build(cls, regime: FaultRegime, n_sites: int, horizon_s: float,
              seed: int) -> "FaultPlan":
        """Materialize every fault span over ``[0, 2*horizon_s]`` (the
        engine's hard stop) from per-class ``default_rng([seed, 173,
        k])`` streams — adding a fault class never reshuffles another's
        spans, and no draw ever touches a non-fault stream."""
        t_end = 2.0 * horizon_s
        site_spans = []
        if regime.site_blackout_rate_per_day > 0.0:
            for s in range(n_sites):
                rng = np.random.default_rng([seed, _TAG, 1, s])
                site_spans.append(_sample_spans(
                    rng, regime.site_blackout_rate_per_day,
                    regime.site_blackout_mean_s, t_end))
        else:
            site_spans = [np.empty((0, 2))] * n_sites
        link_spans: Dict[Tuple[int, int], np.ndarray] = {}
        if regime.link_failure_rate_per_day > 0.0:
            for a in range(n_sites):
                for b in range(a + 1, n_sites):
                    rng = np.random.default_rng([seed, _TAG, 2, a, b])
                    sp = _sample_spans(rng, regime.link_failure_rate_per_day,
                                       regime.link_failure_mean_s, t_end)
                    if len(sp):
                        link_spans[(a, b)] = sp
        replica_spans = []
        if regime.replica_crash_rate_per_day > 0.0:
            for s in range(n_sites):
                rng = np.random.default_rng([seed, _TAG, 3, s])
                replica_spans.append(_sample_spans(
                    rng, regime.replica_crash_rate_per_day,
                    regime.replica_crash_mean_s, t_end))
        else:
            replica_spans = [np.empty((0, 2))] * n_sites
        straggler_spans = []
        if regime.straggler_rate_per_day > 0.0:
            for s in range(n_sites):
                rng = np.random.default_rng([seed, _TAG, 4, s])
                straggler_spans.append(_sample_spans(
                    rng, regime.straggler_rate_per_day,
                    regime.straggler_mean_s, t_end))
        else:
            straggler_spans = [np.empty((0, 2))] * n_sites
        parts = ([sp for sp in site_spans] + list(link_spans.values())
                 + [sp for sp in replica_spans]
                 + [sp for sp in straggler_spans])
        flat = ([p.ravel() for p in parts if len(p)] or [np.empty(0)])
        edges = np.unique(np.concatenate(flat))
        return cls(regime=regime, n_sites=n_sites, horizon_s=horizon_s,
                   seed=seed, site_spans=tuple(site_spans),
                   link_spans=link_spans,
                   replica_spans=tuple(replica_spans),
                   straggler_spans=tuple(straggler_spans), edges=edges)

    def corruption_rng(self) -> np.random.Generator:
        """The checkpoint-corruption Bernoulli stream (one draw per
        rollback, consumed by the simulator — its own tag, so enabling
        corruption perturbs nothing else)."""
        return np.random.default_rng([self.seed, _TAG, 5])

    # ---- point queries -----------------------------------------------------
    def site_up(self, s: int, t: float) -> bool:
        return not _in_span(self.site_spans[s], t)

    def site_up_vec(self, t: float) -> np.ndarray:
        return np.array([not _in_span(sp, t) for sp in self.site_spans],
                        dtype=bool)

    def link_failed(self, a: int, b: int, t: float) -> bool:
        """Hard link failure only (no blackout composition)."""
        sp = self.link_spans.get((min(a, b), max(a, b)))
        return sp is not None and _in_span(sp, t)

    def link_up_mat(self, t: float) -> np.ndarray:
        """Effective ``(n, n)`` link-up truth: a link is down while
        either endpoint is blacked out (NICs dark) *or* the link itself
        has hard-failed.  Diagonal stays True."""
        n = self.n_sites
        up = np.ones((n, n), dtype=bool)
        site_up = self.site_up_vec(t)
        if not site_up.all():
            up &= site_up[:, None] & site_up[None, :]
        for (a, b), sp in self.link_spans.items():
            if _in_span(sp, t):
                up[a, b] = up[b, a] = False
        np.fill_diagonal(up, True)
        return up

    def replica_down(self, s: int, t: float) -> bool:
        return _in_span(self.replica_spans[s], t)

    def replica_down_vec(self, t: float) -> np.ndarray:
        return np.array([_in_span(sp, t) for sp in self.replica_spans],
                        dtype=bool)

    def tput_factor(self, s: int, t: float) -> float:
        if _in_span(self.straggler_spans[s], t):
            return self.regime.straggler_factor
        return 1.0

    def tput_factor_vec(self, t: float) -> np.ndarray:
        f = np.ones(self.n_sites)
        for s, sp in enumerate(self.straggler_spans):
            if _in_span(sp, t):
                f[s] = self.regime.straggler_factor
        return f

    # ---- event scheduling --------------------------------------------------
    def next_edge_after(self, t: float) -> float:
        """First span boundary strictly after ``t`` (``inf`` when none)
        — the simulator's fault event source."""
        i = int(np.searchsorted(self.edges, t, side="right"))
        return float(self.edges[i]) if i < len(self.edges) else float("inf")

    # ---- forecast-layer queries (the plan is exactly forecastable, the
    # same precedent as the WAN brownout calendar) ---------------------------
    def repair_time_s(self, s: int, t: float) -> float:
        """When site ``s`` comes back up (``t`` itself if it is up)."""
        return _span_end(self.site_spans[s], t)

    def repair_time_vec(self, t: float) -> np.ndarray:
        return np.array([_span_end(sp, t) for sp in self.site_spans])

    def next_fault_start_after(self, a: int, b: int, t: float) -> float:
        """First instant strictly after ``t`` at which the ``a``→``b``
        path loses capacity to a fault: the next hard failure of the
        link *or* the next blackout of either endpoint."""
        out = _next_start_after(self.site_spans[a], t)
        out = min(out, _next_start_after(self.site_spans[b], t))
        sp = self.link_spans.get((min(a, b), max(a, b)))
        if sp is not None:
            out = min(out, _next_start_after(sp, t))
        return out

    def next_fault_start_grid(self, t: float) -> np.ndarray:
        """(n, n) matrix of :meth:`next_fault_start_after` (``inf``-
        filled diagonal and fault-free pairs)."""
        n = self.n_sites
        site_next = np.array([_next_start_after(sp, t)
                              for sp in self.site_spans])
        grid = np.minimum(site_next[:, None], site_next[None, :])
        for (a, b), sp in self.link_spans.items():
            nx = _next_start_after(sp, t)
            if nx < grid[a, b]:
                grid[a, b] = grid[b, a] = nx
        np.fill_diagonal(grid, float("inf"))
        return grid

    # ---- telemetry ---------------------------------------------------------
    def outage_stats(self, t_end: float) -> Tuple[int, float]:
        """``(site_outages, mttr_s)`` over blackout spans that *started*
        before ``t_end`` — the count and the mean time-to-repair the
        run actually experienced (repairs past ``t_end`` clip there)."""
        count = 0
        total = 0.0
        for sp in self.site_spans:
            for s0, e0 in sp:
                if s0 >= t_end:
                    break
                count += 1
                total += min(e0, t_end) - s0
        return count, (total / count if count else 0.0)


__all__ = ["FaultPlan", "FaultRegime", "RetryPolicy"]
