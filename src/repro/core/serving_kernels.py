"""Chunked vectorized serving fast path (the PR 10 inference analogue
of the PR 7 compiled decide path).

The per-event scalar plane in :mod:`repro.core.serving` pays three taxes
per request: one engine iteration (a 12-way ``min()`` over event
sources) per arrival/close/service event, one full
:class:`~repro.core.state.ClusterState` construction per batch dispatch
(``ClusterSimulator._serving_state``), and per-request ``Request``
object traffic.  At the paper's "millions of users" rates those taxes
dominate the whole simulation.  This module removes all three while
keeping every observable **bit-identical**:

* **pre-materialized arrival arrays** —
  :func:`repro.core.serving.generate_request_events` yields the sorted
  columnar ``(t, origin, cls, deadline)`` stream (same draws as the
  scalar ``generate_requests``); the plane scans plain python lists of
  it instead of allocating a ``Request`` per row;
* **span processing** — :meth:`ChunkedServingPlane.process_span`
  advances the plane through *every* serving event strictly before the
  next orchestrator-relevant event in one call, so the engine performs
  one iteration per span instead of one per request; within a span,
  runs of pure arrivals (and isolated batch-close / service events) are
  handled by inlined light paths that skip the generic event mirror;
* **router kernels** — scalar-router mirrors that read the plane's live
  arrays, the epoch-cached :meth:`TraceStack.point` /
  :meth:`ForecastHorizon.carbon_grid` views and a precomputed
  reachability matrix directly, instead of building a ``ClusterState``
  per batch.  The carbon-slo kernel scores its candidate site axis in
  one :meth:`ForecastHorizon.grid_carbon_g_rows` call (the documented
  elementwise mirror of ``grid_carbon_g``).  The scalar routers stay
  registered untouched — they are the parity oracles.

Exactness invariants (enforced by the parity suite in
``tests/test_serving_fastpath.py``):

* the jitter stream draws ``normal(0, σ, size=k)`` blocks, bit-identical
  to k sequential scalar draws, and applies ``np.exp`` per element on
  the indexed ``float64`` scalar (same libm path as the scalar plane);
* queue/batch float accounting uses python floats whose add/sub/mul
  sequence mirrors the scalar plane's numpy-scalar ops exactly (IEEE
  double either way);
* service starts happen in ascending site order within an event (the
  scalar ``_start_services`` scan), so the jitter stream is consumed in
  the identical order; the inlined light paths only fire when an event
  is strictly clear (by the engine's ``EPS``) of every other event
  source, so coalescing behaviour matches the scalar ``process``;
* a dispatch that opens a WAN flow ends the span immediately — the
  engine re-splits ``shared_rates`` over migrations + serve flows
  exactly as the per-event path does.

Billing still posts per service span through the shared
:class:`~repro.core.ledger.PowerLedger` (identical call sequence), so
energy/carbon digits match to the bit.
"""
from __future__ import annotations

import bisect
import heapq
import math
import time
from collections import deque
from itertools import repeat
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ledger import PowerLedger
from repro.core.serving import (
    SHED, CarbonSloRouter, GreenFirstRouter, NearestRouter, Router,
    ServingProfile, ServingView, _RNG_TAG, generate_request_events,
)
from repro.core.signals import GridSignals
from repro.core.traces import stack_traces

INF = float("inf")

#: `_dispatch` outcome codes (beyond an enqueued site id >= 0)
_FLOW = -1  # a WAN flow started: the caller must re-split shared rates
_GONE = -2  # dropped or shed: the batch left the system


class _Batch:
    """Chunked-plane batch: request *indices* into the arrival arrays
    instead of Request objects (latency/SLO resolve from the arrays at
    completion time)."""

    __slots__ = ("bid", "origin", "ci", "idx", "opened_s", "site",
                 "t_service_start_s", "service_s", "nominal")

    def __init__(self, bid: int, origin: int, ci: int, idx: List[int],
                 opened_s: float):
        self.bid = bid
        self.origin = origin
        self.ci = ci
        self.idx = idx
        self.opened_s = opened_s
        self.site = -1
        self.t_service_start_s = -1.0
        self.service_s = 0.0
        self.nominal = 0.0  # set at dispatch (len is frozen from there)


class _Flow:
    """In-flight routed batch on the WAN — same lazy-heap protocol as
    the scalar :class:`~repro.core.serving.ServeFlow`."""

    __slots__ = ("fid", "batch", "src", "dst", "remaining_bits",
                 "rate_bps", "anchor_s", "ver")

    def __init__(self, fid: int, batch: _Batch, src: int, dst: int,
                 bits: float, anchor_s: float):
        self.fid = fid
        self.batch = batch
        self.src = src
        self.dst = dst
        self.remaining_bits = bits
        self.rate_bps = 0.0
        self.anchor_s = anchor_s
        self.ver = 0


# ---------------------------------------------------------------------------
# Router kernels — scalar-router mirrors over plane-local state
# ---------------------------------------------------------------------------


class _Kernel:
    """Base: candidate enumeration + lazy post-admission transfer
    estimates, mirroring ``Router._candidates`` / ``Router._xfer_s``
    over the plane's live arrays (no ClusterState)."""

    def __init__(self, plane: "ChunkedServingPlane"):
        self.plane = plane

    def _cands(self, batch: _Batch) -> List[int]:
        p = self.plane
        origin = batch.origin
        reach = p._reach[origin]
        max_q = p._max_q
        queues = p._queues
        out = [origin]
        for s in range(p.n_sites):
            if s == origin or len(queues[s]) >= max_q:
                continue
            if not reach[s]:
                continue
            out.append(s)
        return out

    def route(self, batch: _Batch, t: float) -> int:  # pragma: no cover
        raise NotImplementedError


class NearestKernel(_Kernel):
    """Mirror of :class:`~repro.core.serving.NearestRouter`."""

    def route(self, batch: _Batch, t: float) -> int:
        p = self.plane
        origin = batch.origin
        if len(p._queues[origin]) < p._max_q:
            return origin
        bits = p._cls_bits[batch.ci] * len(batch.idx)
        flows: Optional[list] = None
        best, best_key = origin, (INF, origin)
        for s in self._cands(batch):
            if s == origin:
                xfer = 0.0
            else:
                if flows is None:
                    flows = p._all_flow_pairs()
                rate = p.topo.post_admission_rate(origin, s, flows, t)
                xfer = bits / rate if rate > 0.0 else INF
            delay = xfer + p._est_wait(s)
            key = (delay, s)
            if key < best_key:
                best, best_key = s, key
        return best


class GreenFirstKernel(_Kernel):
    """Mirror of :class:`~repro.core.serving.GreenFirstRouter` reading
    the epoch-cached trace stack directly."""

    def __init__(self, plane: "ChunkedServingPlane", lookahead_s: float,
                 min_gbps: float):
        super().__init__(plane)
        self.lookahead_s = float(lookahead_s)
        self.min_gbps = float(min_gbps)

    def route(self, batch: _Batch, t: float) -> int:
        p = self.plane
        origin = batch.origin
        green, window, nxt = p._stack.point(t)
        cands = self._cands(batch)
        if self.min_gbps > 0.0:
            bits_floor = self.min_gbps * 1e9
            flows = p._all_flow_pairs()
            cands = [s for s in cands if s == origin
                     or p.topo.post_admission_rate(origin, s, flows, t)
                     >= bits_floor]
        free_green = [s for s in cands if green[s]]
        if free_green:
            return max(free_green, key=lambda s: (
                float(window[s]), -p._est_wait(s), -s))
        soon = [s for s in cands
                if t < float(nxt[s]) <= t + self.lookahead_s]
        if soon:
            return min(soon, key=lambda s: (
                float(nxt[s]), p._est_wait(s), s))
        carbon = p._carbon(t)
        return min(cands, key=lambda s: (
            p._est_wait(s), bool(not green[s]), float(carbon[s]), s))


class CarbonSloKernel(_Kernel):
    """Mirror of :class:`~repro.core.serving.CarbonSloRouter`, scoring
    the surviving candidate axis in one ``grid_carbon_g_rows`` call
    (the elementwise mirror of the scalar per-site query), fault vetoes
    and proactive shed included."""

    def __init__(self, plane: "ChunkedServingPlane", slo_margin: float,
                 proactive_shed: bool):
        super().__init__(plane)
        self.slo_margin = float(slo_margin)
        self.proactive_shed = bool(proactive_shed)

    def route(self, batch: _Batch, t: float) -> int:
        p = self.plane
        fc = p._forecast
        origin = batch.origin
        adl = p._adl
        deadline = min(adl[i] for i in batch.idx)
        budget = t + self.slo_margin * max(deadline - t, 0.0)
        svc = batch.nominal
        bits = p._cls_bits[batch.ci] * len(batch.idx)
        rep = fc.site_repair_grid(t) if fc is not None else None
        nf = fc.next_fault_start_grid(t) if rep is not None else None
        flows: Optional[list] = None
        cand_s: List[int] = []
        cand_start: List[float] = []
        for s in self._cands(batch):
            if s == origin:
                xfer = 0.0
            else:
                if flows is None:
                    flows = p._all_flow_pairs()
                rate = p.topo.post_admission_rate(origin, s, flows, t)
                xfer = bits / rate if rate > 0.0 else INF
                if xfer == INF:
                    continue
                if rep is not None and (rep[s] > 0.0 or rep[origin] > 0.0):
                    continue  # endpoint blacked out right now
                if fc is not None and fc.next_outage_start_s(
                        origin, s, t) < t + xfer:
                    continue
                if nf is not None and nf[origin, s] < t + xfer:
                    continue  # hard fault forecast to cut the link
            cand_s.append(s)
            cand_start.append(t + xfer + p._est_wait(s))
        best, best_key = origin, None
        if cand_s:
            # ``grid_carbon_g_rows`` is the documented elementwise mirror
            # of ``grid_carbon_g`` but carries fixed numpy broadcast
            # overhead (~0.2 ms) that only amortizes over wide candidate
            # axes; below the threshold the scalar integral per candidate
            # is ~5x cheaper and trivially bit-identical (same function
            # the oracle router calls)
            if fc is None:
                grams: Sequence[float] = [0.0] * len(cand_s)
            elif len(cand_s) >= 16:
                starts = np.asarray(cand_start, dtype=np.float64)
                grams = fc.grid_carbon_g_rows(
                    np.asarray(cand_s, dtype=np.int64), starts,
                    starts + svc, p._p_kw)
            else:
                grams = [fc.grid_carbon_g(s, st, st + svc, p._p_kw)
                         for s, st in zip(cand_s, cand_start)]
            for k, s in enumerate(cand_s):
                est_start = cand_start[k]
                est_done = est_start + svc
                key = (not (est_done <= budget), float(grams[k]),
                       est_done, s)
                if best_key is None or key < best_key:
                    best, best_key = s, key
        if (self.proactive_shed and rep is not None
                and best_key is not None and best_key[0]):
            return SHED
        return best


def make_kernel(router: Router,
                plane: "ChunkedServingPlane") -> Optional[_Kernel]:
    """The kernel mirror for ``router``, or None when the router has no
    mirror (custom routers fall back to the per-event scalar plane)."""
    if type(router) is NearestRouter:
        return NearestKernel(plane)
    if type(router) is GreenFirstRouter:
        return GreenFirstKernel(plane, router.lookahead_s, router.min_gbps)
    if type(router) is CarbonSloRouter:
        return CarbonSloKernel(plane, router.slo_margin,
                               router.proactive_shed)
    return None


def supports_router(router: Router) -> bool:
    """Whether the chunked plane has a bit-exact kernel for ``router``
    (exact built-in types only — subclasses may override ``route``)."""
    return type(router) in (NearestRouter, GreenFirstRouter,
                            CarbonSloRouter)


# ---------------------------------------------------------------------------
# The chunked plane
# ---------------------------------------------------------------------------


class ChunkedServingPlane:
    """Drop-in :class:`~repro.core.serving.ServingPlane` replacement
    exposing the same engine protocol (``next_event_s`` / ``process`` /
    ``pending`` / ``flow_pairs`` / ``rerate`` / ``crash_replica`` /
    ``repair_replica`` / counters) plus :meth:`process_span`, the
    span-advance entry point the engine's fast path calls.

    The simulator wires run context post-construction via
    :meth:`bind_context` (forecast horizon + live migration pairs);
    until then the plane routes everything to the origin, mirroring a
    scalar plane with no ``state_fn`` bound.
    """

    def __init__(
        self,
        profile: ServingProfile,
        router: Router,
        *,
        n_sites: int,
        days: int,
        seed: int,
        topo,
        traces: Sequence,
        signals: Optional[GridSignals] = None,
        ledger: Optional[PowerLedger] = None,
    ):
        self.profile = profile
        self.router = router  # config source; the kernel mirrors it
        self.n_sites = n_sites
        self.topo = topo
        self.traces = traces
        self.signals = signals
        self.ledger = ledger if ledger is not None else PowerLedger(
            n_sites, signals=signals, traces=traces)
        kern = make_kernel(router, self)
        if kern is None:
            raise ValueError(
                f"no chunked kernel for router {router.name!r}; use the "
                "per-event plane (serving_engine='event')")
        self._kernel = kern
        self._bound = False  # bind_context enables routing (like bind())
        self._forecast = None
        self._mig_pairs_fn: Callable[[], List[Tuple[int, int]]] = list
        self._stack = stack_traces(traces)
        self._reach = [
            [s == o or bool(topo.reachable(o, s)) for s in range(n_sites)]
            for o in range(n_sites)]
        self._zero_carbon = np.zeros(n_sites)
        # columnar arrivals (+ python-list mirrors for the hot scan)
        self.events = generate_request_events(profile, n_sites, days,
                                              seed=seed)
        self._at: List[float] = self.events.t_s.tolist()
        self._ao: List[int] = self.events.origin.tolist()
        self._ac: List[int] = self.events.cls_idx.tolist()
        self._adl: List[float] = self.events.deadline_s.tolist()
        self._n_arr = len(self._at)
        self._ptr = 0
        # per-class scalars
        classes = profile.model_classes
        self._cls_batch_s = [float(c.batch_s) for c in classes]
        self._cls_per_req_s = [float(c.per_req_s) for c in classes]
        self._cls_bits = [8.0 * float(c.req_bytes) for c in classes]
        self._max_batch = int(profile.max_batch)
        self._timeout = float(profile.batch_timeout_s)
        self._max_q = int(profile.max_queue_batches)
        self._p_kw = float(profile.p_serve_kw)
        # jitter: block-drawn (bit-identical to sequential scalar draws)
        self._jrng = np.random.default_rng([seed, _RNG_TAG, 10 ** 6])
        self._jit_buf: Optional[List[float]] = None
        self._jit_i = 0
        self._sigma = float(profile.jitter_frac)
        # free-flow merge support (origin-only routing regime)
        self._ncls = len(classes)
        self._ff_router = type(kern) is NearestKernel
        self._ffs: Optional[list] = None
        self._ff_oc: Optional[List[Tuple[int, int]]] = None
        # batch formation / queues / replicas (python-native hot state)
        self._open: Dict[Tuple[int, int], _Batch] = {}
        self._batches: Dict[int, _Batch] = {}
        self._next_bid = 0
        self._close_heap: List[Tuple[float, int]] = []
        self._queues: List[deque] = [deque() for _ in range(n_sites)]
        self._qreqs: List[int] = [0] * n_sites
        self._pend: List[float] = [0.0] * n_sites
        self._repl: List[int] = [profile.replicas_at(s)
                                 for s in range(n_sites)]
        self._busy: List[int] = [0] * n_sites
        # WAN flows
        self._flows: Dict[int, _Flow] = {}
        self._next_fid = 0
        self._flow_heap: List[Tuple[float, int, int]] = []
        self._svc_heap: List[Tuple[float, int]] = []
        # counters / accounting
        self.arrived = 0
        self.served = 0
        self.dropped = 0
        self.shed = 0
        self.slo_violations = 0
        self.latencies: List[float] = []
        self.queue_samples: List[int] = []
        self._site_served: List[int] = [0] * n_sites
        self._site_routed: List[int] = [0] * n_sites
        self._in_system = 0
        self._area_t = 0.0
        self.area_request_s = 0.0
        self._timing: Optional[Dict[str, float]] = None
        # deferred service billing: merged spans buffer their bills and
        # the ledger drains them (via the registered sync hook) before
        # any other posting or audit, so the global add order onto the
        # shared accumulators is exactly the per-event order
        self._bill_site: List[int] = []
        self._bill_t0: List[float] = []
        self._bill_t1: List[float] = []
        self.ledger._serve_sync = self._flush_bills

    # -- wiring --------------------------------------------------------------
    def bind_context(self, *, forecast=None,
                     mig_pairs_fn: Optional[Callable[
                         [], List[Tuple[int, int]]]] = None) -> None:
        """Attach run context: the forecast horizon (carbon / outage /
        fault grids for the kernels) and a live in-flight-migration
        pair provider (post-admission estimates share the WAN split
        with checkpoint transfers).  Enables routing."""
        self._forecast = forecast
        if mig_pairs_fn is not None:
            self._mig_pairs_fn = mig_pairs_fn
        self._bound = True

    def enable_timing(self) -> Dict[str, float]:
        """Turn on the per-event-class wall breakdown (same keys as the
        scalar plane) and return the live accumulator dict."""
        if self._timing is None:
            self._timing = {"arrivals_s": 0.0, "batch_close_s": 0.0,
                            "flow_s": 0.0, "service_s": 0.0,
                            "router_s": 0.0, "chunk_s": 0.0}
        return self._timing

    # -- kernel-facing helpers -----------------------------------------------
    def _est_wait(self, s: int) -> float:
        r = self._repl[s]
        return self._pend[s] / r if r > 0 else INF

    def _carbon(self, t: float) -> np.ndarray:
        fc = self._forecast
        return fc.carbon_grid(t) if fc is not None else self._zero_carbon

    def _all_flow_pairs(self) -> List[Tuple[int, int]]:
        """Migration pairs + serve-flow pairs, the exact flow set the
        scalar ``_serving_state`` snapshot would carry."""
        pairs = list(self._mig_pairs_fn())
        for f in self._flows.values():
            pairs.append((f.src, f.dst))
        return pairs

    # -- event interface -----------------------------------------------------
    def _heap_min(self) -> float:
        """Earliest valid close/flow/service event (lazy invalidation,
        mirror of the scalar ``next_event_s`` heap peeks)."""
        m = INF
        ch = self._close_heap
        while ch:
            tc, bid = ch[0]
            b = self._batches.get(bid)
            if b is not None and b.site < 0:
                m = tc
                break
            heapq.heappop(ch)
        fh = self._flow_heap
        while fh:
            tf, fid, ver = fh[0]
            f = self._flows.get(fid)
            if f is not None and f.ver == ver:
                if tf < m:
                    m = tf
                break
            heapq.heappop(fh)
        sh = self._svc_heap
        if sh and sh[0][0] < m:
            m = sh[0][0]
        return m

    def next_event_s(self) -> float:
        t = self._at[self._ptr] if self._ptr < self._n_arr else INF
        hm = self._heap_min()
        return hm if hm < t else t

    def pending(self) -> bool:
        return self._ptr < self._n_arr or self._in_system > 0

    def process(self, t: float, eps: float = 1e-6) -> bool:
        """Generic event mirror of the scalar ``process`` (arrivals →
        closes → flow landings → service completions → starts).  The
        engine calls this on the slow path (serving events coalescing
        with engine events); :meth:`process_span` calls it for events
        not strictly clear of each other."""
        flows_dirty = False
        tm = self._timing
        if tm is not None:
            _t0 = time.perf_counter()
        # 1) arrivals -> batch formation (max-batch closes route now)
        at = self._at
        while self._ptr < self._n_arr and at[self._ptr] <= t + eps:
            i = self._ptr
            self._ptr += 1
            self.arrived += 1
            self._bump_area(t)
            self._in_system += 1
            o = self._ao[i]
            key = (o, self._ac[i])
            b = self._open.get(key)
            if b is None:
                b = _Batch(self._next_bid, o, key[1], [i], t)
                self._next_bid += 1
                self._batches[b.bid] = b
                self._open[key] = b
                heapq.heappush(self._close_heap,
                               (t + self._timeout, b.bid))
            else:
                b.idx.append(i)
            if len(b.idx) >= self._max_batch:
                self._open.pop(key, None)
                flows_dirty |= self._dispatch(b, t) == _FLOW
        if tm is not None:
            _t1 = time.perf_counter()
            tm["arrivals_s"] += _t1 - _t0
            _t0 = _t1
        # 2) batch-close timeouts
        while self._close_heap and self._close_heap[0][0] <= t + eps:
            _, bid = heapq.heappop(self._close_heap)
            b = self._batches.get(bid)
            if b is None or b.site >= 0:
                continue  # already dispatched at max size
            self._open.pop((b.origin, b.ci), None)
            flows_dirty |= self._dispatch(b, t) == _FLOW
        if tm is not None:
            _t1 = time.perf_counter()
            tm["batch_close_s"] += _t1 - _t0
            _t0 = _t1
        # 3) WAN flow landings: the routed batch reaches its queue
        while self._flow_heap and self._flow_heap[0][0] <= t + eps:
            _, fid, ver = heapq.heappop(self._flow_heap)
            f = self._flows.get(fid)
            if f is None or f.ver != ver:
                continue
            self._flush_flow(f, t)
            self._flows.pop(fid, None)
            flows_dirty = True
            self._enqueue(f.batch, f.dst, t)
        if tm is not None:
            _t1 = time.perf_counter()
            tm["flow_s"] += _t1 - _t0
            _t0 = _t1
        # 4) service completions
        while self._svc_heap and self._svc_heap[0][0] <= t + eps:
            _, bid = heapq.heappop(self._svc_heap)
            b = self._batches.pop(bid)
            self._complete_service(b, t)
        self._start_services(t)
        if tm is not None:
            tm["service_s"] += time.perf_counter() - _t0
        if self.profile.validate:
            self.audit()
        return flows_dirty

    def process_span(self, limit: float, t_end: float,
                     eps: float = 1e-6) -> Tuple[int, float, bool]:
        """Advance through every serving event with ``t < limit`` (and
        ``t <= t_end``), stopping early when a dispatch opens a WAN
        flow.  Returns ``(n_events, t_last, flows_dirty)`` where
        ``n_events`` counts distinct event times (engine iterations the
        per-event path would have spent) and ``t_last`` is the time of
        the last processed event.

        The caller (the engine) passes ``limit = t_other - EPS`` where
        ``t_other`` is its earliest non-serving event, so any serving
        event that could coalesce with an engine event is left for the
        engine's normal per-event path — coalescing semantics are
        untouched.
        """
        n_ev = 0
        t_last = 0.0
        at = self._at
        n_arr = self._n_arr
        validate = self.profile.validate
        tm = self._timing
        # free-flow merge: when routing is origin-only (nearest kernel,
        # or unbound) and no WAN flow is in flight, the whole chunk
        # collapses to a deterministic arrivals/closes/completions merge
        try_ff = ((self._ff_router or not self._bound) and not validate)
        while True:
            if try_ff and not self._flows:
                if self._flow_heap:
                    self._flow_heap.clear()  # flows empty: all dead
                if tm is not None:
                    _tf = time.perf_counter()
                nf, tl = self._ff_merge(limit, t_end, eps)
                if tm is not None:
                    tm["chunk_s"] += time.perf_counter() - _tf
                if nf:
                    n_ev += nf
                    t_last = tl
                else:
                    try_ff = False  # zero progress: stop thrashing
            hmin = self._heap_min()
            ptr = self._ptr
            # -- inlined arrival runs: pure arrivals strictly clear (by
            # eps) of every heap event take the light path
            if ptr < n_arr:
                ta = at[ptr]
                if ta + eps < hmin and ta < limit and ta <= t_end:
                    if tm is not None:
                        _t0 = time.perf_counter()
                    r = self._arrival_run(hmin, limit, t_end, eps)
                    n_run, t_last2, dirty = r
                    if tm is not None:
                        tm["arrivals_s"] += time.perf_counter() - _t0
                    if n_run:
                        n_ev += n_run
                        t_last = t_last2
                        if validate:
                            self.audit()
                        if dirty:
                            return n_ev, t_last, True
                        continue
            # -- next event (arrival exhausted the light path: it ties
            # with a heap event, or a heap event comes first)
            ptr = self._ptr
            tn = at[ptr] if ptr < n_arr else INF
            if hmin < tn:
                tn = hmin
            if tn >= limit or tn > t_end:
                return n_ev, t_last, False
            # -- inlined isolated close / service completions
            code = self._try_inline_event(tn, eps)
            if code >= 0:
                n_ev += 1
                t_last = tn
                if validate:
                    self.audit()
                if code == 1:
                    return n_ev, t_last, True
                continue
            # -- generic mirror for coalescing events
            n_ev += 1
            t_last = tn
            if self.process(tn, eps):
                return n_ev, t_last, True

    def _arrival_run(self, hmin: float, limit: float, t_end: float,
                     eps: float) -> Tuple[int, float, bool]:
        """Consume consecutive arrival events while each is strictly
        clear of every heap event.  Returns (events, t_last, dirty);
        maintains ``hmin`` across close/service pushes it causes."""
        at, ao, ac = self._at, self._ao, self._ac
        n_arr = self._n_arr
        openb = self._open
        timeout = self._timeout
        max_batch = self._max_batch
        n_ev = 0
        t_last = 0.0
        ptr = self._ptr
        while ptr < n_arr:
            ta = at[ptr]
            if not (ta + eps < hmin and ta < limit and ta <= t_end):
                break
            # one event time: consume every arrival within eps of it
            # (all are clear of heap events since ta + eps < hmin)
            touched: Optional[List[int]] = None
            dirty = False
            while ptr < n_arr and at[ptr] <= ta + eps:
                i = ptr
                ptr += 1
                self.arrived += 1
                # _bump_area(ta): after the first bump the gap is 0
                self.area_request_s += self._in_system * (ta - self._area_t)
                self._area_t = ta
                self._in_system += 1
                o = ao[i]
                key = (o, ac[i])
                b = openb.get(key)
                if b is None:
                    b = _Batch(self._next_bid, o, key[1], [i], ta)
                    self._next_bid += 1
                    self._batches[b.bid] = b
                    openb[key] = b
                    tc = ta + timeout
                    heapq.heappush(self._close_heap, (tc, b.bid))
                    if tc < hmin:
                        hmin = tc
                else:
                    b.idx.append(i)
                if len(b.idx) >= max_batch:
                    openb.pop(key, None)
                    r = self._dispatch(b, ta)
                    if r == _FLOW:
                        dirty = True
                    elif r >= 0:
                        if touched is None:
                            touched = [r]
                        elif r not in touched:
                            touched.append(r)
            self._ptr = ptr
            n_ev += 1
            t_last = ta
            if touched is not None:
                # ascending site order = the scalar _start_services scan
                for s in sorted(touched):
                    td = self._start_site(s, ta)
                    if td < hmin:
                        hmin = td
            if dirty:
                return n_ev, t_last, True
        self._ptr = ptr
        return n_ev, t_last, False

    def _try_inline_event(self, tn: float, eps: float) -> int:
        """Handle an isolated batch-close or service completion at
        ``tn`` without the generic mirror.  Returns -1 when the event
        is not isolated (caller must use :meth:`process`), 0 when
        handled, 1 when handled and the WAN flow set changed."""
        ta = self._at[self._ptr] if self._ptr < self._n_arr else INF
        if ta <= tn + eps:
            return -1
        ch = self._close_heap
        fh = self._flow_heap
        sh = self._svc_heap
        tc = ch[0][0] if ch else INF  # tops are valid (heap_min cleaned)
        tf = fh[0][0] if fh else INF
        ts = sh[0][0] if sh else INF
        if tf <= tn + eps:
            return -1  # flow landings stay on the generic path (rare)
        if tc == tn:
            # isolated close: no second close / svc within eps
            if ts <= tn + eps:
                return -1
            _, bid = heapq.heappop(ch)
            b = self._batches.get(bid)
            if b is not None and b.site < 0:
                if ch and ch[0][0] <= tn + eps:
                    # another close (possibly stale) ties: replay both
                    # through the generic path for exact coalescing
                    heapq.heappush(ch, (tn, bid))
                    return -1
                tm = self._timing
                if tm is not None:
                    _t0 = time.perf_counter()
                self._open.pop((b.origin, b.ci), None)
                r = self._dispatch(b, tn)
                if r >= 0:
                    self._start_site(r, tn)
                if tm is not None:
                    tm["batch_close_s"] += time.perf_counter() - _t0
                return 1 if r == _FLOW else 0
            # stale top (unreachable: _heap_min validated it) — popping
            # it was harmless; let the generic path resolve the time
            return -1
        if ts == tn and tc > tn + eps:
            # isolated service completion: no second svc within eps
            if len(sh) > 1:
                # peek the runner-up without a full sort: heap children
                second = min(sh[1][0], sh[2][0]) if len(sh) > 2 else sh[1][0]
                if second <= tn + eps:
                    return -1
            tm = self._timing
            if tm is not None:
                _t0 = time.perf_counter()
            _, bid = heapq.heappop(sh)
            b = self._batches.pop(bid)
            self._complete_service(b, tn)
            self._start_site(b.site, tn)
            if tm is not None:
                tm["service_s"] += time.perf_counter() - _t0
            return 0
        return -1

    # -- free-flow merge (origin-only routing regime) ------------------------
    def _ff_build_streams(self) -> list:
        """Per-(origin, class) arrival sub-streams plus their *global
        batch-unit partition*.  With origin-only routing a batch opens
        at its stream's first pending arrival, absorbs arrivals until
        ``batch_timeout_s`` later (or ``max_batch`` members), and the
        next batch opens at the following arrival — so the partition of
        each stream into batch units is a pure function of the arrival
        arrays, fixed for the whole run no matter which path (merge or
        per-event replay) processes any given span.  Computing it once
        turns per-span segmentation into a bisect plus precomputed
        slices.

        Each stream entry is ``(gix, gts, ust, uend, ut0, utc, ufill,
        utfl)``: global indices, times, unit start/end positions, unit
        open/close times, max-batch fill flags and fill-arrival times
        (+inf when the unit does not fill)."""
        ev = self.events
        ncls = self._ncls
        timeout = self._timeout
        mb = self._max_batch
        key = ev.origin.astype(np.int64) * ncls + ev.cls_idx
        order = np.argsort(key, kind="stable")
        ks = key[order]
        bounds = np.searchsorted(ks, np.arange(self.n_sites * ncls + 1))
        t_sorted = ev.t_s[order]
        streams = []
        for k in range(self.n_sites * ncls):
            lo, hi = int(bounds[k]), int(bounds[k + 1])
            gix = order[lo:hi].tolist()
            tnp = t_sorted[lo:hi]
            ns = hi - lo
            if ns == 0:
                streams.append((gix, [], [], [], [], [], [], [], [],
                                [], []))
                continue
            nxt = np.searchsorted(tnp, tnp + timeout)
            nxt_l = nxt.tolist()
            ust = []
            i = 0
            while i < ns:
                ust.append(i)
                j = nxt_l[i]
                if j - i >= mb:
                    i += mb  # fill: next batch opens at the next arrival
                elif j > i:
                    i = j
                else:
                    i += 1  # timeout <= 0: degenerate, merge aborts anyway
            ua = np.asarray(ust, dtype=np.int64)
            ut0 = tnp[ua]
            nxtu = nxt[ua]
            ufill = (nxtu - ua) >= mb
            uend = np.where(ufill, ua + mb, nxtu)
            utfl = np.where(
                ufill, tnp[np.minimum(ua + mb - 1, ns - 1)], INF)
            ci = k % ncls
            unom = (self._cls_batch_s[ci]
                    + self._cls_per_req_s[ci] * (uend - ua))
            uend_l = uend.tolist()
            ut0_l = ut0.tolist()
            utc_l = (ut0 + timeout).tolist()
            unom_l = unom.tolist()
            # per-unit close records, C-built: the merge's segmentation
            # slices these directly instead of walking units in python
            urecs = list(zip(utc_l, repeat(k), repeat(k // ncls),
                             repeat(ci), ust, uend_l, unom_l, ut0_l))
            streams.append((gix, tnp.tolist(), ust, uend_l,
                            ut0_l, utc_l,
                            ufill.tolist(), utfl.tolist(),
                            unom_l, np.nonzero(ufill)[0].tolist(),
                            urecs))
        self._ffs = streams
        self._ff_oc = [(k // ncls, k % ncls)
                       for k in range(self.n_sites * ncls)]
        return streams

    def _ff_merge(self, limit: float, t_end: float,
                  eps: float) -> Tuple[int, float]:
        """Advance through the chunk's arrivals / batch closes / service
        completions as one three-way time merge, with no per-event heap
        or dispatch machinery.  Valid only while every dispatch resolves
        to the batch origin — i.e. the nearest kernel with a non-full
        origin queue, or an unbound plane.  Any situation outside that
        regime (a full origin queue under the nearest kernel, a
        max-batch fill, or two events within ``eps`` of each other,
        which the scalar path would coalesce into one tick) stops the
        merge *before* the first affected event; the caller's per-event
        paths replay it with exact scalar semantics.

        Batch membership is precomputed per (origin, class) stream:
        with origin-only routing a batch opens at its stream's first
        pending arrival and closes ``batch_timeout_s`` later, so the
        member set is a pure function of the arrival arrays.  Jitter
        draws happen at service starts in event order (the scalar
        order), billing is buffered in completion order and flushed
        through :meth:`PowerLedger.post_serve_block`, and the ∫N dt
        area integral advances event-by-event with the scalar's exact
        add sequence.  Returns ``(n_events, t_last)``.
        """
        # -- entry invariant: queued work implies every replica is busy
        # (guaranteed by the scalar protocol; checked defensively)
        qs = self._queues
        busy = self._busy
        repl = self._repl
        for s in range(self.n_sites):
            if qs[s] and busy[s] < repl[s]:
                return 0, 0.0
        stop = limit if limit <= t_end else math.nextafter(t_end, INF)
        at, adl = self._at, self._adl
        n_arr = self._n_arr
        ap = self._ptr
        streams = self._ffs
        if streams is None:
            streams = self._ff_build_streams()
        ncls = self._ncls
        max_batch = self._max_batch
        bl = bisect.bisect_left
        oc = self._ff_oc
        openb = self._open
        # -- segmentation over the precomputed global unit partition:
        # locate each stream's first pending unit, collect the closes
        # that land in-span (unit close times are monotone per stream —
        # close = open + timeout — so the walk stops at the first one
        # beyond the cutoff) and the earliest max-batch fill, which the
        # merge cannot dispatch and therefore bounds the span.
        abort_at = INF
        imp: Dict[int, Tuple[_Batch, int]] = {}
        nstr = len(streams)
        p0s = [-1] * nstr
        recs: List[tuple] = []
        for k in range(nstr):
            g = streams[k]
            gix = g[0]
            ust = g[2]
            if not ust:
                continue
            i0 = bl(gix, ap)
            ob = openb.get(oc[k])
            if ob is not None:
                pu = bl(ust, i0) - 1
                if pu < 0 or g[4][pu] != ob.opened_s:
                    return 0, 0.0  # partition drift: replay per-event
                imp[k] = (ob, i0)
            else:
                ns = len(gix)
                if i0 >= ns or g[1][i0] >= stop:
                    continue
                pu = bl(ust, i0 + 1) - 1
                if pu < 0 or ust[pu] != i0:
                    return 0, 0.0  # partition drift: replay per-event
            p0s[k] = pu
            # in-span closes are a contiguous unit range [pu, pe),
            # truncated at the first max-batch fill unit (the walk the
            # scalar would do checks fill *before* the cutoff, so a
            # fill unit reached at pe still bounds the span)
            pe = bl(g[5], stop, pu)
            fpos = g[9]
            if fpos:
                fj = bl(fpos, pu)
                if fj < len(fpos):
                    fp = fpos[fj]
                    if fp <= pe:
                        tf = g[7][fp]
                        if tf < abort_at:
                            abort_at = tf
                        pe = fp
            if pe > pu:
                recs.extend(g[10][pu:pe])
        # closes are chronological once merged across streams; within a
        # span dispatch order equals batch-open order (close = open +
        # constant timeout), so new bids are assigned sequentially at
        # dispatch — exactly the scalar's open-order numbering
        base_bid = self._next_bid
        nbid = base_bid
        recs.sort()
        rec_tc = [r[0] for r in recs]
        n_rec = len(recs)
        # -- pending service completions (pre-chunk in-flight included)
        dones = []
        for td, bid in self._svc_heap:
            bb = self._batches[bid]
            dones.append((td, bid, bb.site, bb.idx,
                          bb.t_service_start_s, bb))
        heapq.heapify(dones)
        self._svc_heap = []
        if abort_at < stop:
            stop = abort_at
        # -- hot locals
        qreqs = self._qreqs
        pend = self._pend
        routed = self._site_routed
        servedl = self._site_served
        lats = self.latencies
        qsamp = self.queue_samples
        cbs = self._cls_batch_s
        cps = self._cls_per_req_s
        max_q = self._max_q
        full_q_aborts = self._bound  # nearest scans remotes when full
        jrng = self._jrng
        sigma = self._sigma
        jl = self._jit_buf
        ji = self._jit_i
        if jl is None:
            # eager first fill: identical rng consumption to the lazy
            # fill `_next_jitter` would do at the first draw
            jl = self._jit_buf = np.exp(
                jrng.normal(0.0, sigma, 4096)).tolist()
            ji = 0
        batches = self._batches
        openb = self._open
        heappush = heapq.heappush
        heappop = heapq.heappop
        bill_site: List[int] = []
        bill_t0: List[float] = []
        bill_t1: List[float] = []
        served = self.served
        dropped = self.dropped
        viol = 0
        in_sys = self._in_system
        area = self.area_request_s
        area_t = self._area_t
        ap0 = ap
        nd = 0
        t_last = 0.0
        cp = 0
        B = stop
        aborted = False
        while True:
            ta = at[ap] if ap < n_arr else INF
            tcv = rec_tc[cp] if cp < n_rec else INF
            tdv = dones[0][0] if dones else INF
            if ta <= tcv and ta <= tdv:
                if ta >= stop:
                    break
                # -- run of consecutive arrivals: hoist the close/done
                # bound out of the per-event loop (scalar add order for
                # the area integral is preserved exactly)
                bound = tcv if tcv <= tdv else tdv
                lim = stop if stop <= bound else bound
                rs = ap
                while True:
                    nxt = at[ap + 1] if ap + 1 < n_arr else INF
                    nx = nxt if nxt < bound else bound
                    if nx - ta <= eps:
                        B = ta
                        aborted = True
                        break
                    area += in_sys * (ta - area_t)
                    area_t = ta
                    in_sys += 1
                    ap += 1
                    if nxt >= lim:
                        break
                    ta = nxt
                if ap > rs:
                    t_last = at[ap - 1]
                if aborted:
                    break
                continue
            if tcv <= tdv:
                te = tcv
                if te >= stop:
                    break
                nx = rec_tc[cp + 1] if cp + 1 < n_rec else INF
                if ta < nx:
                    nx = ta
                if tdv < nx:
                    nx = tdv
                if nx - te <= eps:
                    B = te
                    break
                rec = recs[cp]
                o = rec[2]
                q = qs[o]
                qn = len(q)
                if qn >= max_q and full_q_aborts:
                    B = te  # the nearest router would scan remote sites
                    break
                cp += 1
                k = rec[1]
                e_ = rec[5]
                impk = imp.pop(k, None) if imp else None
                if impk is not None:
                    ob, i_s = impk
                    mem = ob.idx + streams[k][0][i_s:e_]
                    bid = ob.bid
                    openb.pop(oc[k], None)
                else:
                    ob = None
                    mem = streams[k][0][rec[4]:e_]
                    bid = nbid
                    nbid += 1
                n = len(mem)
                nominal = rec[6]
                routed[o] += n
                if qn >= max_q:
                    dropped += n
                    area += in_sys * (te - area_t)
                    area_t = te
                    in_sys -= n
                    if ob is not None:
                        batches.pop(bid, None)
                    t_last = te
                    continue
                qsamp.append(qreqs[o] + n)
                if busy[o] < repl[o]:
                    # queue is empty here (entry invariant + merge
                    # dynamics), so this batch starts immediately
                    pend[o] += nominal
                    pend[o] -= nominal
                    busy[o] += 1
                    if ji >= 4096:
                        jl = self._jit_buf = np.exp(
                            jrng.normal(0.0, sigma, 4096)).tolist()
                        ji = 0
                    jit = jl[ji]
                    ji += 1
                    svc = nominal * jit
                    if ob is not None:
                        # survivors must look exactly as the per-event
                        # path would have left them
                        ob.idx = mem
                        ob.nominal = nominal
                        ob.site = o
                        ob.t_service_start_s = te
                        ob.service_s = svc
                    heappush(dones, (te + svc, bid, o, mem, te, ob,
                                     svc, rec[3], rec[7], nominal))
                else:
                    qreqs[o] += n
                    pend[o] += nominal
                    if ob is not None:
                        bb = ob
                        bb.idx = mem
                    else:
                        bb = _Batch(bid, o, rec[3], mem, rec[7])
                    bb.nominal = nominal
                    bb.site = o
                    # register like the per-event dispatch does: a
                    # queued batch must be reachable through
                    # ``_batches`` when ``_start_site`` later pushes
                    # its bid onto the service heap
                    batches[bid] = bb
                    q.append(bb)
                t_last = te
                continue
            te = tdv
            if te >= stop:
                break
            d = heappop(dones)
            nx = dones[0][0] if dones else INF
            if ta < nx:
                nx = ta
            if tcv < nx:
                nx = tcv
            if nx - te <= eps:
                heappush(dones, d)
                B = te
                break
            s = d[2]
            mem = d[3]
            busy[s] -= 1
            n = len(mem)
            served += n
            servedl[s] += n
            area += in_sys * (te - area_t)
            area_t = te
            in_sys -= n
            if n == 1:
                gi = mem[0]
                lats.append(te - at[gi])
                if te > adl[gi]:
                    viol += 1
            else:
                for gi in mem:
                    lats.append(te - at[gi])
                    if te > adl[gi]:
                        viol += 1
            bill_site.append(s)
            bill_t0.append(d[4])
            bill_t1.append(te)
            batches.pop(d[1], None)
            q = qs[s]
            if q:
                b2 = q.popleft()
                mem2 = b2.idx
                nom2 = b2.nominal
                qreqs[s] -= len(mem2)
                pend[s] -= nom2
                busy[s] += 1
                if ji >= 4096:
                    jl = self._jit_buf = np.exp(
                        jrng.normal(0.0, sigma, 4096)).tolist()
                    ji = 0
                jit = jl[ji]
                ji += 1
                svc = nom2 * jit
                b2.t_service_start_s = te
                b2.service_s = svc
                heappush(dones, (te + svc, b2.bid, b2.site, mem2,
                                 te, b2))
            nd += 1
            t_last = te
        # -- write back scalars
        n_ev = (ap - ap0) + cp + nd
        self._ptr = ap
        self._jit_i = ji
        self.arrived += ap - ap0
        self.served = served
        self.dropped = dropped
        self.slo_violations += viol
        self._in_system = in_sys
        self.area_request_s = area
        self._area_t = area_t
        # -- rebuild the service heap from unfinished work (lazily
        # materializing batch objects the merge never had to build)
        sh = self._svc_heap
        for d in dones:
            bid = d[1]
            bb = d[5]
            if bb is None:
                bb = _Batch(bid, d[2], d[7], d[3], d[8])
                bb.site = d[2]
                bb.nominal = d[9]
                bb.t_service_start_s = d[4]
                bb.service_s = d[6]
            batches[bid] = bb
            sh.append((d[0], bid))
        heapq.heapify(sh)
        # -- re-materialize batches left open at the boundary (at most
        # one per stream: unit intervals are disjoint in time).  New
        # boundary-open units take their bids *after* every in-span
        # dispatch — any unit opening after a dispatched one also
        # closes after it (close = open + constant timeout), so the
        # scalar's open-order numbering is dispatch bids first, then
        # boundary-open units by open time.
        cands = []
        for k in range(nstr):
            pu = p0s[k]
            if pu < 0:
                continue
            g = streams[k]
            ut0l = g[4]
            p = bl(ut0l, B, pu) - 1
            if p < pu:
                continue
            fill = g[6][p]
            if not fill and g[5][p] < B:
                continue  # dispatched in-merge; nothing is open
            ust = g[2]
            jcap = ust[p] + max_batch - 1 if fill else g[3][p]
            impk = imp.get(k)
            if impk is not None and p == pu:
                ob, i_s = impk
                jb = bl(g[1], B, i_s, jcap)
                if jb > i_s:
                    ob.idx.extend(g[0][i_s:jb])
                continue
            i_s = ust[p]
            jb = bl(g[1], B, i_s, jcap)
            if jb > i_s:
                cands.append((ut0l[p], k, p, jb))
        cands.sort()
        for t_open, k, p, jb in cands:
            g = streams[k]
            o, ci = oc[k]
            nb = _Batch(nbid, o, ci, g[0][g[2][p]:jb], t_open)
            nbid += 1
            batches[nb.bid] = nb
            openb[oc[k]] = nb
            heappush(self._close_heap, (g[5][p], nb.bid))
        self._next_bid = nbid
        if bill_site:
            self._bill_site.extend(bill_site)
            self._bill_t0.extend(bill_t0)
            self._bill_t1.extend(bill_t1)
        return n_ev, t_last

    def _flush_bills(self) -> None:
        """Drain deferred service bills through the ledger's block
        posting.  The buffers are detached before posting, so the
        reentrant sync call from a straddle's scalar fallback is a
        no-op instead of a loop."""
        if not self._bill_site:
            return
        bs, b0, b1 = self._bill_site, self._bill_t0, self._bill_t1
        self._bill_site = []
        self._bill_t0 = []
        self._bill_t1 = []
        self.ledger.post_serve_block(bs, self._p_kw, b0, b1)

    # -- WAN flow interface (shared split with migrations) -------------------
    def flow_pairs(self) -> List[Tuple[int, int]]:
        return [(f.src, f.dst) for f in self._flows.values()]

    def rerate(self, t: float, rates: Sequence[float]) -> None:
        for f, r in zip(self._flows.values(), rates):
            self._flush_flow(f, t)
            f.rate_bps = float(r)
            f.ver += 1
            if f.rate_bps > 0.0:
                heapq.heappush(
                    self._flow_heap,
                    (t + f.remaining_bits / f.rate_bps, f.fid, f.ver))

    def _flush_flow(self, f: _Flow, t: float) -> None:
        span = t - f.anchor_s
        if span > 0.0:
            f.remaining_bits = max(0.0, f.remaining_bits - f.rate_bps * span)
        f.anchor_s = t

    # -- internals -----------------------------------------------------------
    def _dispatch(self, b: _Batch, t: float) -> int:
        """Route a closed batch.  Returns the enqueued site id, or
        ``_FLOW`` when a WAN flow started, or ``_GONE`` when the batch
        left the system (overflow drop / proactive shed)."""
        b.nominal = (self._cls_batch_s[b.ci]
                     + self._cls_per_req_s[b.ci] * len(b.idx))
        site = b.origin
        if self._bound:
            tm = self._timing
            if tm is not None:
                _t0 = time.perf_counter()
            try:
                site = int(self._kernel.route(b, t))
            except Exception:
                site = b.origin
            if tm is not None:
                tm["router_s"] += time.perf_counter() - _t0
        if site == SHED:
            self._shed(b, t)
            return _GONE
        if not 0 <= site < self.n_sites:
            site = b.origin
        if site != b.origin and not self.topo.reachable(b.origin, site):
            site = b.origin
        b.site = site
        self._site_routed[site] += len(b.idx)
        if site == b.origin:
            return self._enqueue(b, site, t)
        f = _Flow(self._next_fid, b, b.origin, site,
                  self._cls_bits[b.ci] * len(b.idx), t)
        self._next_fid += 1
        self._flows[f.fid] = f
        return _FLOW  # caller re-splits; rerate() queues the landing

    def _enqueue(self, b: _Batch, site: int, t: float) -> int:
        q = self._queues[site]
        if len(q) >= self._max_q:
            self._drop(b, t)
            return _GONE
        q.append(b)
        self._qreqs[site] += len(b.idx)
        self._pend[site] += b.nominal
        self.queue_samples.append(self._qreqs[site])
        return site

    def _drop(self, b: _Batch, t: float) -> None:
        n = len(b.idx)
        self.dropped += n
        self._bump_area(t)
        self._in_system -= n
        self._batches.pop(b.bid, None)

    def _shed(self, b: _Batch, t: float) -> None:
        n = len(b.idx)
        self.shed += n
        self._bump_area(t)
        self._in_system -= n
        self._batches.pop(b.bid, None)

    def _next_jitter(self) -> float:
        """Next lognormal jitter multiplier.  The buffer holds
        ``np.exp`` of a ``normal(0, σ, size=4096)`` block as python
        floats — bit-identical to ``float(np.exp(draw))`` per scalar
        draw (block exp verified elementwise-equal on build)."""
        i = self._jit_i
        buf = self._jit_buf
        if buf is None or i >= len(buf):
            buf = self._jit_buf = np.exp(
                self._jrng.normal(0.0, self._sigma, 4096)).tolist()
            i = 0
        self._jit_i = i + 1
        return buf[i]

    def _start_site(self, s: int, t: float) -> float:
        """Start queued batches at ``s`` while replicas are free; jitter
        draws in queue order.  Returns the earliest pushed completion
        (INF when none started)."""
        q = self._queues[s]
        first = INF
        while q and self._busy[s] < self._repl[s]:
            b = q.popleft()
            self._qreqs[s] -= len(b.idx)
            self._pend[s] -= b.nominal
            self._busy[s] += 1
            jitter = self._next_jitter()
            b.service_s = b.nominal * jitter
            b.t_service_start_s = t
            td = t + b.service_s
            heapq.heappush(self._svc_heap, (td, b.bid))
            if td < first:
                first = td
        return first

    def _start_services(self, t: float) -> None:
        for s in range(self.n_sites):
            self._start_site(s, t)

    def _complete_service(self, b: _Batch, t: float) -> None:
        s = b.site
        self._busy[s] -= 1
        n = len(b.idx)
        self.served += n
        self._site_served[s] += n
        self._bump_area(t)
        self._in_system -= n
        at, adl = self._at, self._adl
        lats = self.latencies
        viol = 0
        for i in b.idx:
            lats.append(t - at[i])
            if t > adl[i]:
                viol += 1
        self.slo_violations += viol
        self.ledger.post_serve(s, self._p_kw, b.t_service_start_s, t)

    # -- fault interface (mirror of the scalar plane) ------------------------
    def crash_replica(self, site: int, t: float) -> bool:
        s = int(site)
        self._repl[s] = 0
        flows_dirty = False
        interrupted: List[_Batch] = []
        keep: List[Tuple[float, int]] = []
        for td, bid in self._svc_heap:
            b = self._batches.get(bid)
            if b is not None and b.site == s:
                interrupted.append(b)
            else:
                keep.append((td, bid))
        if interrupted:
            heapq.heapify(keep)
            self._svc_heap = keep
        for b in interrupted:
            self._busy[s] -= 1
            self.ledger.post_serve(s, self._p_kw, b.t_service_start_s, t)
            b.t_service_start_s = -1.0
            b.service_s = 0.0
            flows_dirty |= self._dispatch(b, t) == _FLOW
        q = self._queues[s]
        if q:
            drained = list(q)
            q.clear()
            for b in drained:
                self._qreqs[s] -= len(b.idx)
                self._pend[s] -= b.nominal
                flows_dirty |= self._dispatch(b, t) == _FLOW
        self._start_services(t)
        if self.profile.validate:
            self.audit()
        return flows_dirty

    def repair_replica(self, site: int, t: float) -> bool:
        s = int(site)
        self._repl[s] = self.profile.replicas_at(s)
        self._start_services(t)
        if self.profile.validate:
            self.audit()
        return False

    # -- accounting views ----------------------------------------------------
    @property
    def serve_grid_kwh(self) -> float:
        self._flush_bills()
        return self.ledger.serve_grid_kwh

    @property
    def serve_renewable_kwh(self) -> float:
        self._flush_bills()
        return self.ledger.serve_renewable_kwh

    @property
    def request_gco2(self) -> float:
        self._flush_bills()
        return self.ledger.request_gco2

    @property
    def site_request_gco2(self) -> np.ndarray:
        self._flush_bills()
        return self.ledger.site_request_gco2

    @property
    def requests(self) -> np.ndarray:
        """Arrival-count shim matching ``ServingPlane.requests`` (the
        chunked plane keeps columnar events, not Request objects)."""
        return self.events.t_s

    @property
    def replicas(self) -> np.ndarray:
        return np.asarray(self._repl, dtype=np.int64)

    @property
    def busy(self) -> np.ndarray:
        return np.asarray(self._busy, dtype=np.int64)

    @property
    def site_served(self) -> np.ndarray:
        return np.asarray(self._site_served, dtype=np.int64)

    @property
    def site_routed(self) -> np.ndarray:
        return np.asarray(self._site_routed, dtype=np.int64)

    def _bump_area(self, t: float) -> None:
        self.area_request_s += self._in_system * (t - self._area_t)
        self._area_t = t

    @property
    def in_flight(self) -> int:
        return self._in_system

    def view(self) -> ServingView:
        repl = np.asarray(self._repl, dtype=np.int64)
        pend = np.asarray(self._pend, dtype=np.float64)
        est = np.where(repl > 0, pend / np.maximum(repl, 1), INF)
        return ServingView(
            replicas=repl,
            busy_replicas=np.asarray(self._busy, dtype=np.int64),
            queue_batches=np.array([len(q) for q in self._queues],
                                   dtype=np.int64),
            queue_requests=np.asarray(self._qreqs, dtype=np.int64),
            est_wait_s=est,
            max_queue_batches=self._max_q,
            p_serve_kw=self._p_kw,
        )

    def audit(self) -> None:
        """Same conservation invariants as the scalar plane: arrived ==
        served + dropped + shed + in-system, exactly decomposed."""
        assert self.arrived == (self.served + self.dropped + self.shed
                                + self._in_system), (
            self.arrived, self.served, self.dropped, self.shed,
            self._in_system)
        open_n = sum(len(b.idx) for b in self._open.values())
        fly_n = sum(len(f.batch.idx) for f in self._flows.values())
        q_n = sum(self._qreqs)
        svc_n = sum(len(self._batches[bid].idx)
                    for _, bid in self._svc_heap if bid in self._batches
                    and self._batches[bid].t_service_start_s >= 0.0)
        assert self._in_system == open_n + fly_n + q_n + svc_n, (
            self._in_system, open_n, fly_n, q_n, svc_n)

    def latency_percentiles(self) -> Tuple[float, float, float]:
        if not self.latencies:
            return (0.0, 0.0, 0.0)
        arr = np.asarray(self.latencies)
        p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
        return float(p50), float(p95), float(p99)

    def queue_depth_p95(self) -> float:
        if not self.queue_samples:
            return 0.0
        return float(np.percentile(np.asarray(self.queue_samples), 95.0))


__all__ = [
    "CarbonSloKernel", "ChunkedServingPlane", "GreenFirstKernel",
    "NearestKernel", "make_kernel", "supports_router",
]
