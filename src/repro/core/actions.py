"""Typed orchestration actions (the control vocabulary of §V).

The paper's published evaluation only exercises migration, but its extended
control model (§VIII: demand response, grid-aware throttling, deferral until
a renewable window) needs a richer verb set than ``(job_id, dest)`` tuples.
Every policy returns a list of these actions; the simulator validates and
applies them, counting ill-typed or stale ones in ``SimResult`` instead of
crashing mid-run.

Semantics (enforced by ``ClusterSimulator._apply_action``):

  Migrate(jid, dest)        pause -> WAN transfer -> load -> re-queue at dest.
                            Valid only for a *running* job, dest != current.
  Defer(jid, until_s)       hold a *queued* job out of FIFO scheduling until
                            sim-time ``until_s`` (wait-for-window).
  Pause(jid)                stop a *running* job and free its slot; the job
                            keeps its progress and waits for Resume.
  Resume(jid)               re-queue a *paused* job (FIFO by arrival time).
  Throttle(jid, power_frac) run a *running* job at ``power_frac`` of nominal
                            power and speed (demand response). 1.0 restores
                            full power; values are clamped to [0.0, 1.0].
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Action:
    """Base class: every action names the job it applies to."""

    jid: int


@dataclass(frozen=True)
class Migrate(Action):
    dest: int


@dataclass(frozen=True)
class Defer(Action):
    until_s: float


@dataclass(frozen=True)
class Pause(Action):
    pass


@dataclass(frozen=True)
class Resume(Action):
    pass


@dataclass(frozen=True)
class Throttle(Action):
    power_frac: float


__all__ = ["Action", "Migrate", "Defer", "Pause", "Resume", "Throttle"]
