"""Hardware power/energy classes (paper §II Table I) + TPU extension.

The paper's methodology (§II.E) derives these from public specs, not new
measurements; we encode the same mid-range values and reproduce Table I from
them (benchmarks/table1_hardware.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class HardwareClass:
    name: str
    power_kw: Tuple[float, float]  # (min, max) typical wall power
    perf_per_watt: Tuple[float, float]  # system-level TFLOPS/W (bf16-class)
    usd_per_tflop: float
    peak_tflops: float  # dense bf16-class peak per unit

    @property
    def power_typ_kw(self) -> float:
        return 0.5 * (self.power_kw[0] + self.power_kw[1])

    @property
    def perf_per_watt_typ(self) -> float:
        return 0.5 * (self.perf_per_watt[0] + self.perf_per_watt[1])


# Table I (2025 figures as printed in the paper)
TABLE_I: Dict[str, HardwareClass] = {
    "rtx4090-gpu-only": HardwareClass("RTX4090 (GPU only)", (0.45, 0.45), (0.73, 0.73), 6.0, 330.0),
    "a100-80gb-gpu-only": HardwareClass("A100 80GB (GPU only)", (0.35, 0.35), (0.78, 0.78), 38.0, 312.0),
    "rtx4090-mini-pc": HardwareClass("RTX4090 mini-PC", (0.6, 0.9), (0.37, 0.55), 8.0, 330.0),
    "4xa100-node": HardwareClass("4xA100 node", (2.0, 2.5), (0.50, 0.62), 40.0, 1248.0),
    "8xa100-dgx": HardwareClass("8xA100 DGX", (4.0, 4.5), (0.55, 0.63), 60.0, 2496.0),
    # §II.F 100 W-class edge nodes (Jetson Thor: 2070 FP4 TFLOPS, 40-130 W)
    "jetson-thor": HardwareClass("Jetson Thor edge node", (0.10, 0.15), (2.0, 4.0), 3.0, 2070.0 / 4),
    # This framework's target (DESIGN.md §10): TPU v5e, per chip.
    "tpu-v5e-chip": HardwareClass("TPU v5e (chip)", (0.25, 0.30), (0.66, 0.79), 8.0, 197.0),
}

# §II.C energy-per-sample reference points (ViT-B/32 fine-tune)
ENERGY_PER_SAMPLE_MJ = {
    "rtx4090-mini-pc": 2.7,  # 750 W system
    "4xa100-node": 6.5,  # 6-7 mJ/sample, single active GPU
}


def joules_per_sample(hw: HardwareClass, samples_per_sec: float, active_fraction: float = 1.0) -> float:
    """System-level J/sample at a given throughput (paper §II.C model)."""
    return hw.power_typ_kw * 1e3 * active_fraction / samples_per_sec


def node_energy_kwh(power_kw: float, hours: float) -> float:
    return power_kw * hours


# Paper §IV.D / §VII operating points
P_SYS_TRANSFER_KW = 1.8
P_NODE_COMPUTE_KW = 0.75
