"""Trace-driven discrete-time simulator of renewable-powered
micro-datacenters (paper §VII: 5 sites, 10 Gbps WAN, 7-day CAISO-calibrated
trace, job mix A:70% 1–6 GB / B:20% 10–40 GB / C:10% 100–300 GB).

Control flow is event-driven and typed: every ``orch_dt_s`` the simulator
builds an immutable :class:`~repro.core.state.ClusterState` snapshot (one
shared constructor with the dry-run planner and the serve router) and hands
it to ``Policy.decide``, which returns :mod:`repro.core.actions` —
``Migrate``, ``Defer(until)``, ``Pause``/``Resume`` and
``Throttle(power_frac)``.  Invalid or stale actions are counted in
``SimResult.rejected_actions``, never applied.

Models:
  * per-site GPU slots with FIFO queues (``Defer`` holds a queued job out
    of scheduling; ``Pause`` frees a slot until ``Resume``),
  * renewable windows from core/traces.py; grid vs. renewable kWh accounting
    (P_node = 0.75 kW compute — scaled by the job's ``Throttle`` fraction —
    P_sys = 1.8 kW during transfer),
  * WAN transfers with per-site NIC contention (concurrent transfers share
    the uplink — this is what stalls the energy-only policy), plus an
    optional flaky-WAN regime (hourly brownouts, see scenarios.py),
  * migration = pause → transfer → load (10.3 s) → downtime (0.4 s) →
    resume (possibly queued on arrival),
  * optional node failures with checkpoint/restart (beyond-paper).

Jobs are indexed incrementally by (site, state) bucket — the hot loop only
touches jobs whose state can change this tick, never the full job list —
which is what makes the 7-day/240-job run fast (see
``benchmarks/run.py --quick`` for the ticks/sec gate).

Scenarios: construct via ``ClusterSimulator.from_scenario("flaky-wan",
"feasibility-aware")`` or ``run_policy_comparison(scenario="paper-table6")``
— see :mod:`repro.core.scenarios` for the registry.

Deterministic for a given seed.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import feasibility as fz
from repro.core.actions import Action, Defer, Migrate, Pause, Resume, Throttle
from repro.core.orchestrator import Policy, PolicyConfig, make_policy
from repro.core.state import ClusterState, JobView, SiteView, nic_share_counts
from repro.core.traces import Forecaster, SiteTrace, TraceProfile, generate_trace

HOUR = 3600.0
GB = 1e9

# Job lifecycle. "paused" is policy-initiated (Pause action); "migrating"
# and "loading" are the two legs of a migration.
JOB_STATES = ("pending", "queued", "running", "migrating", "loading",
              "paused", "done")


@dataclass
class SimJob:
    jid: int
    arrival_s: float
    compute_s: float
    ckpt_bytes: float
    size_class: str
    home_site: int

    site: int = -1
    state: str = "pending"
    progress_s: float = 0.0
    done_s: float = -1.0
    started_s: float = -1.0
    migrations: int = 0
    failed_migrations: int = 0
    pause_s: float = 0.0  # time spent not computing due to migration
    pause_transfer_s: float = 0.0
    pause_wait_s: float = 0.0  # post-migration queue wait
    queue_s: float = 0.0
    renewable_kwh: float = 0.0
    grid_kwh: float = 0.0
    # in-flight transfer
    transfer_remaining_bits: float = 0.0
    transfer_dest: int = -1
    load_remaining_s: float = 0.0
    last_ckpt_progress_s: float = 0.0
    post_migration_wait: bool = False  # queue time after arrival counts as
    # migration-induced pause (the paper's 'stall/congestion' mode)
    last_migration_end_s: float = -1e18
    # typed-action state
    power_frac: float = 1.0  # Throttle level while running
    defer_until_s: float = -1e18  # Defer: not schedulable before this time
    paused_policy_s: float = 0.0  # time spent in policy-initiated Pause

    @property
    def jct_s(self) -> float:
        return self.done_s - self.arrival_s if self.done_s >= 0 else float("nan")


@dataclass
class SimConfig:
    n_sites: int = 5
    slots_per_site: int = 4
    wan_gbps: float = 10.0
    days: int = 7
    dt_s: float = 30.0
    orch_dt_s: float = 300.0
    seed: int = 0
    n_jobs: int = 240
    arrival_skew: Sequence[float] = (0.45, 0.1925, 0.1485, 0.121, 0.088)
    p_node_kw: float = fz.P_NODE_KW
    p_sys_kw: float = fz.P_SYS_KW
    t_load_s: float = fz.T_LOAD_S
    t_downtime_s: float = fz.T_DOWNTIME_S
    forecast_sigma_s: float = 900.0
    migration_cooldown_s: float = 900.0  # orchestrator debounce per job
    # renewable-window process (scenario-composable)
    trace: TraceProfile = field(default_factory=TraceProfile)
    # flaky-WAN regime: hourly brownouts to wan_degraded_gbps
    wan_degrade_prob: float = 0.0
    wan_degraded_gbps: float = 1.0
    # job mix (paper §VII)
    frac_a: float = 0.70
    frac_b: float = 0.20
    size_a_gb: tuple = (1.0, 6.0)
    size_b_gb: tuple = (10.0, 40.0)
    size_c_gb: tuple = (100.0, 300.0)
    mean_compute_h: float = 3.5
    # beyond-paper fault injection
    failure_rate_per_slot_hour: float = 0.0
    checkpoint_interval_s: float = 1800.0


@dataclass
class SimResult:
    policy: str
    jobs: List[SimJob]
    grid_kwh: float
    renewable_kwh: float
    migration_kwh: float
    migrations: int
    failed_migrations: int
    failures: int
    rejected_actions: int = 0
    ticks: int = 0
    wall_time_s: float = 0.0

    @property
    def mean_jct_s(self) -> float:
        vals = [j.jct_s for j in self.jobs if j.done_s >= 0]
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def completed(self) -> int:
        return sum(1 for j in self.jobs if j.done_s >= 0)

    @property
    def total_compute_s(self) -> float:
        return sum(j.progress_s for j in self.jobs)

    @property
    def migration_overhead(self) -> float:
        """Direct migration cost (transfer + load + downtime) over compute —
        the paper's 'Migr. overhead' column."""
        c = self.total_compute_s
        return (sum(j.pause_transfer_s for j in self.jobs) / c) if c else 0.0

    @property
    def stall_overhead(self) -> float:
        """Migration-induced queueing stalls over compute (the energy-only
        failure mode: §VII.E 'stalled transfers, congestion, retries')."""
        c = self.total_compute_s
        return (sum(j.pause_wait_s for j in self.jobs) / c) if c else 0.0

    @property
    def renewable_fraction(self) -> float:
        tot = self.grid_kwh + self.renewable_kwh
        return self.renewable_kwh / tot if tot else 0.0

    @property
    def ticks_per_sec(self) -> float:
        return self.ticks / self.wall_time_s if self.wall_time_s else 0.0

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "grid_kwh": round(self.grid_kwh, 1),
            "renewable_kwh": round(self.renewable_kwh, 1),
            "renewable_frac": round(self.renewable_fraction, 3),
            "mean_jct_h": round(self.mean_jct_s / HOUR, 2),
            "migration_overhead": round(self.migration_overhead, 4),
            "stall_overhead": round(self.stall_overhead, 4),
            "migrations": self.migrations,
            "failed_migrations": self.failed_migrations,
            "completed": self.completed,
            "failures": self.failures,
        }


def generate_jobs(cfg: SimConfig) -> List[SimJob]:
    rng = np.random.default_rng(cfg.seed + 1)
    horizon = cfg.days * 24 * HOUR
    arrivals = np.sort(rng.uniform(0, horizon * 0.75, cfg.n_jobs))
    skew = np.asarray(cfg.arrival_skew[: cfg.n_sites], float)
    skew = skew / skew.sum()
    jobs = []
    sigma = 0.6
    mu = np.log(cfg.mean_compute_h) - sigma ** 2 / 2
    for i, t in enumerate(arrivals):
        u = rng.random()
        if u < cfg.frac_a:
            cls, (lo, hi) = "A", cfg.size_a_gb
        elif u < cfg.frac_a + cfg.frac_b:
            cls, (lo, hi) = "B", cfg.size_b_gb
        else:
            cls, (lo, hi) = "C", cfg.size_c_gb
        size = rng.uniform(lo, hi) * GB
        compute_h = float(np.clip(rng.lognormal(mu, sigma), 0.5, 24.0))
        home = int(rng.choice(cfg.n_sites, p=skew))
        jobs.append(SimJob(i, float(t), compute_h * HOUR, size, cls, home, site=home))
    return jobs


class ClusterSimulator:
    def __init__(
        self,
        cfg: SimConfig,
        policy: Policy,
        traces: Optional[List[SiteTrace]] = None,
        jobs: Optional[List[SimJob]] = None,
        oracle_forecast: bool = False,
    ):
        self.cfg = cfg
        self.policy = policy
        self.traces = traces or generate_trace(
            cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.trace
        )
        self.jobs = jobs if jobs is not None else generate_jobs(cfg)
        sigma = 0.0 if oracle_forecast else cfg.forecast_sigma_s
        self.forecaster = Forecaster(self.traces, sigma_s=sigma, seed=cfg.seed + 7)
        self._fail_rng = np.random.default_rng(cfg.seed + 23)
        self.grid_kwh = 0.0
        self.renewable_kwh = 0.0
        self.migration_kwh = 0.0
        self.migrations = 0
        self.failed_migrations = 0
        self.failures = 0
        self.rejected_actions = 0
        self.ticks = 0
        # flaky-WAN brownout calendar (deterministic per seed)
        if cfg.wan_degrade_prob > 0.0:
            n_hours = int(cfg.days * 24 * 2) + 1
            rng = np.random.default_rng(cfg.seed + 31)
            self._wan_bad = rng.random(n_hours) < cfg.wan_degrade_prob
        else:
            self._wan_bad = None
        # incremental (site, state) job index: jid-keyed dicts give
        # deterministic (insertion-ordered) iteration and O(1) moves
        self._by_state: Dict[str, Dict[int, SimJob]] = {s: {} for s in JOB_STATES}
        self._site_jobs: Dict[Tuple[int, str], Dict[int, SimJob]] = {}
        self._jobs_by_id: Dict[int, SimJob] = {}
        for j in self.jobs:
            self._jobs_by_id[j.jid] = j
            self._index_add(j)
        self._arrivals = sorted(self._by_state["pending"].values(),
                                key=lambda j: (j.arrival_s, j.jid))
        self._arrival_ptr = 0

    # -- (site, state) bucket maintenance -----------------------------------
    _SITE_STATES = ("queued", "running")

    def _index_add(self, j: SimJob) -> None:
        self._by_state[j.state][j.jid] = j
        if j.state in self._SITE_STATES:
            self._site_jobs.setdefault((j.site, j.state), {})[j.jid] = j

    def _index_remove(self, j: SimJob) -> None:
        self._by_state[j.state].pop(j.jid, None)
        if j.state in self._SITE_STATES:
            bucket = self._site_jobs.get((j.site, j.state))
            if bucket is not None:
                bucket.pop(j.jid, None)

    def _move(self, j: SimJob, state: Optional[str] = None,
              site: Optional[int] = None) -> None:
        self._index_remove(j)
        if state is not None:
            j.state = state
        if site is not None:
            j.site = site
        self._index_add(j)

    def _running_count(self, sid: int) -> int:
        return len(self._site_jobs.get((sid, "running"), ()))

    def _queued_count(self, sid: int) -> int:
        return len(self._site_jobs.get((sid, "queued"), ()))

    # -- WAN model -----------------------------------------------------------
    def _nic_bps(self, t: float) -> float:
        if self._wan_bad is not None:
            hr = min(int(t // HOUR), len(self._wan_bad) - 1)
            if self._wan_bad[hr]:
                return self.cfg.wan_degraded_gbps * 1e9
        return self.cfg.wan_gbps * 1e9

    def _effective_bw(self, transfers: List[SimJob], t: float) -> Dict[int, float]:
        """Per-transfer effective bps under per-site NIC sharing — the same
        share model the snapshot advertises (state.nic_share_counts)."""
        nic = self._nic_bps(t)
        src_count, dst_count = nic_share_counts(
            [(j.site, j.transfer_dest) for j in transfers])
        return {
            j.jid: min(nic / src_count[j.site], nic / dst_count[j.transfer_dest])
            for j in transfers
        }

    # -- snapshot ------------------------------------------------------------
    def snapshot(self, t: float) -> ClusterState:
        """Build the policy-facing ClusterState via the shared constructor.
        The advertised bandwidth matrix uses the same per-NIC share counts
        as the transfer loop (``_effective_bw``)."""
        cfg = self.cfg
        incoming = [0] * cfg.n_sites
        transfers: List[Tuple[int, int]] = []
        for j in self._by_state["migrating"].values():
            incoming[j.transfer_dest] += 1
            transfers.append((j.site, j.transfer_dest))
        for j in self._by_state["loading"].values():
            incoming[j.site] += 1
        sites = []
        for s in range(cfg.n_sites):
            tr = self.traces[s]
            sites.append(
                SiteView(
                    sid=s,
                    slots=cfg.slots_per_site,
                    busy=self._running_count(s),
                    queued=self._queued_count(s),
                    renewable_active=tr.active(t),
                    window_remaining_s=self.forecaster.remaining(s, t),
                    incoming=incoming[s],
                    next_window_start_s=self.forecaster.next_window_start(s, t),
                )
            )
        views = []
        for state_name in ("queued", "running", "paused"):
            for j in self._by_state[state_name].values():
                views.append(
                    JobView(
                        j.jid, j.site, j.ckpt_bytes, j.compute_s - j.progress_s,
                        cfg.t_load_s, state=state_name,
                        eligible=(t - j.last_migration_end_s
                                  >= cfg.migration_cooldown_s),
                        power_frac=j.power_frac,
                    )
                )
        views.sort(key=lambda v: v.jid)
        return ClusterState.build(t, views, sites, nic_bps=self._nic_bps(t),
                                  transfers=transfers)

    # -- action application --------------------------------------------------
    def _apply_action(self, action: Action, t: float, state: ClusterState,
                      horizon: float) -> None:
        if not isinstance(action, Action):
            # e.g. a legacy (jid, dest) tuple from a pre-redesign policy
            self.rejected_actions += 1
            return
        j = self._jobs_by_id.get(action.jid)
        if j is None:
            self.rejected_actions += 1
            return
        if isinstance(action, Migrate):
            dest = action.dest
            if (j.state != "running" or dest == j.site
                    or not 0 <= dest < self.cfg.n_sites
                    or t - j.last_migration_end_s < self.cfg.migration_cooldown_s):
                self.rejected_actions += 1
                return
            j.transfer_dest = dest
            j.transfer_remaining_bits = 8.0 * j.ckpt_bytes
            j.migrations += 1
            self.migrations += 1
            # a migration whose destination window closes before the
            # transfer ends is counted as failed (it still completes,
            # but arrives onto grid power — the paper's stall mode)
            bw_now = float(state.bandwidth_bps[j.site, dest])
            t_arrive = t + 8.0 * j.ckpt_bytes / bw_now
            if not self.traces[dest].active(min(t_arrive, horizon - 1)):
                self.failed_migrations += 1
            self._move(j, state="migrating")
        elif isinstance(action, Defer):
            if j.state != "queued":
                self.rejected_actions += 1
                return
            j.defer_until_s = max(t, float(action.until_s))
        elif isinstance(action, Pause):
            if j.state != "running":
                self.rejected_actions += 1
                return
            self._move(j, state="paused")
        elif isinstance(action, Resume):
            if j.state != "paused":
                self.rejected_actions += 1
                return
            self._move(j, state="queued")
        elif isinstance(action, Throttle):
            if j.state != "running":
                self.rejected_actions += 1
                return
            j.power_frac = float(min(1.0, max(0.0, action.power_frac)))
        else:
            self.rejected_actions += 1

    # -- main loop ----------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        wall_t0 = time.perf_counter()
        horizon = cfg.days * 24 * HOUR
        # allow the tail of late jobs to finish
        t, t_end = 0.0, horizon * 2.0
        next_orch = 0.0
        n_jobs = len(self.jobs)
        by_state = self._by_state
        site_jobs = self._site_jobs
        while t < t_end:
            dt = cfg.dt_s
            self.ticks += 1
            # 1) arrivals (pending jobs, in arrival order)
            while (self._arrival_ptr < len(self._arrivals)
                   and self._arrivals[self._arrival_ptr].arrival_s <= t):
                j = self._arrivals[self._arrival_ptr]
                self._arrival_ptr += 1
                if j.state == "pending":
                    self._move(j, state="queued")
            # 2) transfers progress
            if by_state["migrating"]:
                transfers = list(by_state["migrating"].values())
                eff = self._effective_bw(transfers, t)
                for j in transfers:
                    rate = eff[j.jid]
                    j.transfer_remaining_bits -= rate * dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    e = cfg.p_sys_kw * dt / HOUR
                    self.migration_kwh += e
                    self.grid_kwh += e  # transfer power billed to grid
                    if j.transfer_remaining_bits <= 0:
                        dest = j.transfer_dest
                        j.transfer_dest = -1
                        j.load_remaining_s = cfg.t_load_s + cfg.t_downtime_s
                        self._move(j, state="loading", site=dest)
            # 3) checkpoint loads
            if by_state["loading"]:
                for j in list(by_state["loading"].values()):
                    j.load_remaining_s -= dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    if j.load_remaining_s <= 0:
                        j.post_migration_wait = True
                        j.last_migration_end_s = t
                        self._move(j, state="queued")
            # 4) scheduling: fill free slots FIFO (Defer holds jobs back)
            for s in range(cfg.n_sites):
                q = site_jobs.get((s, "queued"))
                if not q:
                    continue
                free = cfg.slots_per_site - self._running_count(s)
                if free <= 0:
                    continue
                ready = [j for j in q.values() if j.defer_until_s <= t]
                ready.sort(key=lambda x: (x.arrival_s, x.jid))
                for j in ready[:free]:
                    j.post_migration_wait = False
                    if j.started_s < 0:
                        j.started_s = t
                    self._move(j, state="running")
            # 5) compute progress + energy + failures
            for s in range(cfg.n_sites):
                running = site_jobs.get((s, "running"))
                if not running:
                    continue
                green = self.traces[s].active(t)
                for j in list(running.values()):
                    frac = j.power_frac
                    j.progress_s += dt * frac
                    e = cfg.p_node_kw * frac * dt / HOUR
                    if green:
                        j.renewable_kwh += e
                        self.renewable_kwh += e
                    else:
                        j.grid_kwh += e
                        self.grid_kwh += e
                    if j.progress_s - j.last_ckpt_progress_s >= cfg.checkpoint_interval_s:
                        j.last_ckpt_progress_s = j.progress_s
                    if cfg.failure_rate_per_slot_hour > 0.0:
                        if self._fail_rng.random() < cfg.failure_rate_per_slot_hour * dt / HOUR:
                            # node failure: roll back to last checkpoint
                            lost = j.progress_s - j.last_ckpt_progress_s
                            j.progress_s = j.last_ckpt_progress_s
                            j.pause_s += lost
                            self.failures += 1
                    if j.progress_s >= j.compute_s:
                        j.done_s = t
                        self._move(j, state="done")
            # queue / pause time accounting
            for j in by_state["queued"].values():
                j.queue_s += dt
                if j.post_migration_wait:
                    j.pause_s += dt  # stalled by its own migration
                    j.pause_wait_s += dt
            for j in by_state["paused"].values():
                j.paused_policy_s += dt
            # 6) orchestrator tick: snapshot -> typed actions -> apply
            if t >= next_orch:
                next_orch = t + cfg.orch_dt_s
                state = self.snapshot(t)
                for action in self.policy.decide(state):
                    self._apply_action(action, t, state, horizon)
            if len(by_state["done"]) == n_jobs:
                break
            t += dt
        return SimResult(
            policy=self.policy.name,
            jobs=self.jobs,
            grid_kwh=self.grid_kwh,
            renewable_kwh=self.renewable_kwh,
            migration_kwh=self.migration_kwh,
            migrations=self.migrations,
            failed_migrations=self.failed_migrations,
            failures=self.failures,
            rejected_actions=self.rejected_actions,
            ticks=self.ticks,
            wall_time_s=time.perf_counter() - wall_t0,
        )

    # -- scenario entry point ------------------------------------------------
    @classmethod
    def from_scenario(
        cls,
        scenario,
        policy: Union[str, Policy],
        *,
        overrides: Optional[dict] = None,
        jobs: Optional[List[SimJob]] = None,
        traces: Optional[List[SiteTrace]] = None,
    ) -> "ClusterSimulator":
        """Build a simulator from a registered scenario name (or Scenario)
        and a registered policy name (or Policy instance)."""
        from repro.core.scenarios import get_scenario

        scn = get_scenario(scenario)
        cfg = scn.sim_config(**(overrides or {}))
        pol = make_policy(policy) if isinstance(policy, str) else policy
        return cls(cfg, pol, jobs=jobs, traces=traces,
                   oracle_forecast=getattr(pol, "wants_oracle_forecast", False))


def run_policy_comparison(
    cfg: Optional[SimConfig] = None,
    policies: Sequence[str] = ("static", "energy-only", "feasibility-aware", "oracle"),
    *,
    scenario=None,
    overrides: Optional[dict] = None,
    policy_configs: Optional[Dict[str, Union[PolicyConfig, dict]]] = None,
) -> Dict[str, SimResult]:
    """Table VI / VIII: same trace + same jobs, one run per policy.

    ``scenario`` names a registered scenario (or passes a ``Scenario``);
    ``overrides`` tweaks individual ``SimConfig`` fields on top of it;
    ``policy_configs`` maps policy name -> ``PolicyConfig`` (or kwargs dict),
    so per-policy knobs like stochastic feasibility ``eps`` /
    ``forecast_sigma_s`` reach the comparison path.
    """
    import copy

    if scenario is not None:
        if cfg is not None:
            raise ValueError(
                "pass either cfg or scenario (+overrides), not both")
        from repro.core.scenarios import get_scenario

        cfg = get_scenario(scenario).sim_config(**(overrides or {}))
    elif overrides:
        cfg = dataclasses.replace(cfg or SimConfig(), **overrides)
    cfg = cfg or SimConfig()
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.trace)
    base_jobs = generate_jobs(cfg)
    policy_configs = policy_configs or {}
    out: Dict[str, SimResult] = {}
    for name in policies:
        jobs = copy.deepcopy(base_jobs)
        pconf = policy_configs.get(name)
        if isinstance(pconf, dict):
            pol = make_policy(name, **pconf)
        else:
            pol = make_policy(name, config=pconf)
        sim = ClusterSimulator(
            cfg, pol, traces=traces, jobs=jobs,
            oracle_forecast=pol.wants_oracle_forecast,
        )
        out[name] = sim.run()
    return out


def normalized_table(results: Dict[str, SimResult]) -> List[dict]:
    """Paper Table VI/VIII format: normalized to the static baseline."""
    base = results["static"]
    rows = []
    for name, r in results.items():
        rows.append(
            {
                "policy": name,
                "nonrenew_energy": round(r.grid_kwh / base.grid_kwh, 2) if base.grid_kwh else 0.0,
                "jct": round(r.mean_jct_s / base.mean_jct_s, 2),
                "migration_overhead": round(r.migration_overhead, 3),
                "stall_overhead": round(r.stall_overhead, 3),
                "renewable_frac": round(r.renewable_fraction, 3),
            }
        )
    return rows
