"""Trace-driven simulator of renewable-powered micro-datacenters
(paper §VII: 5 sites, 10 Gbps WAN, 7-day CAISO-calibrated trace, job mix
A:70% 1–6 GB / B:20% 10–40 GB / C:10% 100–300 GB).

Control flow is event-driven and typed: every ``orch_dt_s`` the simulator
builds an immutable :class:`~repro.core.state.ClusterState` snapshot (one
shared constructor with the dry-run planner and the serve router) and hands
it to ``Policy.decide``, which returns :mod:`repro.core.actions` —
``Migrate``, ``Defer(until)``, ``Pause``/``Resume`` and
``Throttle(power_frac)``.  Invalid or stale actions are counted in
``SimResult.rejected_actions``, never applied.

Models:
  * per-site GPU slots with FIFO queues (``Defer`` holds a queued job out
    of scheduling; ``Pause`` frees a slot until ``Resume``),
  * renewable windows from core/traces.py; grid vs. renewable kWh accounting
    (P_node = 0.75 kW compute — scaled by the job's ``Throttle`` fraction —
    P_sys = 1.8 kW during transfer),
  * WAN transfers over a :class:`~repro.core.wan.WanTopology` — per-site
    (possibly asymmetric) NIC rates, a per-link capacity matrix and fabric-
    or per-link-scoped brownout calendars; concurrent transfers get the
    fair share of every resource they traverse (this is what stalls the
    energy-only policy),
  * migration = pause → transfer → load (10.3 s) → downtime (0.4 s) →
    resume (possibly queued on arrival),
  * optional node failures with checkpoint/restart (beyond-paper).

Two time-stepping engines share all state, indexing and action code
(``SimConfig.engine``):

  * ``"event"`` (default) — next-event stepping: time jumps straight to
    the next arrival, transfer/load/job completion, window edge, brownout
    edge, defer expiry, failure or orchestrator tick.  Job accounting is
    integrated *analytically* over each inter-event span (renewable vs.
    grid kWh by exact window overlap, transfer bits at the current share
    rate), and in-flight transfer rates are re-split only when the flow
    set or the link state actually changes.
  * ``"fixed-dt"`` — the legacy fixed ``dt_s`` loop, kept as the parity
    reference (see tests/test_event_engine.py).

Jobs are indexed incrementally by (site, state) bucket — the hot loop only
touches jobs whose state can change at the current event, never the full
job list.  ``benchmarks/run.py --quick`` prints wall time and ticks/sec
(one tick = one processed event) and gates them in CI against
``benchmarks/BENCH_quick.json``.

Scenarios: construct via ``ClusterSimulator.from_scenario("flaky-wan",
"feasibility-aware")`` or ``run_policy_comparison(scenario="paper-table6")``
— see :mod:`repro.core.scenarios` for the registry (including the
WAN-topology scenarios ``hub-spoke-wan``, ``asymmetric-uplink``,
``partitioned-wan``).

Deterministic for a given seed (each engine separately; the two engines
agree within tolerance, not bit-for-bit — completions are exact events
rather than rounded up to the next tick).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import feasibility as fz
from repro.core.actions import Action, Defer, Migrate, Pause, Resume, Throttle
from repro.core.faults import FaultPlan, FaultRegime, RetryPolicy
from repro.core.ledger import BatteryConfig, PowerLedger, ThrottleCurve
from repro.core.orchestrator import Policy, PolicyConfig, make_policy
from repro.core.serving import ServingPlane, ServingProfile, make_router
from repro.core.signals import (
    GridSignals, SignalProfile, generate_signals, grid_signal_integral,
)
from repro.core.state import ClusterState, JobSoA, JobView, SiteView
from repro.core.traces import Forecaster, SiteTrace, TraceProfile, generate_trace
from repro.core.wan import WanProfile, WanTopology

HOUR = 3600.0
GB = 1e9

# Job lifecycle. "paused" is policy-initiated (Pause action); "migrating"
# and "loading" are the two legs of a migration.
JOB_STATES = ("pending", "queued", "running", "migrating", "loading",
              "paused", "done")
# codes for the incremental state column; the live-state codes are taken
# from state.py so the SoA column can never drift from what the policy
# kernels compare against (STATE_QUEUED/RUNNING/PAUSED)
from repro.core.state import _STATE_CODES as _LIVE_STATE_CODES

_STATE_CODE = {**_LIVE_STATE_CODES, "pending": 3, "migrating": 4,
               "loading": 5, "done": 6}
# packed column indices (see ClusterSimulator.__init__)
_CF_CKPT, _CF_COMPUTE, _CF_PROGRESS, _CF_POWER, _CF_DEFER, _CF_LASTMIG = range(6)
_CI_SITE, _CI_STATE = range(2)


@dataclass
class SimJob:
    jid: int
    arrival_s: float
    compute_s: float
    ckpt_bytes: float
    size_class: str
    home_site: int

    site: int = -1
    state: str = "pending"
    progress_s: float = 0.0
    done_s: float = -1.0
    started_s: float = -1.0
    migrations: int = 0
    failed_migrations: int = 0
    pause_s: float = 0.0  # time spent not computing due to migration
    pause_transfer_s: float = 0.0
    pause_wait_s: float = 0.0  # post-migration queue wait
    queue_s: float = 0.0
    renewable_kwh: float = 0.0
    grid_kwh: float = 0.0
    # in-flight transfer
    transfer_remaining_bits: float = 0.0
    transfer_dest: int = -1
    load_remaining_s: float = 0.0
    last_ckpt_progress_s: float = 0.0
    post_migration_wait: bool = False  # queue time after arrival counts as
    # migration-induced pause (the paper's 'stall/congestion' mode)
    last_migration_end_s: float = -1e18
    # typed-action state
    power_frac: float = 1.0  # Throttle power cap while running
    # throughput fraction delivered at power_frac: equal to power_frac
    # without a SimConfig.throttle_curve (legacy linear scalar), else
    # curve.throughput(power_frac).  Progress integrates tput_frac;
    # energy always integrates power_frac.
    tput_frac: float = 1.0
    defer_until_s: float = -1e18  # Defer: not schedulable before this time
    paused_policy_s: float = 0.0  # time spent in policy-initiated Pause
    # next-event engine bookkeeping
    anchor_s: float = 0.0  # sim-time the job's accounting was last flushed
    rate_bps: float = 0.0  # current transfer share (migrating only)
    ver: int = 0  # bumped on any change that invalidates a queued event
    # recovery ladder (transfer-stall watchdog, core/faults.py)
    stall_since_s: float = -1.0  # when the in-flight rate hit 0 (-1: flowing)
    retry_attempts: int = 0  # watchdog-aborted transfers since last success
    last_failed_dest: int = -1  # destination of the last aborted transfer
    fail_counted: bool = False  # this attempt already in failed_migrations

    @property
    def jct_s(self) -> float:
        return self.done_s - self.arrival_s if self.done_s >= 0 else float("nan")


@dataclass
class SimConfig:
    n_sites: int = 5
    slots_per_site: int = 4
    wan_gbps: float = 10.0
    days: int = 7
    dt_s: float = 30.0  # fixed-dt engine step
    engine: str = "event"  # "event" (next-event) or "fixed-dt" (legacy)
    orch_dt_s: float = 300.0
    seed: int = 0
    n_jobs: int = 240
    arrival_skew: Sequence[float] = (0.45, 0.1925, 0.1485, 0.121, 0.088)
    p_node_kw: float = fz.P_NODE_KW
    p_sys_kw: float = fz.P_SYS_KW
    t_load_s: float = fz.T_LOAD_S
    t_downtime_s: float = fz.T_DOWNTIME_S
    forecast_sigma_s: float = 900.0
    forecast_horizon_s: float = 24 * HOUR  # ClusterState.forecast lookahead
    migration_cooldown_s: float = 900.0  # orchestrator debounce per job
    # renewable-window process (scenario-composable)
    trace: TraceProfile = field(default_factory=TraceProfile)
    # grid-signal process (carbon gCO2/kWh + price $/kWh traces, derived
    # demand-response curtail requests) — always on: the signal accounting
    # is a parallel integral, the kWh numbers it annotates never change
    signals: SignalProfile = field(default_factory=SignalProfile)
    # WAN: a full WanProfile wins over the legacy uniform scalars below
    wan: Optional[WanProfile] = None
    # flaky-WAN regime: hourly brownouts to wan_degraded_gbps
    wan_degrade_prob: float = 0.0
    wan_degraded_gbps: float = 1.0
    # job mix (paper §VII)
    frac_a: float = 0.70
    frac_b: float = 0.20
    size_a_gb: tuple = (1.0, 6.0)
    size_b_gb: tuple = (10.0, 40.0)
    size_c_gb: tuple = (100.0, 300.0)
    mean_compute_h: float = 3.5
    # beyond-paper fault injection.  ``failure_rate_per_slot_hour`` is
    # the legacy alias for FaultRegime.job_failure_rate_per_slot_hour
    # (the two rates add); the full fault spec lives in ``faults``
    failure_rate_per_slot_hour: float = 0.0
    checkpoint_interval_s: float = 1800.0
    # deterministic fault injection + recovery (core/faults.py): site
    # blackouts, hard link failures, checkpoint corruption, replica
    # crashes, stragglers.  None (or an all-off regime) draws zero RNG
    # numbers and adds zero float ops.  Event engine only.
    faults: Optional[FaultRegime] = None
    # transfer-stall watchdog: a migration whose shared rate sits at 0
    # for this long is aborted and requeued at the source (bounded
    # retries via RetryPolicy).  Active regardless of ``faults`` — it is
    # the fix for the historic silent-infinite-stall bug.
    stall_timeout_s: float = 1800.0
    # inference serving plane (None or a disabled profile = training only;
    # event engine only).  The plane's RNG lives entirely in the
    # [seed, 151, ...] streams, so enabling it never moves a training draw.
    serving: Optional[ServingProfile] = None
    serving_router: str = "green-first"
    # serving engine selection: "chunked" (default) uses the span-advance
    # fast path in core/serving_kernels.py whenever the router has a
    # bit-exact kernel mirror, falling back to the per-event scalar plane
    # otherwise; "event" forces the scalar plane (the parity oracle).
    serving_engine: str = "chunked"
    # prosumer microgrid layer (core/ledger.py): per-site battery /
    # sell-back spec (None = storage off; with storage off the ledger
    # reproduces the pre-ledger accounting bit-for-bit), and the
    # physical power→throughput curve Throttle actions map through
    # (None = the legacy linear scalar).  Event engine only.
    battery: Optional[BatteryConfig] = None
    throttle_curve: Optional[ThrottleCurve] = None

    def wan_profile(self) -> WanProfile:
        """The authoritative WAN spec: ``wan`` if set, else the legacy
        uniform scalars."""
        if self.wan is not None:
            return self.wan
        return WanProfile(gbps=self.wan_gbps,
                          hourly_degrade_prob=self.wan_degrade_prob,
                          degraded_gbps=self.wan_degraded_gbps)


@dataclass
class SimResult:
    policy: str
    jobs: List[SimJob]
    grid_kwh: float
    renewable_kwh: float
    migration_kwh: float
    migrations: int
    failed_migrations: int
    failures: int
    rejected_actions: int = 0
    ticks: int = 0
    wall_time_s: float = 0.0
    # cumulative wall time inside Policy.decide, WARM ticks only: the
    # first decide of a run (XLA compile, lazy caches) lands in
    # decide_first_s so compiled backends don't gate on compile jitter
    decide_s: float = 0.0
    decide_first_s: float = 0.0
    engine: str = "event"
    # grid-signal accounting: gCO2 / $ of every grid-billed kWh, weighted
    # by the per-site time-of-use signal at the moment the energy was
    # drawn, plus the per-site breakdowns (each gram is billed to exactly
    # one site; sums equal the totals to float precision)
    grid_gco2: float = 0.0
    grid_cost: float = 0.0
    site_grid_gco2: Tuple[float, ...] = ()
    site_grid_cost: Tuple[float, ...] = ()
    # serving-plane accounting (all zero when the run carries no serving
    # plane; separate accumulators from the training spine — the kWh /
    # gCO2 columns above never include request energy)
    requests_arrived: int = 0
    requests_served: int = 0
    requests_dropped: int = 0  # queue-overflow drops
    requests_shed: int = 0  # router-initiated proactive sheds
    slo_violations: int = 0
    request_gco2: float = 0.0
    site_request_gco2: Tuple[float, ...] = ()
    serve_grid_kwh: float = 0.0
    serve_renewable_kwh: float = 0.0
    latency_p50_s: float = 0.0
    latency_p95_s: float = 0.0
    latency_p99_s: float = 0.0
    queue_depth_p95: float = 0.0
    # prosumer accounting (all zero with storage/sell-back disabled)
    battery_charge_kwh: float = 0.0
    battery_discharge_kwh: float = 0.0
    battery_loss_kwh: float = 0.0
    battery_cycles: float = 0.0
    sellback_kwh: float = 0.0
    sellback_usd: float = 0.0
    # demand-response compliance (watt-seconds requested shed vs shed)
    dr_requested_ws: float = 0.0
    dr_shed_ws: float = 0.0
    # fault/recovery telemetry (all zero without an active FaultRegime —
    # except watchdog_aborts/retries/reroutes, which the always-on
    # transfer-stall watchdog can also produce)
    site_outages: int = 0  # blackout spans experienced during the run
    mttr_s: float = 0.0  # mean time-to-repair of those blackouts
    retries: int = 0  # re-admitted migrations after a watchdog abort
    reroutes: int = 0  # retries that picked a different destination
    replica_crashes: int = 0  # serving replica crash events applied
    watchdog_aborts: int = 0  # transfers aborted by the stall watchdog

    @property
    def dr_compliance(self) -> float:
        """Fraction of curtail-request span-watts actually shed (1.0
        when no request overlapped any compute span)."""
        if self.dr_requested_ws <= 0.0:
            return 1.0
        return min(1.0, max(0.0, self.dr_shed_ws / self.dr_requested_ws))

    @property
    def slo_attainment(self) -> float:
        """Fraction of served requests that met their latency SLO (1.0
        with no serving plane / nothing served)."""
        if self.requests_served <= 0:
            return 1.0
        return 1.0 - self.slo_violations / self.requests_served

    @property
    def mean_jct_s(self) -> float:
        vals = [j.jct_s for j in self.jobs if j.done_s >= 0]
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def completed(self) -> int:
        return sum(1 for j in self.jobs if j.done_s >= 0)

    @property
    def total_compute_s(self) -> float:
        return sum(j.progress_s for j in self.jobs)

    @property
    def migration_overhead(self) -> float:
        """Direct migration cost (transfer + load + downtime) over compute —
        the paper's 'Migr. overhead' column."""
        c = self.total_compute_s
        return (sum(j.pause_transfer_s for j in self.jobs) / c) if c else 0.0

    @property
    def stall_overhead(self) -> float:
        """Migration-induced queueing stalls over compute (the energy-only
        failure mode: §VII.E 'stalled transfers, congestion, retries')."""
        c = self.total_compute_s
        return (sum(j.pause_wait_s for j in self.jobs) / c) if c else 0.0

    @property
    def renewable_fraction(self) -> float:
        tot = self.grid_kwh + self.renewable_kwh
        return self.renewable_kwh / tot if tot else 0.0

    @property
    def ticks_per_sec(self) -> float:
        """Events (fixed-dt: ticks) processed per wall-clock second."""
        return self.ticks / self.wall_time_s if self.wall_time_s else 0.0

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "grid_kwh": round(self.grid_kwh, 1),
            "renewable_kwh": round(self.renewable_kwh, 1),
            "renewable_frac": round(self.renewable_fraction, 3),
            "mean_jct_h": round(self.mean_jct_s / HOUR, 2),
            "migration_overhead": round(self.migration_overhead, 4),
            "stall_overhead": round(self.stall_overhead, 4),
            "migrations": self.migrations,
            "failed_migrations": self.failed_migrations,
            "completed": self.completed,
            "failures": self.failures,
            "rejected_actions": self.rejected_actions,
            "grid_gco2": round(self.grid_gco2, 1),
            "grid_cost": round(self.grid_cost, 2),
            "site_grid_gco2": [round(x, 1) for x in self.site_grid_gco2],
            "site_grid_cost": [round(x, 2) for x in self.site_grid_cost],
            "requests_arrived": self.requests_arrived,
            "requests_served": self.requests_served,
            "requests_dropped": self.requests_dropped,
            "requests_shed": self.requests_shed,
            "slo_violations": self.slo_violations,
            "slo_attainment": round(self.slo_attainment, 4),
            "request_gco2": round(self.request_gco2, 1),
            "serve_grid_kwh": round(self.serve_grid_kwh, 3),
            "serve_renewable_kwh": round(self.serve_renewable_kwh, 3),
            "latency_p50_s": round(self.latency_p50_s, 3),
            "latency_p95_s": round(self.latency_p95_s, 3),
            "latency_p99_s": round(self.latency_p99_s, 3),
            "queue_depth_p95": round(self.queue_depth_p95, 1),
            "battery_charge_kwh": round(self.battery_charge_kwh, 3),
            "battery_discharge_kwh": round(self.battery_discharge_kwh, 3),
            "battery_cycles": round(self.battery_cycles, 3),
            "sellback_kwh": round(self.sellback_kwh, 3),
            "sellback_usd": round(self.sellback_usd, 4),
            "dr_compliance": round(self.dr_compliance, 4),
            "site_outages": self.site_outages,
            "mttr_s": round(self.mttr_s, 1),
            "retries": self.retries,
            "reroutes": self.reroutes,
            "replica_crashes": self.replica_crashes,
            "watchdog_aborts": self.watchdog_aborts,
            "ticks_per_sec": round(self.ticks_per_sec, 1),
            "decide_s": round(self.decide_s, 4),
            "decide_first_s": round(self.decide_first_s, 4),
            "wall_s": round(self.wall_time_s, 4),
        }


def generate_jobs(cfg: SimConfig, *, seed: Optional[int] = None) -> List[SimJob]:
    """The arrival process.  ``seed`` overrides the job-stream seed
    (default ``cfg.seed``): the sweep engine's split-seed modes hold one
    of {traces, jobs} fixed while the other varies (variance
    decomposition); the default reproduces the coupled legacy stream."""
    rng = np.random.default_rng((cfg.seed if seed is None else seed) + 1)
    horizon = cfg.days * 24 * HOUR
    arrivals = np.sort(rng.uniform(0, horizon * 0.75, cfg.n_jobs))
    skew = np.asarray(cfg.arrival_skew[: cfg.n_sites], float)
    skew = skew / skew.sum()
    jobs = []
    sigma = 0.6
    mu = np.log(cfg.mean_compute_h) - sigma ** 2 / 2
    for i, t in enumerate(arrivals):
        u = rng.random()
        if u < cfg.frac_a:
            cls, (lo, hi) = "A", cfg.size_a_gb
        elif u < cfg.frac_a + cfg.frac_b:
            cls, (lo, hi) = "B", cfg.size_b_gb
        else:
            cls, (lo, hi) = "C", cfg.size_c_gb
        size = rng.uniform(lo, hi) * GB
        compute_h = float(np.clip(rng.lognormal(mu, sigma), 0.5, 24.0))
        home = int(rng.choice(cfg.n_sites, p=skew))
        jobs.append(SimJob(i, float(t), compute_h * HOUR, size, cls, home, site=home))
    return jobs


class ClusterSimulator:
    def __init__(
        self,
        cfg: SimConfig,
        policy: Policy,
        traces: Optional[List[SiteTrace]] = None,
        jobs: Optional[List[SimJob]] = None,
        oracle_forecast: bool = False,
        wan_topology: Optional[WanTopology] = None,
        forecast_horizon=None,
        grid_signals: Optional[GridSignals] = None,
    ):
        """``wan_topology`` / ``forecast_horizon`` / ``grid_signals``
        accept prebuilt shared objects (the sweep engine builds them once
        per (scenario, seed) cell); the constructions are deterministic,
        so passing them is result-identical to letting the simulator
        build its own."""
        self.cfg = cfg
        self.policy = policy
        self.traces = traces or generate_trace(
            cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.trace
        )
        self.jobs = jobs if jobs is not None else generate_jobs(cfg)
        sigma = 0.0 if oracle_forecast else cfg.forecast_sigma_s
        self.forecaster = Forecaster(self.traces, sigma_s=sigma, seed=cfg.seed + 7)
        # legacy per-job failure stream, unified onto the repo-wide
        # list-seed convention (was the ad-hoc ``default_rng(seed + 23)``
        # — PR 9 regenerated the failure-storm numbers; no gated digits
        # depend on this stream)
        self._fail_rng = np.random.default_rng([cfg.seed, 23])
        # deterministic fault plan (core/faults.py): every span sampled
        # up front from its own [seed, 173, ...] streams.  None when the
        # regime is unset/inactive — the faults-off path never consults
        # it and never draws from a fault stream.
        self.fault_plan: Optional[FaultPlan] = None
        if cfg.faults is not None and cfg.faults.any_active():
            self.fault_plan = FaultPlan.build(
                cfg.faults, cfg.n_sites, cfg.days * 24 * HOUR, cfg.seed)
        # live fault-state caches (updated at plan span edges)
        self._site_up = np.ones(cfg.n_sites, dtype=bool)
        self._link_up = np.ones((cfg.n_sites, cfg.n_sites), dtype=bool)
        self._fault_tput: Optional[np.ndarray] = None  # straggler factors
        self._replica_down = np.zeros(cfg.n_sites, dtype=bool)
        # grid-signal traces (per-site carbon/price + curtail requests):
        # own RNG stream, so enabling signals changes no existing draw
        self.signals = grid_signals or generate_signals(
            cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.signals)
        # the one accounting spine: every kWh / gCO2 / $ accumulator of
        # the run lives in the per-site PowerLedger (core/ledger.py).
        # Postings reproduce the historical per-span expressions op for
        # op, so every digit is bit-identical with storage disabled;
        # with a battery the ledger also runs the charge/sell-back
        # timeline (deterministic, zero RNG draws).
        self.ledger = PowerLedger(cfg.n_sites, signals=self.signals,
                                  traces=self.traces, battery=cfg.battery)
        self.migrations = 0
        self.failed_migrations = 0
        self.failures = 0
        self.rejected_actions = 0
        self.ticks = 0
        # recovery telemetry (SimResult.{retries,reroutes,...})
        self.retries = 0
        self.reroutes = 0
        self.watchdog_aborts = 0
        self.replica_crashes = 0
        self._final_t = 0.0  # sim time the event loop actually reached
        # the one WAN object every consumer shares (transfer loop, snapshot
        # advertisement, and — via scenarios — dryrun --plan / serve)
        self.wan_topology = wan_topology or cfg.wan_profile().build_topology(
            cfg.n_sites, cfg.days, cfg.seed)
        # the lookahead product (window + outage forecasts) attached to
        # every snapshot.  Built once: window noise is hash-deterministic
        # per (seed, site), so the horizon is identical at every tick —
        # which is what lets plan-ahead policies hold a plan across ticks.
        from repro.core.forecast import ForecastHorizon

        self.forecast_horizon = forecast_horizon or ForecastHorizon.build(
            self.traces, wan=self.wan_topology, signals=self.signals,
            horizon_s=cfg.forecast_horizon_s, sigma_s=sigma,
            seed=cfg.seed + 7, faults=self.fault_plan)
        # Prebuilt horizons (sweep cells share one across policies) were
        # constructed without a fault plan; graft this run's plan on so
        # fault-aware policies see the same repair/next-fault answers
        # they would get from a from-scratch build.  The plan is a pure
        # function of (regime, n_sites, days, seed), so every sim in the
        # cell grafts the identical calendar.
        if (self.fault_plan is not None
                and self.forecast_horizon.faults is None):
            self.forecast_horizon = dataclasses.replace(
                self.forecast_horizon, faults=self.fault_plan)
        # inference serving plane (event engine only).  All serving RNG
        # lives in the [seed, 151, ...] streams and routing reads a
        # noise-free trace snapshot (never the forecaster), so a run with
        # serving disabled is bit-identical to one without the plane.
        self.serving: Optional[ServingPlane] = None
        if cfg.serving is not None and cfg.serving.enabled:
            from repro.core.traces import stack_traces

            router = make_router(cfg.serving_router)
            from repro.core.serving_kernels import (
                ChunkedServingPlane, supports_router)

            if (cfg.serving_engine == "chunked"
                    and supports_router(router)):
                plane = ChunkedServingPlane(
                    cfg.serving, router, n_sites=cfg.n_sites,
                    days=cfg.days, seed=cfg.seed, topo=self.wan_topology,
                    traces=self.traces, signals=self.signals,
                    ledger=self.ledger)
                plane.bind_context(
                    forecast=self.forecast_horizon,
                    mig_pairs_fn=lambda: [
                        (j.site, j.transfer_dest)
                        for j in self._by_state["migrating"].values()])
                self.serving = plane
            else:
                self.serving = ServingPlane(
                    cfg.serving, router,
                    n_sites=cfg.n_sites, days=cfg.days, seed=cfg.seed,
                    topo=self.wan_topology, traces=self.traces,
                    signals=self.signals, state_fn=self._serving_state,
                    ledger=self.ledger)
            self._serve_stack = stack_traces(self.traces)
            self._empty_soa = JobSoA.from_views([])
        # incremental (site, state) job index: jid-keyed dicts give
        # deterministic (insertion-ordered) iteration and O(1) moves
        self._by_state: Dict[str, Dict[int, SimJob]] = {s: {} for s in JOB_STATES}
        self._site_jobs: Dict[Tuple[int, str], Dict[int, SimJob]] = {}
        self._jobs_by_id: Dict[int, SimJob] = {}
        for j in self.jobs:
            self._jobs_by_id[j.jid] = j
            self._index_add(j)
        self._arrivals = sorted(self._by_state["pending"].values(),
                                key=lambda j: (j.arrival_s, j.jid))
        self._arrival_ptr = 0
        self.decide_s = 0.0  # cumulative WARM decide wall (see _record_decide)
        self.decide_first_s = 0.0
        self._decide_calls = 0
        # jid-indexed structure-of-arrays columns behind the snapshot's
        # JobSoA: static facts filled once; volatile facts mirrored at
        # their single mutation points (_move, _apply_action, migration
        # end) except progress, which is refreshed for the running bucket
        # at snapshot time (it advances continuously)
        size = max((j.jid for j in self.jobs), default=-1) + 1
        self._site_slots_arr = np.full(cfg.n_sites, cfg.slots_per_site,
                                       dtype=np.int64)
        self._tload_buf = np.full(max(size, 1), cfg.t_load_s)
        # packed jid-row column matrices: one fancy-index gather per
        # snapshot instead of one per column (float: _CF_* columns,
        # int: _CI_* columns)
        self._colf = np.zeros((size, 6))
        self._coli = np.zeros((size, 2), dtype=np.int64)
        self._colf[:, _CF_POWER] = 1.0
        self._colf[:, _CF_DEFER] = -1e18
        self._colf[:, _CF_LASTMIG] = -1e18
        self._coli[:, _CI_STATE] = _STATE_CODE["pending"]
        for j in self.jobs:
            jid = j.jid
            self._coli[jid, _CI_SITE] = j.site
            self._coli[jid, _CI_STATE] = _STATE_CODE[j.state]
            self._colf[jid, _CF_CKPT] = j.ckpt_bytes
            self._colf[jid, _CF_COMPUTE] = j.compute_s
            self._colf[jid, _CF_PROGRESS] = j.progress_s
            self._colf[jid, _CF_POWER] = j.power_frac
            self._colf[jid, _CF_DEFER] = j.defer_until_s
            self._colf[jid, _CF_LASTMIG] = j.last_migration_end_s

    # -- (site, state) bucket maintenance -----------------------------------
    _SITE_STATES = ("queued", "running")

    def _index_add(self, j: SimJob) -> None:
        self._by_state[j.state][j.jid] = j
        if j.state in self._SITE_STATES:
            self._site_jobs.setdefault((j.site, j.state), {})[j.jid] = j

    def _index_remove(self, j: SimJob) -> None:
        self._by_state[j.state].pop(j.jid, None)
        if j.state in self._SITE_STATES:
            bucket = self._site_jobs.get((j.site, j.state))
            if bucket is not None:
                bucket.pop(j.jid, None)

    def _move(self, j: SimJob, state: Optional[str] = None,
              site: Optional[int] = None) -> None:
        self._index_remove(j)
        if state is not None:
            if j.state == "running":
                # progress only advances while running; sync the column as
                # the job leaves (snapshot refreshes the running bucket)
                self._colf[j.jid, _CF_PROGRESS] = j.progress_s
            j.state = state
            self._coli[j.jid, _CI_STATE] = _STATE_CODE[state]
        if site is not None:
            j.site = site
            self._coli[j.jid, _CI_SITE] = site
        self._index_add(j)

    def _running_count(self, sid: int) -> int:
        return len(self._site_jobs.get((sid, "running"), ()))

    def _queued_count(self, sid: int) -> int:
        return len(self._site_jobs.get((sid, "queued"), ()))

    # -- WAN model -----------------------------------------------------------
    def _nic_bps(self, t: float) -> float:
        """Legacy scalar view (uniform fabrics): the NIC rate at time t."""
        return self.wan_topology.nic_bps_at(t)

    def _effective_bw(self, transfers: List[SimJob], t: float) -> Dict[int, float]:
        """Per-transfer effective bps — the topology's fair share over the
        current flow set (the same model the snapshot advertises)."""
        rates = self.wan_topology.shared_rates(
            [(j.site, j.transfer_dest) for j in transfers], t)
        return {j.jid: float(r) for j, r in zip(transfers, rates)}

    # -- snapshot ------------------------------------------------------------
    def snapshot(self, t: float) -> ClusterState:
        """Build the policy-facing ClusterState from the incremental SoA
        columns (no per-job objects — ``state.jobs`` materializes lazily
        if a scalar consumer asks).  The advertised bandwidth matrix comes
        from the same WanTopology (and flow set) the transfer loop grants
        from; the per-site forecasts are drawn batched, consuming the
        forecaster's noise streams exactly as the per-site scalar calls
        would."""
        cfg = self.cfg
        incoming = [0] * cfg.n_sites
        transfers: List[Tuple[int, int]] = []
        for j in self._by_state["migrating"].values():
            incoming[j.transfer_dest] += 1
            transfers.append((j.site, j.transfer_dest))
        for j in self._by_state["loading"].values():
            incoming[j.site] += 1
        if self.serving is not None:
            # routed request batches occupy the same WAN resources as
            # checkpoint transfers — the advertised matrix must dilute
            # against them too
            transfers.extend(self.serving.flow_pairs())
        active, remaining, next_start = self.forecaster.snapshot_all(t)
        busy = np.array([self._running_count(s) for s in range(cfg.n_sites)],
                        dtype=np.int64)
        queued = np.array([self._queued_count(s) for s in range(cfg.n_sites)],
                          dtype=np.int64)
        inc = np.array(incoming, dtype=np.int64)
        slots = max(cfg.slots_per_site, 1)
        site_arrays = {
            "site_window_s": remaining,
            "site_renewable": active,
            "site_next_window_s": next_start,
            "site_busy": busy,
            "site_slots": self._site_slots_arr,
            "site_load": (busy + queued + inc) / slots,
            "site_free_slots": np.maximum(0, cfg.slots_per_site - busy - inc),
            "site_bq_load": (busy + queued) / slots,
        }
        if cfg.battery is not None:
            # battery timelines are advanced lazily at posting time; the
            # snapshot advertises the ledger's current per-site state of
            # charge (policies treat it as a lower bound — charge landed
            # since a site's last posting shows up at the next one)
            site_arrays["site_battery_soc"] = self.ledger.soc.copy()
        if self.fault_plan is not None:
            # fault-aware policies mask these down; with no active
            # regime the keys stay unseeded and ClusterState's all-up
            # cached-property defaults cost nothing
            site_arrays["site_up"] = self._site_up.copy()
            site_arrays["link_up"] = self._link_up.copy()
        def sites_factory():  # scalar consumers only (lazy)
            return [
                SiteView(
                    sid=s,
                    slots=cfg.slots_per_site,
                    busy=int(busy[s]),
                    queued=int(queued[s]),
                    renewable_active=bool(active[s]),
                    window_remaining_s=float(remaining[s]),
                    incoming=incoming[s],
                    next_window_start_s=float(next_start[s]),
                )
                for s in range(cfg.n_sites)
            ]
        by = self._by_state
        for j in by["running"].values():  # progress advances while running
            self._colf[j.jid, _CF_PROGRESS] = j.progress_s
        jid_list = list(by["queued"])
        jid_list += by["running"]
        jid_list += by["paused"]
        jids = np.array(jid_list, dtype=np.int64)
        jids.sort()
        gf = self._colf[jids]  # one gather for all float columns
        gi = self._coli[jids]
        soa = JobSoA(
            jids=jids,
            site=gi[:, _CI_SITE],
            ckpt_bytes=gf[:, _CF_CKPT],
            remaining_s=gf[:, _CF_COMPUTE] - gf[:, _CF_PROGRESS],
            t_load_s=self._tload_buf[:len(jids)],
            state=gi[:, _CI_STATE],
            eligible=t - gf[:, _CF_LASTMIG] >= cfg.migration_cooldown_s,
            power_frac=gf[:, _CF_POWER],
            defer_until_s=gf[:, _CF_DEFER],
            n_queued=len(by["queued"]),
            n_running=len(by["running"]),
            n_paused=len(by["paused"]),
        )
        return ClusterState.build_soa(t, soa, sites_factory,
                                      n_sites=cfg.n_sites,
                                      wan=self.wan_topology,
                                      transfers=transfers,
                                      forecast=self.forecast_horizon,
                                      site_arrays=site_arrays,
                                      battery=cfg.battery,
                                      serving=(self.serving.view()
                                               if self.serving is not None
                                               else None))

    def _serving_state(self, t: float) -> ClusterState:
        """Light routing snapshot for the serving plane's per-batch
        dispatch.  Unlike :meth:`snapshot` it reads the *noise-free*
        trace stack (``TraceStack.point``), NOT the forecaster — batch
        dispatches happen at request-driven times, and drawing forecast
        noise there would shift the forecaster's RNG stream and break
        the serving-off ⇒ bit-identical guarantee.  Jobs are omitted
        (routers read sites, forecast, WAN and the serving view only)."""
        cfg = self.cfg
        topo = self.wan_topology
        active, remaining, next_start = self._serve_stack.point(t)
        busy = np.array([self._running_count(s) for s in range(cfg.n_sites)],
                        dtype=np.int64)
        site_arrays = {
            "site_window_s": remaining,
            "site_renewable": active,
            "site_next_window_s": next_start,
            "site_busy": busy,
            "site_slots": self._site_slots_arr,
        }
        transfers = [(j.site, j.transfer_dest)
                     for j in self._by_state["migrating"].values()]
        transfers += self.serving.flow_pairs()

        def sites_factory():  # scalar consumers only (rare)
            return [
                SiteView(sid=s, slots=cfg.slots_per_site, busy=int(busy[s]),
                         queued=self._queued_count(s),
                         renewable_active=bool(active[s]),
                         window_remaining_s=float(remaining[s]),
                         next_window_start_s=float(next_start[s]))
                for s in range(cfg.n_sites)
            ]

        # bandwidth: the uncontended capacity matrix (cached per link
        # state) — routers do admission via post_admission_bps, which
        # re-splits against `transfers` through the topology anyway
        return ClusterState.build_soa(
            t, self._empty_soa, sites_factory, n_sites=cfg.n_sites,
            wan=topo, transfers=tuple(transfers),
            bandwidth_bps=topo.capacity_matrix(t),
            forecast=self.forecast_horizon, site_arrays=site_arrays,
            serving=self.serving.view())

    def _has_live_jobs(self) -> bool:
        by = self._by_state
        return bool(by["queued"] or by["running"] or by["paused"])

    # -- action application --------------------------------------------------
    def _apply_action(self, action: Action, t: float, state: ClusterState,
                      horizon: float) -> None:
        if not isinstance(action, Action):
            # e.g. a legacy (jid, dest) tuple from a pre-redesign policy
            self.rejected_actions += 1
            return
        j = self._jobs_by_id.get(action.jid)
        if j is None:
            self.rejected_actions += 1
            return
        if isinstance(action, Migrate):
            dest = action.dest
            if (j.state != "running" or dest == j.site
                    or not 0 <= dest < self.cfg.n_sites
                    or t - j.last_migration_end_s < self.cfg.migration_cooldown_s
                    # a 0-capacity (partitioned) path can never complete the
                    # transfer — admitting it would strand the job forever
                    or not self.wan_topology.reachable(j.site, dest)):
                self.rejected_actions += 1
                return
            j.transfer_dest = dest
            j.transfer_remaining_bits = 8.0 * j.ckpt_bytes
            j.migrations += 1
            self.migrations += 1
            if j.retry_attempts > 0:
                # re-admission after a watchdog abort: one rung up the
                # retry ladder; a different destination is a re-route
                self.retries += 1
                if dest != j.last_failed_dest:
                    self.reroutes += 1
            self._move(j, state="migrating")
            # a migration whose destination window closes before the
            # transfer ends is counted as failed (it still completes,
            # but arrives onto grid power — the paper's stall mode).
            # The arrival estimate uses the POST-admission share: this
            # flow itself dilutes every resource it traverses (flows+1),
            # so ask the topology for the rate with the flow included —
            # the snapshot's pre-admission matrix is systematically
            # optimistic for exactly this query.
            mig = list(self._by_state["migrating"].values())
            pairs = [(x.site, x.transfer_dest) for x in mig]
            if self.serving is not None:
                pairs += self.serving.flow_pairs()  # requests dilute too
            rates = self.wan_topology.shared_rates(pairs, t)
            rate = next(float(r) for x, r in zip(mig, rates) if x.jid == j.jid)
            t_arrive = (t + j.transfer_remaining_bits / rate if rate > 0.0
                        else float("inf"))
            # Post-horizon arrivals are explicitly failed: the trace carries
            # no windows beyond the horizon, and the old clamp to
            # horizon - 1 classified such a transfer by whatever the last
            # in-horizon sample happened to be.
            j.fail_counted = (t_arrive >= horizon
                              or not self.traces[dest].active(t_arrive))
            if j.fail_counted:
                self.failed_migrations += 1
        elif isinstance(action, Defer):
            if j.state != "queued":
                self.rejected_actions += 1
                return
            j.defer_until_s = max(t, float(action.until_s))
            self._colf[j.jid, _CF_DEFER] = j.defer_until_s
        elif isinstance(action, Pause):
            if j.state != "running":
                self.rejected_actions += 1
                return
            self._move(j, state="paused")
        elif isinstance(action, Resume):
            if j.state != "paused":
                self.rejected_actions += 1
                return
            self._move(j, state="queued")
        elif isinstance(action, Throttle):
            if j.state != "running":
                self.rejected_actions += 1
                return
            j.power_frac = float(min(1.0, max(0.0, action.power_frac)))
            curve = self.cfg.throttle_curve
            j.tput_frac = (j.power_frac if curve is None
                           else curve.throughput(j.power_frac))
            self._colf[j.jid, _CF_POWER] = j.power_frac
        else:
            self.rejected_actions += 1

    # -- engine dispatch -----------------------------------------------------
    def run(self) -> SimResult:
        if self.cfg.engine == "event":
            return self._run_event()
        if self.cfg.engine == "fixed-dt":
            return self._run_fixed_dt()
        raise ValueError(
            f"unknown engine {self.cfg.engine!r}; use 'event' or 'fixed-dt'")

    def _result(self, wall_t0: float) -> SimResult:
        serving_kw = {}
        if self.serving is not None:
            srv = self.serving
            p50, p95, p99 = srv.latency_percentiles()
            serving_kw = dict(
                requests_arrived=srv.arrived,
                requests_served=srv.served,
                requests_dropped=srv.dropped,
                requests_shed=srv.shed,
                slo_violations=srv.slo_violations,
                request_gco2=srv.request_gco2,
                site_request_gco2=tuple(float(x)
                                        for x in srv.site_request_gco2),
                serve_grid_kwh=srv.serve_grid_kwh,
                serve_renewable_kwh=srv.serve_renewable_kwh,
                latency_p50_s=p50, latency_p95_s=p95, latency_p99_s=p99,
                queue_depth_p95=srv.queue_depth_p95(),
            )
        led = self.ledger
        # run every site's battery/sell-back timeline out to the end of
        # the horizon (idle sites still charge + export); no-op with
        # storage disabled
        led.finalize(self.cfg.days * 24 * HOUR * 2.0)
        # A transfer still in flight at the horizon never delivered its
        # checkpoint.  The admission pre-count misses exactly the
        # dead-link case: the optimistic (fault-free) arrival estimate
        # is finite, so fail_counted stays False while the transfer
        # silently stalls to the end of the run.  Only fault regimes can
        # zero a link outside the brownout calendar, so the sweep is
        # gated on an active plan and faults-off runs keep their
        # historical accounting.
        if self.fault_plan is not None:
            for j in self._by_state["migrating"].values():
                if not j.fail_counted:
                    j.failed_migrations += 1
                    self.failed_migrations += 1
        self.audit_no_job_lost()
        site_outages, mttr_s = 0, 0.0
        if self.fault_plan is not None:
            site_outages, mttr_s = self.fault_plan.outage_stats(
                max(self._final_t, 0.0))
        return SimResult(
            policy=self.policy.name,
            jobs=self.jobs,
            grid_kwh=led.grid_kwh,
            renewable_kwh=led.renewable_kwh,
            migration_kwh=led.migration_kwh,
            migrations=self.migrations,
            failed_migrations=self.failed_migrations,
            failures=self.failures,
            rejected_actions=self.rejected_actions,
            ticks=self.ticks,
            wall_time_s=time.perf_counter() - wall_t0,
            decide_s=self.decide_s,
            decide_first_s=self.decide_first_s,
            engine=self.cfg.engine,
            grid_gco2=led.grid_gco2,
            grid_cost=led.grid_cost,
            site_grid_gco2=tuple(float(x) for x in led.site_grid_gco2),
            site_grid_cost=tuple(float(x) for x in led.site_grid_cost),
            battery_charge_kwh=led.battery_charge_kwh,
            battery_discharge_kwh=led.battery_discharge_kwh,
            battery_loss_kwh=led.battery_loss_kwh,
            battery_cycles=led.battery_cycles,
            sellback_kwh=led.sellback_kwh,
            sellback_usd=led.sellback_usd,
            dr_requested_ws=led.dr_requested_ws,
            dr_shed_ws=led.dr_shed_ws,
            site_outages=site_outages,
            mttr_s=mttr_s,
            retries=self.retries,
            reroutes=self.reroutes,
            replica_crashes=self.replica_crashes,
            watchdog_aborts=self.watchdog_aborts,
            **serving_kw,
        )

    def audit_no_job_lost(self) -> None:
        """No-job-lost invariant: every admitted job is in exactly one
        lifecycle bucket, each bucket is internally consistent, and a
        job that is not ``done`` is live in a recoverable state (never
        silently dropped by a fault).  Holds for arbitrary fault
        sequences; raises ``AssertionError`` on violation."""
        seen: set = set()
        for name, bucket in self._by_state.items():
            for jid, j in bucket.items():
                assert jid not in seen, f"job {jid} indexed twice"
                seen.add(jid)
                assert j.state == name, (
                    f"job {jid} in bucket {name!r} but state {j.state!r}")
                if name == "done":
                    assert j.done_s >= 0.0, f"done job {jid} missing done_s"
                else:
                    assert j.done_s < 0.0, (
                        f"finished job {jid} stuck in {name!r}")
        assert len(seen) == len(self.jobs), (
            f"{len(self.jobs) - len(seen)} job(s) lost from the index")

    # -- next-event engine ---------------------------------------------------
    def _record_decide(self, dt: float) -> None:
        """Attribute one decide's wall time: the run's FIRST call (XLA
        compile, lazy caches — cold by construction) lands in
        ``decide_first_s``; every later (warm) tick accumulates in
        ``decide_s``, the number benchmarks gate on."""
        if self._decide_calls == 0:
            self.decide_first_s = dt
        else:
            self.decide_s += dt
        self._decide_calls += 1

    def _run_event(self) -> SimResult:
        """Drive :meth:`_event_gen` to completion with this simulator's
        own policy (the batched sweep runner drives many generators in
        lockstep instead, answering whole groups of yielded snapshots
        with one ``Policy.decide_batch`` call)."""
        wall_t0 = time.perf_counter()
        gen = self._event_gen()
        actions: Optional[List[Action]] = None
        while True:
            try:
                state = gen.send(actions)
            except StopIteration:
                break
            d0 = time.perf_counter()
            actions = self.policy.decide(state)
            self._record_decide(time.perf_counter() - d0)
        return self._result(wall_t0)

    def _event_gen(self):
        """Next-event time stepping as a coroutine: yields the
        ``ClusterState`` snapshot at every orchestrator tick and resumes
        with the caller's action list (``actions = gen.send(...)``).

        Every candidate next event is the min of: next job arrival, the
        earliest transfer completion at current share rates, the earliest
        checkpoint-load completion, the earliest running-job completion,
        the next renewable-window edge, the next WAN brownout edge, the
        next defer expiry, the next node failure, and the next orchestrator
        tick.  Per-job accounting (progress, grid/renewable kWh, queue and
        pause time) is integrated analytically over each inter-event span
        from a per-job ``anchor_s``; transfer rates are re-split only when
        the flow set or the link state changes.  Completion heaps use lazy
        invalidation: entries carry the job's ``ver`` at push time and are
        discarded on pop if the job changed since.
        """
        cfg = self.cfg
        horizon = cfg.days * 24 * HOUR
        t_end = horizon * 2.0  # allow the tail of late jobs to finish
        INF = float("inf")
        EPS = 1e-6
        by_state = self._by_state
        jobs_by_id = self._jobs_by_id
        topo = self.wan_topology
        traces = self.traces
        serving = self.serving
        ledger = self.ledger
        n_jobs = len(self.jobs)
        p_node, p_sys = cfg.p_node_kw, cfg.p_sys_kw

        done_heap: List[Tuple[float, int, int]] = []  # running completions
        transfer_heap: List[Tuple[float, int, int]] = []
        load_heap: List[Tuple[float, int, int]] = []
        defer_heap: List[Tuple[float, int]] = []
        stall_heap: List[Tuple[float, int]] = []  # watchdog deadlines
        edges = sorted({e for tr in traces for w in tr.windows
                        for e in (w.start_s, w.end_s) if 0.0 < e < t_end})
        eptr = 0
        next_orch = 0.0
        next_brownout = topo.next_transition(0.0)
        next_failure = INF
        # legacy per-job Poisson rollback: the SimConfig scalar is the
        # alias path; a FaultRegime's job_failure rate adds to it
        fail_rate = cfg.failure_rate_per_slot_hour + (
            cfg.faults.job_failure_rate_per_slot_hour
            if cfg.faults is not None else 0.0)
        fail_enabled = fail_rate > 0.0
        # fault plan + recovery machinery.  With no active regime every
        # hook below is None-gated: zero extra draws, zero float ops.
        plan = self.fault_plan
        regime = cfg.faults
        ckpt_interval = cfg.checkpoint_interval_s
        if regime is not None and regime.checkpoint_interval_s is not None:
            ckpt_interval = regime.checkpoint_interval_s
        corrupt_p = regime.ckpt_corruption_prob if plan is not None else 0.0
        corrupt_rng = (plan.corruption_rng()
                       if plan is not None and corrupt_p > 0.0 else None)
        stall_timeout = (regime.stall_timeout_s if regime is not None
                         else cfg.stall_timeout_s)
        retry = regime.retry if regime is not None else RetryPolicy()
        fault_tput: Optional[np.ndarray] = None
        next_fault = INF
        if plan is not None:
            self._site_up = plan.site_up_vec(0.0)
            self._link_up = plan.link_up_mat(0.0)
            if serving is not None:
                self._replica_down = plan.replica_down_vec(0.0)
            if regime.straggler_rate_per_day > 0.0:
                fault_tput = plan.tput_factor_vec(0.0)
            next_fault = plan.next_edge_after(0.0)

        def resample_failure(t: float) -> None:
            nonlocal next_failure
            n_run = len(by_state["running"])
            if not fail_enabled or n_run == 0:
                next_failure = INF
                return
            lam = fail_rate * n_run / HOUR
            next_failure = t + float(self._fail_rng.exponential(1.0 / lam))

        def rollback(j: SimJob) -> None:
            """Roll a (flushed) job back to its last checkpoint; with
            corruption enabled, a Bernoulli draw can cost one more
            interval (its own RNG stream — one draw per rollback)."""
            ckpt = (j.progress_s // ckpt_interval) * ckpt_interval
            if corrupt_rng is not None and corrupt_rng.random() < corrupt_p:
                ckpt = max(0.0, ckpt - ckpt_interval
                           * regime.ckpt_corruption_extra_intervals)
            lost = j.progress_s - ckpt
            j.progress_s = ckpt
            j.last_ckpt_progress_s = ckpt
            j.pause_s += lost

        def flush(j: SimJob, t: float) -> None:
            span = t - j.anchor_s
            if span <= 0.0:
                j.anchor_s = t
                return
            st = j.state
            if st == "running":
                frac = j.power_frac
                tput = j.tput_frac
                if fault_tput is not None:  # straggler degradation
                    tput = tput * fault_tput[j.site]
                j.progress_s += span * tput
                g = traces[j.site].renewable_seconds(j.anchor_s, t)
                e_g, e_b = ledger.post_train(
                    j.site, p_node * frac, j.anchor_s, t, g,
                    p_nominal_kw=p_node)
                j.renewable_kwh += e_g
                j.grid_kwh += e_b
            elif st == "migrating":
                j.transfer_remaining_bits -= j.rate_bps * span
                j.pause_s += span
                j.pause_transfer_s += span
                ledger.post_migration(j.site, p_sys, j.anchor_s, t)
            elif st == "loading":
                j.load_remaining_s -= span
                j.pause_s += span
                j.pause_transfer_s += span
            elif st == "queued":
                j.queue_s += span
                if j.post_migration_wait:
                    j.pause_s += span  # stalled by its own migration
                    j.pause_wait_s += span
            elif st == "paused":
                j.paused_policy_s += span
            j.anchor_s = t

        def flush_live(t: float) -> None:
            for name in ("running", "queued", "paused", "migrating", "loading"):
                for j in by_state[name].values():
                    flush(j, t)

        def flush_running(t: float) -> None:
            # the snapshot only reads *running* progress; every other
            # state's accounting is flushed at its own transitions
            for j in by_state["running"].values():
                flush(j, t)

        def refresh_transfers(t: float) -> None:
            """Re-split in-flight transfer rates (flow set / link state
            changed) and requeue their completion events.  Checkpoint
            migrations and routed request batches form ONE flow set over
            the shared topology — each dilutes the other."""
            mig = list(by_state["migrating"].values())
            srv_pairs = serving.flow_pairs() if serving is not None else []
            if not mig and not srv_pairs:
                return
            pairs = [(j.site, j.transfer_dest) for j in mig] + srv_pairs
            rates = topo.shared_rates(pairs, t)
            if plan is not None:
                # hard fault overlay: the topology stays pure (it only
                # knows the *scheduled* brownout calendar) — a failed
                # link or a blacked-out endpoint zeroes the flow here
                lu = self._link_up
                rates = [r if lu[a, b] else 0.0
                         for (a, b), r in zip(pairs, rates)]
            for j, r in zip(mig, rates):
                flush(j, t)
                j.rate_bps = float(r)
                j.ver += 1
                if j.rate_bps > 0.0:
                    # link (re)carrying traffic: a partial transfer
                    # resumes from its surviving remaining_bits
                    j.stall_since_s = -1.0
                    heapq.heappush(
                        transfer_heap,
                        (t + j.transfer_remaining_bits / j.rate_bps,
                         j.jid, j.ver))
                # rate 0 (no link / browned out to zero / hard fault):
                # no completion until a link-state change re-rates the
                # flow — arm the stall watchdog so a path that never
                # recovers can no longer strand the job forever
                elif j.stall_since_s < 0.0:
                    j.stall_since_s = t
                    heapq.heappush(stall_heap, (t + stall_timeout, j.jid))
            if serving is not None and srv_pairs:
                serving.rerate(t, rates[len(mig):])

        def push_run_completion(j: SimJob, t: float) -> None:
            j.ver += 1
            tput = j.tput_frac
            if fault_tput is not None:  # straggler degradation
                tput = tput * fault_tput[j.site]
            if tput > 0.0:
                heapq.heappush(
                    done_heap,
                    (t + (j.compute_s - j.progress_s) / tput,
                     j.jid, j.ver))

        def schedule_site(s: int, t: float) -> None:
            if plan is not None and not self._site_up[s]:
                return  # blacked out: no slots until repair
            q = self._site_jobs.get((s, "queued"))
            if not q:
                return
            free = cfg.slots_per_site - self._running_count(s)
            if free <= 0:
                return
            ready = [j for j in q.values() if j.defer_until_s <= t]
            if not ready:
                return
            ready.sort(key=lambda x: (x.arrival_s, x.jid))
            for j in ready[:free]:
                flush(j, t)
                j.post_migration_wait = False
                if j.started_s < 0:
                    j.started_s = t
                self._move(j, state="running")
                j.anchor_s = t
                push_run_completion(j, t)

        def peek(heap: List[Tuple[float, int, int]], want_state: str) -> float:
            while heap:
                tt, jid, ver = heap[0]
                j = jobs_by_id[jid]
                if j.state == want_state and j.ver == ver:
                    return tt
                heapq.heappop(heap)
            return INF

        def peek_stall() -> float:
            """Next valid watchdog deadline.  Entries are validated
            against the job's live stall state: recovered (or finished)
            transfers drop out; a transfer that stalled again later is
            re-pushed at its fresh ``stall_since + timeout`` deadline."""
            while stall_heap:
                tt, jid = stall_heap[0]
                j = jobs_by_id[jid]
                if (j.state != "migrating" or j.rate_bps > 0.0
                        or j.stall_since_s < 0.0):
                    heapq.heappop(stall_heap)
                    continue
                due = j.stall_since_s + stall_timeout
                if tt < due - EPS:
                    heapq.heappop(stall_heap)
                    heapq.heappush(stall_heap, (due, jid))
                    continue
                return tt
            return INF

        def watchdog_abort(j: SimJob, t: float) -> None:
            """Abort a dead in-flight transfer: the checkpoint never
            left the source, so the job requeues there; the retry ladder
            (bounded attempts, exponential backoff via the migration-
            eligibility clock) decides when it may try again."""
            flush(j, t)
            dest = j.transfer_dest
            j.transfer_remaining_bits = 0.0
            j.transfer_dest = -1
            j.rate_bps = 0.0
            j.stall_since_s = -1.0
            j.last_failed_dest = dest
            j.retry_attempts += 1
            j.failed_migrations += 1
            self.watchdog_aborts += 1
            if not j.fail_counted:
                self.failed_migrations += 1
            j.fail_counted = False
            j.ver += 1
            j.post_migration_wait = True  # queue wait = its own stall
            if j.retry_attempts >= retry.max_attempts:
                # out of retries: the job still runs locally — it is
                # simply never offered for migration again
                j.last_migration_end_s = 1e18
            else:
                backoff = retry.backoff_s(j.retry_attempts)
                j.last_migration_end_s = t + max(
                    0.0, backoff - cfg.migration_cooldown_s)
            self._colf[j.jid, _CF_LASTMIG] = j.last_migration_end_s
            self._move(j, state="queued")
            j.anchor_s = t

        def apply_fault_edges(t: float, dirty: set) -> bool:
            """Advance the live fault-state caches across the plan edges
            at ``t``: blackout starts roll back + requeue the site's
            workers, repairs re-open scheduling, straggler flips re-rate
            running completions, replica crashes/returns reach the
            serving plane.  Returns True when WAN flows must re-rate."""
            nonlocal fault_tput
            new_site_up = plan.site_up_vec(t)
            new_link_up = plan.link_up_mat(t)
            link_changed = not np.array_equal(new_link_up, self._link_up)
            started = (~new_site_up) & self._site_up
            repaired = new_site_up & (~self._site_up)
            for s in np.nonzero(started)[0]:
                s = int(s)
                # running jobs: every slot is down — checkpoint
                # rollback (corruption possible) and back to the queue
                for j in list(self._site_jobs.get((s, "running"),
                                                  {}).values()):
                    flush(j, t)
                    rollback(j)
                    self.failures += 1
                    j.ver += 1
                    self._move(j, state="queued")
                    j.anchor_s = t
                # interrupted checkpoint loads: the checkpoint landed
                # intact — the arrival requeues and waits out the repair
                for j in [x for x in by_state["loading"].values()
                          if x.site == s]:
                    flush(j, t)
                    j.load_remaining_s = 0.0
                    j.post_migration_wait = True
                    j.last_migration_end_s = t
                    self._colf[j.jid, _CF_LASTMIG] = t
                    j.ver += 1
                    self._move(j, state="queued")
                    j.anchor_s = t
            for s in np.nonzero(repaired)[0]:
                dirty.add(int(s))  # freed slots: schedule FIFO below
            self._site_up = new_site_up
            self._link_up = new_link_up
            if fault_tput is not None:
                new_tput = plan.tput_factor_vec(t)
                flipped = np.nonzero(new_tput != fault_tput)[0]
                if len(flipped):
                    affected = []
                    for s in flipped:
                        affected.extend(self._site_jobs.get(
                            (int(s), "running"), {}).values())
                    for j in affected:
                        flush(j, t)  # old factor up to t
                    fault_tput = new_tput
                    for j in affected:
                        push_run_completion(j, t)  # new factor from t
            if serving is not None:
                new_rep = plan.replica_down_vec(t)
                for s in np.nonzero(new_rep & ~self._replica_down)[0]:
                    link_changed |= serving.crash_replica(int(s), t)
                    self.replica_crashes += 1
                for s in np.nonzero(self._replica_down & ~new_rep)[0]:
                    link_changed |= serving.repair_replica(int(s), t)
                self._replica_down = new_rep
            return link_changed

        arrivals = self._arrivals
        # span-advance fast path: a chunked plane exposes process_span;
        # the scalar plane (serving_engine="event") does not, keeping the
        # historical one-heap-event-per-request interleave
        serving_span = getattr(serving, "process_span", None)
        t = 0.0
        while (len(by_state["done"]) < n_jobs
               or (serving is not None and serving.pending())):
            t_arr = (arrivals[self._arrival_ptr].arrival_s
                     if self._arrival_ptr < len(arrivals) else INF)
            t_ld = peek(load_heap, "loading")
            t_df = defer_heap[0][0] if defer_heap else INF
            t_ed = edges[eptr] if eptr < len(edges) else INF
            t_other = min(t_arr, peek(transfer_heap, "migrating"), t_ld,
                          t_df, peek(done_heap, "running"), t_ed,
                          next_brownout, next_failure, next_orch,
                          next_fault, peek_stall())
            t_srv = serving.next_event_s() if serving is not None else INF
            if (serving_span is not None and t_srv < t_other - EPS
                    and t_srv <= t_end):
                # every serving event strictly clear of the next engine
                # event advances in one span (one engine iteration per
                # event the per-event path would have ticked through);
                # events that could coalesce with an engine event fall
                # through to the normal tick below
                n_ev, t_last, fdirty = serving_span(t_other - EPS, t_end,
                                                    EPS)
                if n_ev:
                    t = t_last
                    self.ticks += n_ev
                    if fdirty:
                        refresh_transfers(t_last)
                    continue
            t_next = t_other if t_other < t_srv else t_srv
            if t_next > t_end:
                flush_live(t_end)  # account the unfinished tail to horizon
                break
            t = t_next
            self.ticks += 1
            dirty: set = set()
            transfers_dirty = False
            n_run_before = len(by_state["running"])

            # 1) arrivals
            while (self._arrival_ptr < len(arrivals)
                   and arrivals[self._arrival_ptr].arrival_s <= t + EPS):
                j = arrivals[self._arrival_ptr]
                self._arrival_ptr += 1
                if j.state == "pending":
                    self._move(j, state="queued")
                    j.anchor_s = t
                    dirty.add(j.site)
            # 2) WAN brownout edge: link capacities changed
            if next_brownout <= t + EPS:
                transfers_dirty = True
                next_brownout = topo.next_transition(t + EPS)
            # 2b) fault-plan span edges: blackouts start/repair, links
            #     fail/recover, straggler factors flip, replicas crash
            if plan is not None and next_fault <= t + EPS:
                transfers_dirty |= apply_fault_edges(t, dirty)
                next_fault = plan.next_edge_after(t + EPS)
            # 3) transfer completions (at current share rates)
            while peek(transfer_heap, "migrating") <= t + EPS:
                _, jid, _ = heapq.heappop(transfer_heap)
                j = jobs_by_id[jid]
                flush(j, t)
                j.transfer_remaining_bits = 0.0
                dest = j.transfer_dest
                j.transfer_dest = -1
                j.rate_bps = 0.0
                j.load_remaining_s = cfg.t_load_s + cfg.t_downtime_s
                self._move(j, state="loading", site=dest)
                j.anchor_s = t
                heapq.heappush(load_heap, (t + j.load_remaining_s, jid,
                                           j.ver))
                transfers_dirty = True
            # 4) checkpoint-load completions (ver-checked: a blackout can
            #    interrupt a load and requeue the job before this fires)
            while peek(load_heap, "loading") <= t + EPS:
                _, jid, _ = heapq.heappop(load_heap)
                j = jobs_by_id[jid]
                flush(j, t)
                j.load_remaining_s = 0.0
                j.post_migration_wait = True
                j.last_migration_end_s = t
                self._colf[jid, _CF_LASTMIG] = t
                j.retry_attempts = 0  # a landed migration resets the ladder
                j.last_failed_dest = -1
                self._move(j, state="queued")
                j.anchor_s = t
                dirty.add(j.site)
            # 5) defer expiries: the held job becomes schedulable
            while defer_heap and defer_heap[0][0] <= t + EPS:
                _, jid = heapq.heappop(defer_heap)
                j = jobs_by_id[jid]
                if j.state == "queued":
                    dirty.add(j.site)
            # 6) running-job completions
            while peek(done_heap, "running") <= t + EPS:
                _, jid, _ = heapq.heappop(done_heap)
                j = jobs_by_id[jid]
                flush(j, t)
                j.progress_s = j.compute_s
                j.done_s = t
                dirty.add(j.site)
                self._move(j, state="done")
            # 7) node failure: roll back to the last checkpoint
            if next_failure <= t + EPS:
                running = by_state["running"]
                if running:
                    jids = sorted(running)
                    jid = jids[int(self._fail_rng.integers(len(jids)))]
                    j = running[jid]
                    flush(j, t)
                    interval = cfg.checkpoint_interval_s
                    ckpt = (j.progress_s // interval) * interval
                    lost = j.progress_s - ckpt
                    j.progress_s = ckpt
                    j.last_ckpt_progress_s = ckpt
                    j.pause_s += lost
                    self.failures += 1
                    push_run_completion(j, t)
                resample_failure(t)
            # 8) renewable-window edges: pure span boundaries (energy is
            #    integrated analytically, so only the pointer advances)
            while eptr < len(edges) and edges[eptr] <= t + EPS:
                eptr += 1
            # 8b) serving events: request arrivals, batch closes, routed-
            #     batch landings, service completions.  A changed flow set
            #     re-splits EVERY WAN rate below (migrations included)
            if serving is not None and t_srv <= t + EPS:
                transfers_dirty |= serving.process(t, EPS)
            if transfers_dirty:
                refresh_transfers(t)
                transfers_dirty = False
            # 8c) transfer-stall watchdog: rates are fresh now — any
            #     transfer still at rate 0 past its deadline aborts,
            #     requeues at the source and climbs the retry ladder
            #     (the freed flow re-rates the survivors)
            if peek_stall() <= t + EPS:
                while peek_stall() <= t + EPS:
                    _, jid = heapq.heappop(stall_heap)
                    watchdog_abort(jobs_by_id[jid], t)
                    dirty.add(jobs_by_id[jid].site)
                refresh_transfers(t)
            # 9) scheduling: fill freed slots at touched sites, FIFO
            for s in sorted(dirty):
                schedule_site(s, t)
            dirty.clear()
            # 10) orchestrator tick: snapshot -> typed actions -> apply
            if next_orch <= t + EPS:
                next_orch = t + cfg.orch_dt_s
                if self._has_live_jobs():
                    flush_running(t)
                    state = self.snapshot(t)
                    actions = yield state
                    for action in actions:
                        j = (jobs_by_id.get(action.jid)
                             if isinstance(action, Action) else None)
                        pre = ((j.state, j.tput_frac, j.defer_until_s)
                               if j is not None else None)
                        if j is not None:
                            flush(j, t)  # account up to t before any move
                        self._apply_action(action, t, state, horizon)
                        if j is None:
                            continue
                        st0, tput0, defer0 = pre
                        if j.state != st0:
                            dirty.add(j.site)  # slot freed / job re-queued
                            if j.state == "migrating":
                                transfers_dirty = True
                        if j.tput_frac != tput0:
                            push_run_completion(j, t)  # throttle re-rates
                        if j.defer_until_s != defer0:
                            dirty.add(j.site)
                            if j.defer_until_s > t:
                                heapq.heappush(
                                    defer_heap, (j.defer_until_s, j.jid))
                    if transfers_dirty:
                        refresh_transfers(t)
                    for s in sorted(dirty):
                        schedule_site(s, t)
            if fail_enabled and len(by_state["running"]) != n_run_before:
                resample_failure(t)
        self._final_t = t

    # -- legacy fixed-dt engine (parity reference) ---------------------------
    def _run_fixed_dt(self) -> SimResult:
        if self.serving is not None:
            raise ValueError(
                "the serving plane requires the next-event engine; "
                "use engine='event' (fixed-dt is the training-only "
                "parity reference)")
        if self.cfg.faults is not None:
            raise ValueError(
                "fault injection (SimConfig.faults) requires the "
                "next-event engine; use engine='event' (blackout/"
                "link-failure edges and the stall watchdog are "
                "event sources, not tick samples)")
        if self.cfg.battery is not None:
            raise ValueError(
                "battery storage requires the next-event engine; "
                "use engine='event' (the charge/discharge timeline is "
                "integrated analytically per span)")
        cfg = self.cfg
        wall_t0 = time.perf_counter()
        horizon = cfg.days * 24 * HOUR
        # allow the tail of late jobs to finish
        t, t_end = 0.0, horizon * 2.0
        next_orch = 0.0
        n_jobs = len(self.jobs)
        by_state = self._by_state
        site_jobs = self._site_jobs
        while t < t_end:
            dt = cfg.dt_s
            self.ticks += 1
            # 1) arrivals (pending jobs, in arrival order)
            while (self._arrival_ptr < len(self._arrivals)
                   and self._arrivals[self._arrival_ptr].arrival_s <= t):
                j = self._arrivals[self._arrival_ptr]
                self._arrival_ptr += 1
                if j.state == "pending":
                    self._move(j, state="queued")
            # per-tick signal samples (rectangle rule; the stacks cache
            # the per-segment column, so this is one bisect per tick)
            carb = self.signals.carbon.value_grid(t)
            price = self.signals.price.value_grid(t)
            # 2) transfers progress
            if by_state["migrating"]:
                transfers = list(by_state["migrating"].values())
                eff = self._effective_bw(transfers, t)
                for j in transfers:
                    rate = eff[j.jid]
                    j.transfer_remaining_bits -= rate * dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    e = cfg.p_sys_kw * dt / HOUR
                    self.ledger.post_migration_tick(j.site, e, carb, price)
                    if j.transfer_remaining_bits <= 0:
                        dest = j.transfer_dest
                        j.transfer_dest = -1
                        j.load_remaining_s = cfg.t_load_s + cfg.t_downtime_s
                        self._move(j, state="loading", site=dest)
            # 3) checkpoint loads
            if by_state["loading"]:
                for j in list(by_state["loading"].values()):
                    j.load_remaining_s -= dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    if j.load_remaining_s <= 0:
                        j.post_migration_wait = True
                        j.last_migration_end_s = t
                        self._colf[j.jid, _CF_LASTMIG] = t
                        self._move(j, state="queued")
            # 4) scheduling: fill free slots FIFO (Defer holds jobs back)
            for s in range(cfg.n_sites):
                q = site_jobs.get((s, "queued"))
                if not q:
                    continue
                free = cfg.slots_per_site - self._running_count(s)
                if free <= 0:
                    continue
                ready = [j for j in q.values() if j.defer_until_s <= t]
                ready.sort(key=lambda x: (x.arrival_s, x.jid))
                for j in ready[:free]:
                    j.post_migration_wait = False
                    if j.started_s < 0:
                        j.started_s = t
                    self._move(j, state="running")
            # 5) compute progress + energy + failures
            for s in range(cfg.n_sites):
                running = site_jobs.get((s, "running"))
                if not running:
                    continue
                green = self.traces[s].active(t)
                for j in list(running.values()):
                    frac = j.power_frac
                    j.progress_s += dt * j.tput_frac
                    e = cfg.p_node_kw * frac * dt / HOUR
                    if green:
                        j.renewable_kwh += e
                    else:
                        j.grid_kwh += e
                    self.ledger.post_train_tick(s, e, green, carb, price)
                    self.ledger.post_dr(s, cfg.p_node_kw * frac,
                                        cfg.p_node_kw, t, t + dt)
                    if j.progress_s - j.last_ckpt_progress_s >= cfg.checkpoint_interval_s:
                        j.last_ckpt_progress_s = j.progress_s
                    if cfg.failure_rate_per_slot_hour > 0.0:
                        if self._fail_rng.random() < cfg.failure_rate_per_slot_hour * dt / HOUR:
                            # node failure: roll back to last checkpoint
                            lost = j.progress_s - j.last_ckpt_progress_s
                            j.progress_s = j.last_ckpt_progress_s
                            j.pause_s += lost
                            self.failures += 1
                    if j.progress_s >= j.compute_s:
                        j.done_s = t
                        self._move(j, state="done")
            # queue / pause time accounting
            for j in by_state["queued"].values():
                j.queue_s += dt
                if j.post_migration_wait:
                    j.pause_s += dt  # stalled by its own migration
                    j.pause_wait_s += dt
            for j in by_state["paused"].values():
                j.paused_policy_s += dt
            # 6) orchestrator tick: snapshot -> typed actions -> apply
            if t >= next_orch:
                next_orch = t + cfg.orch_dt_s
                if self._has_live_jobs():
                    state = self.snapshot(t)
                    d0 = time.perf_counter()
                    actions = self.policy.decide(state)
                    self._record_decide(time.perf_counter() - d0)
                    for action in actions:
                        self._apply_action(action, t, state, horizon)
            if len(by_state["done"]) == n_jobs:
                break
            t += dt
        return self._result(wall_t0)

    # -- scenario entry point ------------------------------------------------
    @classmethod
    def from_scenario(
        cls,
        scenario,
        policy: Union[str, Policy],
        *,
        overrides: Optional[dict] = None,
        jobs: Optional[List[SimJob]] = None,
        traces: Optional[List[SiteTrace]] = None,
    ) -> "ClusterSimulator":
        """Build a simulator from a registered scenario name (or Scenario)
        and a registered policy name (or Policy instance).  When the
        policy is resolved by name, the scenario's ``policy_configs``
        entry for it (if any) supplies constructor kwargs — an explicit
        Policy instance is used as-is."""
        from repro.core.scenarios import get_scenario

        scn = get_scenario(scenario)
        cfg = scn.sim_config(**(overrides or {}))
        if isinstance(policy, str):
            pconf = scn.policy_configs.get(
                policy.lower().replace("_", "-"), {})
            pol = make_policy(policy, **dict(pconf))
        else:
            pol = policy
        return cls(cfg, pol, jobs=jobs, traces=traces,
                   oracle_forecast=getattr(pol, "wants_oracle_forecast", False))


def run_policy_comparison(
    cfg: Optional[SimConfig] = None,
    policies: Sequence[str] = ("static", "energy-only", "feasibility-aware", "oracle"),
    *,
    scenario=None,
    overrides: Optional[dict] = None,
    policy_configs: Optional[Dict[str, Union[PolicyConfig, dict]]] = None,
) -> Dict[str, SimResult]:
    """Table VI / VIII: same trace + same jobs, one run per policy.

    ``scenario`` names a registered scenario (or passes a ``Scenario``);
    ``overrides`` tweaks individual ``SimConfig`` fields on top of it;
    ``policy_configs`` maps policy name -> ``PolicyConfig`` (or kwargs dict),
    so per-policy knobs like stochastic feasibility ``eps`` /
    ``forecast_sigma_s`` reach the comparison path.

    Implemented as a one-cell sweep through :mod:`repro.core.sweep`
    (run inline, no process pool): the cell runner is what provides the
    same-trace-same-jobs guarantee, for this comparison and for every
    seed of a Monte-Carlo sweep alike.
    """
    from repro.core.sweep import run_cells

    label = "config"
    if scenario is not None:
        if cfg is not None:
            raise ValueError(
                "pass either cfg or scenario (+overrides), not both")
        from repro.core.scenarios import get_scenario

        scn = get_scenario(scenario)
        label = scn.name
        cfg = scn.sim_config(**(overrides or {}))
        if scn.policy_configs:
            # scenario-scoped defaults; explicit policy_configs win
            merged = {k: dict(v) for k, v in scn.policy_configs.items()}
            merged.update(dict(policy_configs or {}))
            policy_configs = merged
    elif overrides:
        cfg = dataclasses.replace(cfg or SimConfig(), **overrides)
    cfg = cfg or SimConfig()
    res = run_cells(
        [(cfg, label, cfg.seed, tuple(policies), dict(policy_configs or {}),
          True, cfg.seed)],
        workers=1)
    return {r.policy: r.result for r in res.runs}


def normalized_table(results: Dict[str, SimResult]) -> List[dict]:
    """Paper Table VI/VIII format: normalized to the static baseline, plus
    the action-validity and engine-throughput columns benchmarks surface."""
    base = results["static"]
    any_serving = any(r.requests_arrived > 0 for r in results.values())
    any_dr = any(r.dr_requested_ws > 0.0 for r in results.values())
    any_batt = any(r.battery_charge_kwh > 0.0 or r.sellback_kwh > 0.0
                   for r in results.values())
    any_faults = any(r.site_outages > 0 or r.watchdog_aborts > 0
                     or r.replica_crashes > 0 for r in results.values())
    rows = []
    for name, r in results.items():
        row = {
            "policy": name,
            "nonrenew_energy": round(r.grid_kwh / base.grid_kwh, 2) if base.grid_kwh else 0.0,
            "grid_gco2": round(r.grid_gco2 / base.grid_gco2, 2) if base.grid_gco2 else 0.0,
            "grid_cost": round(r.grid_cost / base.grid_cost, 2) if base.grid_cost else 0.0,
            "jct": round(r.mean_jct_s / base.mean_jct_s, 2),
            "migration_overhead": round(r.migration_overhead, 3),
            "stall_overhead": round(r.stall_overhead, 3),
            "renewable_frac": round(r.renewable_fraction, 3),
            "rejected_actions": r.rejected_actions,
            "ticks_per_sec": round(r.ticks_per_sec, 1),
            "decide_s": round(r.decide_s, 4),
        }
        if any_dr:
            # fraction of CurtailRequest span-watts actually shed
            row["dr_compliance"] = round(r.dr_compliance, 4)
        if any_batt:
            row["battery_cycles"] = round(r.battery_cycles, 3)
            row["sellback_usd"] = round(r.sellback_usd, 4)
        if any_faults:
            row["completed"] = r.completed
            row["site_outages"] = r.site_outages
            row["mttr_s"] = round(r.mttr_s, 1)
            row["retries"] = r.retries
            row["reroutes"] = r.reroutes
            row["watchdog_aborts"] = r.watchdog_aborts
            row["failed_migrations"] = r.failed_migrations
        if any_serving:
            row["requests_served"] = r.requests_served
            row["slo_attainment"] = round(r.slo_attainment, 4)
            row["request_gco2"] = round(r.request_gco2, 1)
            row["latency_p95_s"] = round(r.latency_p95_s, 3)
        rows.append(row)
    return rows
