"""Trace-driven discrete-time simulator of renewable-powered
micro-datacenters (paper §VII: 5 sites, 10 Gbps WAN, 7-day CAISO-calibrated
trace, job mix A:70% 1–6 GB / B:20% 10–40 GB / C:10% 100–300 GB).

Models:
  * per-site GPU slots with FIFO queues,
  * renewable windows from core/traces.py; grid vs. renewable kWh accounting
    (P_node = 0.75 kW compute, P_sys = 1.8 kW during transfer),
  * WAN transfers with per-site NIC contention (concurrent transfers share
    the 10 Gbps uplink — this is what stalls the energy-only policy),
  * migration = pause → transfer → load (10.3 s) → downtime (0.4 s) →
    resume (possibly queued on arrival),
  * optional node failures with checkpoint/restart (beyond-paper: the
    fault-tolerance path of the framework, §VIII.F of the paper lists this
    as unmodeled future work).

Deterministic for a given seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import feasibility as fz
from repro.core.orchestrator import (
    JobView, OrchestratorContext, Policy, SiteView, StaticPolicy,
)
from repro.core.traces import Forecaster, SiteTrace, generate_trace

HOUR = 3600.0
GB = 1e9


@dataclass
class SimJob:
    jid: int
    arrival_s: float
    compute_s: float
    ckpt_bytes: float
    size_class: str
    home_site: int

    site: int = -1
    state: str = "pending"  # pending|queued|running|migrating|loading|done
    progress_s: float = 0.0
    done_s: float = -1.0
    started_s: float = -1.0
    migrations: int = 0
    failed_migrations: int = 0
    pause_s: float = 0.0  # time spent not computing due to migration
    pause_transfer_s: float = 0.0
    pause_wait_s: float = 0.0  # post-migration queue wait
    queue_s: float = 0.0
    renewable_kwh: float = 0.0
    grid_kwh: float = 0.0
    # in-flight transfer
    transfer_remaining_bits: float = 0.0
    transfer_dest: int = -1
    load_remaining_s: float = 0.0
    last_ckpt_progress_s: float = 0.0
    post_migration_wait: bool = False  # queue time after arrival counts as
    # migration-induced pause (the paper's 'stall/congestion' mode)
    last_migration_end_s: float = -1e18

    @property
    def jct_s(self) -> float:
        return self.done_s - self.arrival_s if self.done_s >= 0 else float("nan")


@dataclass
class SimConfig:
    n_sites: int = 5
    slots_per_site: int = 4
    wan_gbps: float = 10.0
    days: int = 7
    dt_s: float = 30.0
    orch_dt_s: float = 300.0
    seed: int = 0
    n_jobs: int = 240
    arrival_skew: Sequence[float] = (0.45, 0.1925, 0.1485, 0.121, 0.088)
    p_node_kw: float = fz.P_NODE_KW
    p_sys_kw: float = fz.P_SYS_KW
    t_load_s: float = fz.T_LOAD_S
    t_downtime_s: float = fz.T_DOWNTIME_S
    forecast_sigma_s: float = 900.0
    migration_cooldown_s: float = 900.0  # orchestrator debounce per job
    # job mix (paper §VII)
    frac_a: float = 0.70
    frac_b: float = 0.20
    size_a_gb: tuple = (1.0, 6.0)
    size_b_gb: tuple = (10.0, 40.0)
    size_c_gb: tuple = (100.0, 300.0)
    mean_compute_h: float = 3.5
    # beyond-paper fault injection
    failure_rate_per_slot_hour: float = 0.0
    checkpoint_interval_s: float = 1800.0


@dataclass
class SimResult:
    policy: str
    jobs: List[SimJob]
    grid_kwh: float
    renewable_kwh: float
    migration_kwh: float
    migrations: int
    failed_migrations: int
    failures: int

    @property
    def mean_jct_s(self) -> float:
        vals = [j.jct_s for j in self.jobs if j.done_s >= 0]
        return float(np.mean(vals)) if vals else float("nan")

    @property
    def completed(self) -> int:
        return sum(1 for j in self.jobs if j.done_s >= 0)

    @property
    def total_compute_s(self) -> float:
        return sum(j.progress_s for j in self.jobs)

    @property
    def migration_overhead(self) -> float:
        """Direct migration cost (transfer + load + downtime) over compute —
        the paper's 'Migr. overhead' column."""
        c = self.total_compute_s
        return (sum(j.pause_transfer_s for j in self.jobs) / c) if c else 0.0

    @property
    def stall_overhead(self) -> float:
        """Migration-induced queueing stalls over compute (the energy-only
        failure mode: §VII.E 'stalled transfers, congestion, retries')."""
        c = self.total_compute_s
        return (sum(j.pause_wait_s for j in self.jobs) / c) if c else 0.0

    @property
    def renewable_fraction(self) -> float:
        tot = self.grid_kwh + self.renewable_kwh
        return self.renewable_kwh / tot if tot else 0.0

    def summary(self) -> dict:
        return {
            "policy": self.policy,
            "grid_kwh": round(self.grid_kwh, 1),
            "renewable_kwh": round(self.renewable_kwh, 1),
            "renewable_frac": round(self.renewable_fraction, 3),
            "mean_jct_h": round(self.mean_jct_s / HOUR, 2),
            "migration_overhead": round(self.migration_overhead, 4),
            "stall_overhead": round(self.stall_overhead, 4),
            "migrations": self.migrations,
            "failed_migrations": self.failed_migrations,
            "completed": self.completed,
            "failures": self.failures,
        }


def generate_jobs(cfg: SimConfig) -> List[SimJob]:
    rng = np.random.default_rng(cfg.seed + 1)
    horizon = cfg.days * 24 * HOUR
    arrivals = np.sort(rng.uniform(0, horizon * 0.75, cfg.n_jobs))
    skew = np.asarray(cfg.arrival_skew[: cfg.n_sites], float)
    skew = skew / skew.sum()
    jobs = []
    sigma = 0.6
    mu = np.log(cfg.mean_compute_h) - sigma ** 2 / 2
    for i, t in enumerate(arrivals):
        u = rng.random()
        if u < cfg.frac_a:
            cls, (lo, hi) = "A", cfg.size_a_gb
        elif u < cfg.frac_a + cfg.frac_b:
            cls, (lo, hi) = "B", cfg.size_b_gb
        else:
            cls, (lo, hi) = "C", cfg.size_c_gb
        size = rng.uniform(lo, hi) * GB
        compute_h = float(np.clip(rng.lognormal(mu, sigma), 0.5, 24.0))
        home = int(rng.choice(cfg.n_sites, p=skew))
        jobs.append(SimJob(i, float(t), compute_h * HOUR, size, cls, home, site=home))
    return jobs


class ClusterSimulator:
    def __init__(
        self,
        cfg: SimConfig,
        policy: Policy,
        traces: Optional[List[SiteTrace]] = None,
        jobs: Optional[List[SimJob]] = None,
        oracle_forecast: bool = False,
    ):
        self.cfg = cfg
        self.policy = policy
        self.traces = traces or generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed)
        self.jobs = jobs if jobs is not None else generate_jobs(cfg)
        sigma = 0.0 if oracle_forecast else cfg.forecast_sigma_s
        self.forecaster = Forecaster(self.traces, sigma_s=sigma, seed=cfg.seed + 7)
        self._fail_rng = np.random.default_rng(cfg.seed + 23)
        self.grid_kwh = 0.0
        self.renewable_kwh = 0.0
        self.migration_kwh = 0.0
        self.migrations = 0
        self.failed_migrations = 0
        self.failures = 0

    # -- helpers ------------------------------------------------------------
    def _running(self, sid: int) -> List[SimJob]:
        return [j for j in self.jobs if j.site == sid and j.state == "running"]

    def _queued(self, sid: int) -> List[SimJob]:
        return [j for j in self.jobs if j.site == sid and j.state == "queued"]

    def _transfers(self) -> List[SimJob]:
        return [j for j in self.jobs if j.state == "migrating"]

    def _effective_bw(self, transfers: List[SimJob]) -> Dict[int, float]:
        """Per-transfer effective bps under per-site NIC sharing."""
        nic = self.cfg.wan_gbps * 1e9
        src_count: Dict[int, int] = {}
        dst_count: Dict[int, int] = {}
        for j in transfers:
            src_count[j.site] = src_count.get(j.site, 0) + 1
            dst_count[j.transfer_dest] = dst_count.get(j.transfer_dest, 0) + 1
        return {
            j.jid: min(nic / src_count[j.site], nic / dst_count[j.transfer_dest])
            for j in transfers
        }

    def _ctx(self, t: float) -> OrchestratorContext:
        incoming: Dict[int, int] = {s: 0 for s in range(self.cfg.n_sites)}
        for j in self.jobs:
            if j.state == "migrating":
                incoming[j.transfer_dest] += 1
            elif j.state == "loading":
                incoming[j.site] += 1
        sites = []
        for s in range(self.cfg.n_sites):
            sites.append(
                SiteView(
                    sid=s,
                    slots=self.cfg.slots_per_site,
                    busy=len(self._running(s)),
                    queued=len(self._queued(s)),
                    renewable_active=self.traces[s].active(t),
                    window_remaining_s=self.forecaster.remaining(s, t),
                    incoming=incoming[s],
                )
            )
        # measured bandwidth: current NIC contention applied symmetrically
        n = self.cfg.n_sites
        bw = np.full((n, n), self.cfg.wan_gbps * 1e9)
        active = self._transfers()
        for j in active:
            bw[j.site, :] /= 2.0
            bw[:, j.transfer_dest] /= 2.0
        jobs = [
            JobView(j.jid, j.site, j.ckpt_bytes, j.compute_s - j.progress_s, self.cfg.t_load_s)
            for j in self.jobs
            if j.state == "running"
            and t - j.last_migration_end_s >= self.cfg.migration_cooldown_s
        ]
        return OrchestratorContext(t=t, jobs=jobs, sites=sites, bandwidth_bps=bw)

    # -- main loop ----------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.cfg
        horizon = cfg.days * 24 * HOUR
        # allow the tail of late jobs to finish
        t, t_end = 0.0, horizon * 2.0
        next_orch = 0.0
        jobs_by_id = {j.jid: j for j in self.jobs}
        while t < t_end:
            dt = cfg.dt_s
            # 1) arrivals
            for j in self.jobs:
                if j.state == "pending" and j.arrival_s <= t:
                    j.state = "queued"
            # 2) transfers progress
            transfers = self._transfers()
            if transfers:
                eff = self._effective_bw(transfers)
                for j in transfers:
                    rate = eff[j.jid]
                    j.transfer_remaining_bits -= rate * dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    e = self.cfg.p_sys_kw * dt / HOUR
                    self.migration_kwh += e
                    self.grid_kwh += e  # transfer power billed to grid
                    if j.transfer_remaining_bits <= 0:
                        j.site = j.transfer_dest
                        j.transfer_dest = -1
                        j.state = "loading"
                        j.load_remaining_s = cfg.t_load_s + cfg.t_downtime_s
            # 3) checkpoint loads
            for j in self.jobs:
                if j.state == "loading":
                    j.load_remaining_s -= dt
                    j.pause_s += dt
                    j.pause_transfer_s += dt
                    if j.load_remaining_s <= 0:
                        j.state = "queued"
                        j.post_migration_wait = True
                        j.last_migration_end_s = t
            # 4) scheduling: fill free slots FIFO
            for s in range(cfg.n_sites):
                free = cfg.slots_per_site - len(self._running(s))
                if free > 0:
                    for j in sorted(self._queued(s), key=lambda x: x.arrival_s)[:free]:
                        j.state = "running"
                        j.post_migration_wait = False
                        if j.started_s < 0:
                            j.started_s = t
            # 5) compute progress + energy + failures
            for s in range(cfg.n_sites):
                green = self.traces[s].active(t)
                for j in self._running(s):
                    j.progress_s += dt
                    e = cfg.p_node_kw * dt / HOUR
                    if green:
                        j.renewable_kwh += e
                        self.renewable_kwh += e
                    else:
                        j.grid_kwh += e
                        self.grid_kwh += e
                    if j.progress_s - j.last_ckpt_progress_s >= cfg.checkpoint_interval_s:
                        j.last_ckpt_progress_s = j.progress_s
                    if cfg.failure_rate_per_slot_hour > 0.0:
                        if self._fail_rng.random() < cfg.failure_rate_per_slot_hour * dt / HOUR:
                            # node failure: roll back to last checkpoint
                            lost = j.progress_s - j.last_ckpt_progress_s
                            j.progress_s = j.last_ckpt_progress_s
                            j.pause_s += lost
                            self.failures += 1
                    if j.progress_s >= j.compute_s:
                        j.state = "done"
                        j.done_s = t
            # queue-time accounting
            for j in self.jobs:
                if j.state == "queued":
                    j.queue_s += dt
                    if j.post_migration_wait:
                        j.pause_s += dt  # stalled by its own migration
                        j.pause_wait_s += dt
            # 6) orchestrator tick
            if t >= next_orch:
                next_orch = t + cfg.orch_dt_s
                ctx = self._ctx(t)
                for jid, dest in self.policy.decide(ctx):
                    j = jobs_by_id[jid]
                    if j.state != "running" or dest == j.site:
                        continue
                    j.state = "migrating"
                    j.transfer_dest = dest
                    j.transfer_remaining_bits = 8.0 * j.ckpt_bytes
                    j.migrations += 1
                    self.migrations += 1
                    # a migration whose destination window closes before the
                    # transfer ends is counted as failed (it still completes,
                    # but arrives onto grid power — the paper's stall mode)
                    bw_now = float(ctx.bandwidth_bps[j.site, dest])
                    t_arrive = t + 8.0 * j.ckpt_bytes / bw_now
                    if not self.traces[dest].active(min(t_arrive, horizon - 1)):
                        self.failed_migrations += 1
            if all(j.state == "done" for j in self.jobs):
                break
            t += dt
        return SimResult(
            policy=self.policy.name,
            jobs=self.jobs,
            grid_kwh=self.grid_kwh,
            renewable_kwh=self.renewable_kwh,
            migration_kwh=self.migration_kwh,
            migrations=self.migrations,
            failed_migrations=self.failed_migrations,
            failures=self.failures,
        )


def run_policy_comparison(
    cfg: Optional[SimConfig] = None,
    policies: Sequence[str] = ("static", "energy-only", "feasibility-aware", "oracle"),
) -> Dict[str, SimResult]:
    """Table VI / VIII: same trace + same jobs, one run per policy."""
    from repro.core.orchestrator import make_policy
    import copy

    cfg = cfg or SimConfig()
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed)
    base_jobs = generate_jobs(cfg)
    out: Dict[str, SimResult] = {}
    for name in policies:
        jobs = copy.deepcopy(base_jobs)
        pol = make_policy(name)
        sim = ClusterSimulator(
            cfg, pol, traces=traces, jobs=jobs, oracle_forecast=(name == "oracle")
        )
        out[name] = sim.run()
    return out


def normalized_table(results: Dict[str, SimResult]) -> List[dict]:
    """Paper Table VI/VIII format: normalized to the static baseline."""
    base = results["static"]
    rows = []
    for name, r in results.items():
        rows.append(
            {
                "policy": name,
                "nonrenew_energy": round(r.grid_kwh / base.grid_kwh, 2) if base.grid_kwh else 0.0,
                "jct": round(r.mean_jct_s / base.mean_jct_s, 2),
                "migration_overhead": round(r.migration_overhead, 3),
                "stall_overhead": round(r.stall_overhead, 3),
                "renewable_frac": round(r.renewable_fraction, 3),
            }
        )
    return rows
