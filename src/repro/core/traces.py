"""Renewable-surplus window traces calibrated on CAISO curtailment
statistics (paper §VII: 7-day trace, mean window ≈ 2.5 h; footnote 1:
events last 2.5–9.5 h; solar curtailment peaks midday).

Windows are generated per site with a diurnal solar profile: one surplus
window per day with probability `p_window`, centered near local noon
(per-site phase offsets model geographic spread), duration ~ clipped
lognormal with mean 2.5 h. Deterministic given a seed.

Forecasts: the orchestrator sees the true window start/end with Gaussian
noise on the remaining duration (σ configurable); the Oracle policy gets
σ = 0 (paper Table VIII 'Perfect Forecast').
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from functools import cached_property
from typing import List, Optional, Sequence, Tuple

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


@dataclass(frozen=True, slots=True)
class Window:
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclass
class TraceProfile:
    """Shape of the renewable-surplus process a trace is drawn from.
    Scenario dataclasses compose one of these; ``generate_trace`` consumes
    it. Defaults reproduce the paper's CAISO calibration (§VII, fn. 1)."""

    mean_window_h: float = 4.25
    max_window_h: float = 9.5
    min_window_h: float = 1.5
    p_window: float = 1.0
    noon_h: float = 12.5
    phase_spread_h: float = 9.0
    p_wind: float = 0.5
    wind_mean_h: float = 2.5


@dataclass(slots=True)
class SiteTrace:
    site: int
    windows: List[Window]
    # bisect cache over the (sorted, non-overlapping) window bounds; rebuilt
    # whenever the window count changes
    _starts: List[float] = field(default=None, repr=False, compare=False)
    _ends: List[float] = field(default=None, repr=False, compare=False)
    _n_cached: int = field(default=-1, repr=False, compare=False)

    def _refresh(self) -> None:
        if self._n_cached != len(self.windows):
            self.windows.sort(key=lambda w: w.start_s)
            self._starts = [w.start_s for w in self.windows]
            self._ends = [w.end_s for w in self.windows]
            self._n_cached = len(self.windows)

    def _index(self, t: float) -> int:
        """Index of the window containing t, or -1."""
        self._refresh()
        i = bisect.bisect_right(self._starts, t) - 1
        if i >= 0 and t < self._ends[i]:
            return i
        return -1

    def active(self, t: float) -> bool:
        return self._index(t) >= 0

    def remaining(self, t: float) -> float:
        """Remaining surplus seconds at time t (0 if not in a window)."""
        i = self._index(t)
        return self._ends[i] - t if i >= 0 else 0.0

    def next_window(self, t: float) -> Optional[Window]:
        self._index(t)  # refresh cache / sort
        i = bisect.bisect_right(self._starts, t)
        return self.windows[i] if i < len(self.windows) else None

    def overlaps(self, t0: float, t1: float) -> List[Tuple[float, float]]:
        """Clipped ``(start, end)`` overlaps of surplus windows with
        ``[t0, t1]`` (disjoint, sorted) — what the signal accounting
        subtracts from a span's carbon/price integral
        (:func:`repro.core.signals.grid_signal_integral`)."""
        if t1 <= t0:
            return []
        self._refresh()
        starts, ends = self._starts, self._ends
        lo = bisect.bisect_right(ends, t0)
        hi = bisect.bisect_left(starts, t1)
        out = []
        for k in range(lo, hi):
            a, b = max(t0, starts[k]), min(t1, ends[k])
            if b > a:
                out.append((a, b))
        return out

    def renewable_seconds(self, t0: float, t1: float) -> float:
        """Surplus seconds overlapping [t0, t1] — bisect over the sorted
        window-bounds cache, touching only windows that can overlap (the
        event engine integrates energy with this on every span)."""
        if t1 <= t0:
            return 0.0
        self._refresh()
        starts, ends = self._starts, self._ends
        lo = bisect.bisect_right(ends, t0)  # first window ending after t0
        hi = bisect.bisect_left(starts, t1)  # windows starting before t1
        tot = 0.0
        for k in range(lo, hi):
            tot += max(0.0, min(t1, ends[k]) - max(t0, starts[k]))
        return tot


@dataclass(frozen=True, eq=False)
class TraceStack:
    """Padded structure-of-arrays view over a fleet of :class:`SiteTrace`
    windows, for whole-fleet batched queries (the decide-path hot loop asks
    "remaining / next start / renewable seconds" for *every* site or job
    every tick; per-call bisect over Python lists was ~60k scalar calls per
    7-day run).

    ``starts``/``ends`` are ``(n_sites, K)`` float64 padded with ``+inf``
    (K = max window count + 1 so a searchsorted index can always be used to
    gather); ``cum[i, k]`` is the total duration of site ``i``'s windows
    ``0..k-1``.  Built once per run from static traces — a stack does NOT
    track later mutations of the underlying ``SiteTrace.windows``.
    """

    starts: np.ndarray  # (n, K) window starts, +inf padded
    ends: np.ndarray  # (n, K) window ends, +inf padded
    cum: np.ndarray  # (n, K + 1) cumulative window durations
    n_windows: np.ndarray  # (n,)

    @property
    def n_sites(self) -> int:
        return len(self.starts)

    # -- point-in-time fleet queries (scalar t -> (n_sites,) arrays) --------
    @cached_property
    def _rows(self) -> np.ndarray:
        return np.arange(len(self.starts))

    @cached_property
    def _edge_list(self) -> List[float]:
        """Sorted window edges: between two consecutive edges the per-site
        window index is constant, so its gathers are cached per epoch."""
        vals = np.unique(np.concatenate([self.starts.ravel(),
                                         self.ends.ravel()]))
        return [float(v) for v in vals if np.isfinite(v)]

    @cached_property
    def _epoch_cache(self) -> dict:
        return {}

    def _epoch(self, t: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(j, end[j-1], start[j]) per site for the epoch containing t."""
        key = bisect.bisect_right(self._edge_list, t)
        got = self._epoch_cache.get(key)
        if got is None:
            j = (self.starts <= t).sum(axis=1)  # == bisect_right per site
            r = self._rows
            got = self._epoch_cache[key] = (
                j, self.ends[r, np.maximum(j - 1, 0)], self.starts[r, j])
        return got

    def point(self, t: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One pass for the three per-site point queries the snapshot
        needs: ``(active, remaining, next_window_start)`` — matching
        ``SiteTrace.active`` / ``.remaining`` /
        ``.next_window().start_s`` (+inf when none) per site."""
        j, end, nxt = self._epoch(t)
        act = (j > 0) & (t < end)
        rem = np.where(act, end - t, 0.0)
        return act, rem, nxt

    def active(self, t: float) -> np.ndarray:
        """(n,) bool: site inside a surplus window at ``t``."""
        return self.point(t)[0]

    def remaining(self, t: float) -> np.ndarray:
        """(n,) surplus seconds left at ``t`` (0 outside windows)."""
        return self.point(t)[1]

    def next_window_start(self, t: float) -> np.ndarray:
        """(n,) start of the first window strictly after ``t`` (+inf when
        none)."""
        return self.point(t)[2]

    # -- batched span overlap ------------------------------------------------
    def _cover(self, sites: np.ndarray, x: np.ndarray) -> np.ndarray:
        """Renewable seconds before time ``x`` at each site (cumulative
        window coverage; the searchsorted analogue of summing overlaps)."""
        j = (self.starts[sites] <= x[:, None]).sum(axis=1)
        jm = np.maximum(j - 1, 0)
        with np.errstate(invalid="ignore"):  # inf-inf on empty-trace pads
            open_tail = np.maximum(0.0, self.ends[sites, jm] - x)
            # window j-1 is the only one that can still be open at x
            dur = self.ends[sites, jm] - self.starts[sites, jm]
        return self.cum[sites, j] - np.where(j > 0,
                                             np.minimum(open_tail, dur), 0.0)

    def renewable_seconds(
        self, sites: np.ndarray, t0: np.ndarray, t1
    ) -> np.ndarray:
        """Batched ``SiteTrace.renewable_seconds``: surplus seconds
        overlapping ``[t0[k], t1]`` at ``sites[k]`` (``t1`` scalar or
        array).  Agrees with the scalar loop to float round-off (cumulative
        differences instead of per-window overlap sums)."""
        sites = np.asarray(sites)
        t0 = np.asarray(t0, dtype=np.float64)
        t1 = np.broadcast_to(np.asarray(t1, dtype=np.float64), t0.shape)
        return np.maximum(0.0, self._cover(sites, t1) - self._cover(sites, t0))


def stack_traces(traces: Sequence[SiteTrace]) -> TraceStack:
    """Build the padded :class:`TraceStack` for a fleet (sorts each site's
    windows exactly like ``SiteTrace._refresh``)."""
    sorted_wins = []
    for tr in traces:
        tr._refresh()
        sorted_wins.append(list(zip(tr._starts, tr._ends)))
    k = max((len(w) for w in sorted_wins), default=0) + 1
    n = len(traces)
    starts = np.full((n, k), np.inf)
    ends = np.full((n, k), np.inf)
    cum = np.zeros((n, k + 1))
    n_windows = np.zeros(n, dtype=np.int64)
    for i, wins in enumerate(sorted_wins):
        n_windows[i] = len(wins)
        for j, (a, b) in enumerate(wins):
            starts[i, j] = a
            ends[i, j] = b
        if wins:
            cum[i, 1:len(wins) + 1] = np.cumsum(
                [b - a for a, b in wins])
            cum[i, len(wins) + 1:] = cum[i, len(wins)]
    return TraceStack(starts, ends, cum, n_windows)


def generate_trace(
    n_sites: int = 5,
    days: int = 7,
    *,
    seed: int = 0,
    profile: Optional[TraceProfile] = None,
    **overrides,
) -> List[SiteTrace]:
    """CAISO-calibrated per-site renewable windows over `days`:
    one solar-curtailment window per day (midday, site-phase-shifted) plus
    an optional night wind-curtailment window.  The window process is
    parameterized by a :class:`TraceProfile` (scenario-composable); keyword
    overrides adjust individual fields."""
    import dataclasses as _dc

    prof = profile or TraceProfile()
    if overrides:
        prof = _dc.replace(prof, **overrides)
    mean_window_h, max_window_h, min_window_h = (
        prof.mean_window_h, prof.max_window_h, prof.min_window_h)
    p_window, noon_h, phase_spread_h = prof.p_window, prof.noon_h, prof.phase_spread_h
    p_wind, wind_mean_h = prof.p_wind, prof.wind_mean_h
    rng = np.random.default_rng(seed)
    # lognormal with mean mean_window_h: mu = ln(mean) - sigma^2/2
    sigma = 0.55
    mu = np.log(mean_window_h) - sigma ** 2 / 2
    mu_w = np.log(wind_mean_h) - sigma ** 2 / 2
    traces = []
    for s in range(n_sites):
        phase = (s / max(n_sites - 1, 1) - 0.5) * 2 * phase_spread_h  # hours
        wins: List[Window] = []
        for d in range(days):
            if rng.random() <= p_window:
                dur = float(np.clip(rng.lognormal(mu, sigma), min_window_h, max_window_h))
                center = d * 24 + noon_h + phase + rng.normal(0, 0.75)
                start = max(d * 24.0, center - dur / 2)
                end = min((d + 1) * 24.0, start + dur)
                if end - start >= min_window_h:
                    wins.append(Window(start * HOUR, end * HOUR))
            if rng.random() <= p_wind:
                dur = float(np.clip(rng.lognormal(mu_w, sigma), 1.0, 6.0))
                center = d * 24 + (2.5 + (phase if abs(phase) < 6 else 0) + rng.normal(0, 1.0)) % 24
                start = max(d * 24.0, center - dur / 2)
                end = min((d + 1) * 24.0, start + dur)
                if end - start >= 1.0 and not any(
                    max(w.start_s, start * HOUR) < min(w.end_s, end * HOUR) for w in wins
                ):
                    wins.append(Window(start * HOUR, end * HOUR))
        wins.sort(key=lambda w: w.start_s)
        traces.append(SiteTrace(s, wins))
    return traces


@dataclass
class Forecaster:
    """Noisy view of the remaining-window duration (§VI.H)."""

    traces: Sequence[SiteTrace]
    sigma_s: float = 900.0  # 15 min 1-sigma forecast error
    seed: int = 17

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        # separate stream for next-window noise so adding/removing those
        # queries never perturbs the remaining-window noise sequence
        self._rng_next = np.random.default_rng(self.seed + 1)
        self._stack: Optional[TraceStack] = None

    def _trace_stack(self) -> TraceStack:
        """Padded window arrays for the batched queries (built lazily —
        traces must be static by first batched use)."""
        if self._stack is None:
            self._stack = stack_traces(self.traces)
        return self._stack

    def remaining(self, site: int, t: float) -> float:
        true = self.traces[site].remaining(t)
        if self.sigma_s <= 0:
            return true
        if true <= 0:
            return 0.0
        return max(0.0, true + float(self._rng.normal(0, self.sigma_s)))

    def next_window_start(self, site: int, t: float) -> float:
        """Forecast start of the next surplus window (inf if none); subject
        to the same sigma noise as remaining-window forecasts."""
        nw = self.traces[site].next_window(t)
        if nw is None:
            return float("inf")
        if self.sigma_s <= 0:
            return nw.start_s
        return max(t, nw.start_s + float(self._rng_next.normal(0, self.sigma_s)))

    def active(self, site: int, t: float) -> bool:
        return self.traces[site].active(t)

    # -- batched fleet queries (bit-identical noise streams) ----------------
    def _noisy_remaining(self, true: np.ndarray) -> np.ndarray:
        if self.sigma_s <= 0:
            return true
        mask = true > 0
        k = int(mask.sum())
        if k == 0:
            return true  # all zero: no draws, exactly the scalar behaviour
        noise = self._rng.normal(0, self.sigma_s, k)
        if k == len(true):
            return np.maximum(0.0, true + noise)
        out = np.zeros(len(true))
        out[mask] = np.maximum(0.0, true[mask] + noise)
        return out

    def _noisy_next_start(self, t: float, starts: np.ndarray) -> np.ndarray:
        if self.sigma_s <= 0:
            return starts
        mask = np.isfinite(starts)
        k = int(mask.sum())
        if k == 0:
            return starts  # all inf: no draws
        noise = self._rng_next.normal(0, self.sigma_s, k)
        if k == len(starts):
            return np.maximum(t, starts + noise)
        out = np.full(len(starts), np.inf)
        out[mask] = np.maximum(t, starts[mask] + noise)
        return out

    def snapshot_all(self, t: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(active, noisy remaining, noisy next-window start) for every
        site in one pass.  Per-site noise draws happen in site order from
        the same streams as the scalar calls (a batched ``normal(size=k)``
        consumes the generator identically to ``k`` scalar draws), so
        interleaving batched and scalar queries yields the same
        sequence."""
        act, rem, nxt = self._trace_stack().point(t)
        return act, self._noisy_remaining(rem), self._noisy_next_start(t, nxt)


def trace_stats(traces: Sequence[SiteTrace]) -> dict:
    durs = [w.duration_s / HOUR for tr in traces for w in tr.windows]
    total = sum(durs)
    return {
        "n_windows": len(durs),
        "mean_h": float(np.mean(durs)) if durs else 0.0,
        "min_h": float(np.min(durs)) if durs else 0.0,
        "max_h": float(np.max(durs)) if durs else 0.0,
        "total_surplus_h": total,
    }
