"""Renewable-surplus window traces calibrated on CAISO curtailment
statistics (paper §VII: 7-day trace, mean window ≈ 2.5 h; footnote 1:
events last 2.5–9.5 h; solar curtailment peaks midday).

Windows are generated per site with a diurnal solar profile: one surplus
window per day with probability `p_window`, centered near local noon
(per-site phase offsets model geographic spread), duration ~ clipped
lognormal with mean 2.5 h. Deterministic given a seed.

Forecasts: the orchestrator sees the true window start/end with Gaussian
noise on the remaining duration (σ configurable); the Oracle policy gets
σ = 0 (paper Table VIII 'Perfect Forecast').
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np

HOUR = 3600.0
DAY = 24 * HOUR


@dataclass(frozen=True)
class Window:
    start_s: float
    end_s: float

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s


@dataclass
class SiteTrace:
    site: int
    windows: List[Window]

    def active(self, t: float) -> bool:
        return any(w.start_s <= t < w.end_s for w in self.windows)

    def remaining(self, t: float) -> float:
        """Remaining surplus seconds at time t (0 if not in a window)."""
        for w in self.windows:
            if w.start_s <= t < w.end_s:
                return w.end_s - t
        return 0.0

    def next_window(self, t: float):
        for w in self.windows:
            if w.start_s > t:
                return w
        return None

    def renewable_seconds(self, t0: float, t1: float) -> float:
        tot = 0.0
        for w in self.windows:
            tot += max(0.0, min(t1, w.end_s) - max(t0, w.start_s))
        return tot


def generate_trace(
    n_sites: int = 5,
    days: int = 7,
    *,
    seed: int = 0,
    mean_window_h: float = 4.25,
    max_window_h: float = 9.5,
    min_window_h: float = 1.5,
    p_window: float = 1.0,
    noon_h: float = 12.5,
    phase_spread_h: float = 9.0,
    p_wind: float = 0.5,
    wind_mean_h: float = 2.5,
) -> List[SiteTrace]:
    """CAISO-calibrated per-site renewable windows over `days`:
    one solar-curtailment window per day (midday, site-phase-shifted) plus
    an optional night wind-curtailment window."""
    rng = np.random.default_rng(seed)
    # lognormal with mean mean_window_h: mu = ln(mean) - sigma^2/2
    sigma = 0.55
    mu = np.log(mean_window_h) - sigma ** 2 / 2
    mu_w = np.log(wind_mean_h) - sigma ** 2 / 2
    traces = []
    for s in range(n_sites):
        phase = (s / max(n_sites - 1, 1) - 0.5) * 2 * phase_spread_h  # hours
        wins: List[Window] = []
        for d in range(days):
            if rng.random() <= p_window:
                dur = float(np.clip(rng.lognormal(mu, sigma), min_window_h, max_window_h))
                center = d * 24 + noon_h + phase + rng.normal(0, 0.75)
                start = max(d * 24.0, center - dur / 2)
                end = min((d + 1) * 24.0, start + dur)
                if end - start >= min_window_h:
                    wins.append(Window(start * HOUR, end * HOUR))
            if rng.random() <= p_wind:
                dur = float(np.clip(rng.lognormal(mu_w, sigma), 1.0, 6.0))
                center = d * 24 + (2.5 + (phase if abs(phase) < 6 else 0) + rng.normal(0, 1.0)) % 24
                start = max(d * 24.0, center - dur / 2)
                end = min((d + 1) * 24.0, start + dur)
                if end - start >= 1.0 and not any(
                    max(w.start_s, start * HOUR) < min(w.end_s, end * HOUR) for w in wins
                ):
                    wins.append(Window(start * HOUR, end * HOUR))
        wins.sort(key=lambda w: w.start_s)
        traces.append(SiteTrace(s, wins))
    return traces


@dataclass
class Forecaster:
    """Noisy view of the remaining-window duration (§VI.H)."""

    traces: Sequence[SiteTrace]
    sigma_s: float = 900.0  # 15 min 1-sigma forecast error
    seed: int = 17

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def remaining(self, site: int, t: float) -> float:
        true = self.traces[site].remaining(t)
        if self.sigma_s <= 0:
            return true
        if true <= 0:
            return 0.0
        return max(0.0, true + float(self._rng.normal(0, self.sigma_s)))

    def active(self, site: int, t: float) -> bool:
        return self.traces[site].active(t)


def trace_stats(traces: Sequence[SiteTrace]) -> dict:
    durs = [w.duration_s / HOUR for tr in traces for w in tr.windows]
    total = sum(durs)
    return {
        "n_windows": len(durs),
        "mean_h": float(np.mean(durs)) if durs else 0.0,
        "min_h": float(np.min(durs)) if durs else 0.0,
        "max_h": float(np.max(durs)) if durs else 0.0,
        "total_surplus_h": total,
    }
