"""Migration policies, including the paper's feasibility-aware scheduler
(Algorithm 1), behind a typed event-driven control API.

Contract: ``Policy.decide(state: ClusterState) -> list[Action]`` evaluated
at every orchestrator tick (Δt).  The :class:`~repro.core.state.ClusterState`
snapshot carries live jobs (with *measured* checkpoint sizes), per-site
renewable forecasts, the advertised WAN bandwidth matrix (per-NIC fair
share), and site load; actions are the typed verbs of
:mod:`repro.core.actions` (``Migrate``/``Defer``/``Pause``/``Resume``/
``Throttle``).

Policies live in a registry: decorate a class with
``@register_policy("name", aliases=(...), config=SomePolicyConfig)`` and it
becomes constructible via ``make_policy(name, config=..., **overrides)`` and
usable from ``run_policy_comparison``, benchmarks and examples.  Structured
``PolicyConfig`` dataclasses carry per-policy knobs (e.g. stochastic
feasibility ``eps``/``forecast_sigma_s``) through every entry point.

Built-ins:

  static            never migrates (Table VI row 1)
  energy-only       chases renewable windows, no feasibility filter (row 2)
  feasibility-aware Algorithm 1: hard feasibility filter, then utility
                    maximization within the feasible set (row 3)
  oracle            feasibility-aware with σ=0 forecasts (Table VIII row 4)
  grid-throttle     beyond-paper demand response: Throttle jobs on grid
                    power, restore full power inside renewable windows
  defer-to-window   beyond-paper: Defer queued jobs at dark sites until the
                    site's next forecast window start
  plan-ahead        beyond-paper: multi-step plans over ``state.forecast``
                    — Algorithm 1 hardened against forecast link outages,
                    Pause-for-window sequences, pre-emptive evacuation
                    ahead of uplink brownouts, horizon-bounded Defer
  receding-horizon  beyond-paper: signal-aware multi-window plan search —
                    every tick, stay/park(k)/migrate(d) branches scored in
                    forecast gCO2 (grid-signal stacks), demand-response
                    throttling through carbon peaks and curtail requests
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core import feasibility as fz
from repro.core import policy_kernels as pk
from repro.core.actions import Action, Defer, Migrate, Pause, Resume, Throttle
from repro.core.policy_kernels import _norm_ppf_cached
from repro.core.state import (
    STATE_PAUSED, STATE_QUEUED, STATE_RUNNING, ClusterState, JobSoA, JobView,
    SiteView,
)

# Backwards-looking alias: the pre-redesign name for the snapshot type.
OrchestratorContext = ClusterState


# ---------------------------------------------------------------------------
# Policy configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicyConfig:
    """Base for structured per-policy parameters (empty for static/energy)."""


@dataclass(frozen=True)
class FeasibilityConfig(PolicyConfig):
    """Algorithm 1 knobs (§V.B, §VI.H)."""

    alpha: float = fz.ALPHA
    gamma: float = 1.0  # renewable weight (benefit term)
    beta: float = 1.0  # congestion weight
    queue_penalty_s: float = 7200.0  # expected wait per unit load
    min_benefit_s: float = 1500.0  # hysteresis: don't move for marginal wins
    eps: float = 0.0  # >0 enables stochastic feasibility (§VI.H)
    forecast_sigma_s: float = 0.0
    fault_aware: bool = True  # mask blacked-out sites / dead links


@dataclass(frozen=True)
class ThrottleConfig(PolicyConfig):
    power_frac: float = 0.5  # demand-response level on grid power


@dataclass(frozen=True)
class DeferConfig(PolicyConfig):
    max_wait_s: float = 4 * 3600.0  # never hold a queued job longer than this


@dataclass(frozen=True)
class RecedingHorizonConfig(PolicyConfig):
    """Knobs for the signal-aware receding-horizon planner."""

    alpha: float = fz.ALPHA
    plan_windows: int = 4  # K: how many future windows a plan search tries
    delay_cost_g_per_s: float = 0.01  # gCO2-equivalent per second of delay
    min_benefit_g: float = 60.0  # hysteresis: act only for real gram wins
    min_park_compute_s: float = 1800.0  # don't park nearly-done jobs
    max_park_s: float = 12 * 3600.0  # Pause-plan lookahead bound
    max_wait_s: float = 6 * 3600.0  # Defer bound for queued jobs
    arrival_margin_s: float = 1800.0  # forecast-noise margin on arrivals
    peak_threshold_g: float = 430.0  # Throttle grid compute above this
    dr_power_frac: float = 0.3  # throttle level during peaks / DR spans
    price_weight_g_per_usd: float = 0.0  # >0 folds $ into the objective
    battery_aware: bool = False  # credit stored kWh against dark spans
    fault_aware: bool = True  # mask blacked-out sites / dead links


@dataclass(frozen=True)
class PlanAheadConfig(PolicyConfig):
    """Knobs for the forecast-driven planner (Algorithm 1 + lookahead)."""

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    max_wait_s: float = 4 * 3600.0  # Defer bound (as defer-to-window)
    pause_horizon_s: float = 4 * 3600.0  # Pause-for-window lookahead
    min_pause_compute_s: float = 1800.0  # don't park nearly-done jobs
    arrival_margin_s: float = 1800.0  # forecast-noise margin on arrivals
    fault_aware: bool = True  # mask blacked-out sites / dead links


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["Policy"]] = {}
_ALIASES: Dict[str, str] = {}
_CONFIGS: Dict[str, Type[PolicyConfig]] = {}


def register_policy(name: str, *, aliases: Tuple[str, ...] = (),
                    config: Type[PolicyConfig] = PolicyConfig):
    """Class decorator: add a Policy to the registry under ``name``
    (stored normalized — lowercase, dashes — so lookups always hit)."""

    key = _norm(name)

    def deco(cls: Type["Policy"]) -> Type["Policy"]:
        cls.name = key
        _REGISTRY[key] = cls
        _CONFIGS[key] = config
        for a in aliases:
            _ALIASES[_norm(a)] = key
        return cls

    return deco


def _norm(name: str) -> str:
    return name.lower().replace("_", "-")


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


def policy_config_cls(name: str) -> Type[PolicyConfig]:
    return _CONFIGS[_resolve(name)]


def _resolve(name: str) -> str:
    key = _norm(name)
    key = _ALIASES.get(key, key)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; available: {', '.join(available_policies())}"
        )
    return key


def make_policy(name: str, config: Optional[PolicyConfig] = None, **kw) -> "Policy":
    """Instantiate a registered policy.

    ``config`` is a :class:`PolicyConfig` matching the policy (its fields are
    splatted into the constructor); ``**kw`` overrides individual fields.
    """
    key = _resolve(name)
    if config is not None:
        kw = {**dataclasses.asdict(config), **kw}
    return _REGISTRY[key](**kw)


# ---------------------------------------------------------------------------
# Algorithm 1 building blocks (shared by feasibility-aware and plan-ahead)
# ---------------------------------------------------------------------------


def algorithm1_grid(state: ClusterState, candidates: List[JobView], *,
                    alpha: float, eps: float = 0.0,
                    forecast_sigma_s: float = 0.0, bw_grid=None):
    """Stage 1, vectorized: one feasibility evaluation over the whole
    (candidate × destination) grid per tick.  ``bw_grid`` overrides the
    snapshot's advertised rows (plan-ahead hardens them against forecast
    outages first); ``eps`` > 0 with ``forecast_sigma_s`` > 0 swaps the
    deterministic time gate for the stochastic one (§VI.H).  Returns
    ``(ok_grid, t_transfer_grid)``."""
    import numpy as np

    sizes = np.array([j.ckpt_bytes for j in candidates])[:, None]
    t_loads = np.array([j.t_load_s for j in candidates])[:, None]
    if bw_grid is None:
        bw_grid = np.asarray(state.bandwidth_bps)[
            np.array([j.site for j in candidates], dtype=np.int64), :
        ]  # (n_candidates, n_sites)
    windows = state.site_window_s[None, :]
    v = fz.evaluate(sizes, bw_grid, windows, alpha=alpha, t_load_s=t_loads)
    if eps > 0.0 and forecast_sigma_s > 0.0:
        ok_grid = (
            np.asarray(
                fz.stochastic_feasible(
                    sizes, bw_grid, windows, forecast_sigma_s,
                    eps=eps, alpha=alpha, t_load_s=t_loads,
                )
            )
            & np.asarray(v.energy_ok)
            & (np.asarray(v.workload_class) != 2)
        )
    else:
        ok_grid = np.asarray(v.feasible)
    return ok_grid, np.asarray(v.t_transfer_s)


def best_destination(state: ClusterState, job: JobView, ok_row,
                     t_transfer_row, reserved: Dict[int, int], *,
                     gamma: float, beta: float, queue_penalty_s: float,
                     min_benefit_s: float) -> Optional[int]:
    """Stage 2: utility maximization inside the feasible set.

        benefit(d) = γ · expected grid-seconds avoided
                     − β · queue penalty · (load(d) − load(s))

    ``reserved`` tracks same-tick slot commitments so concurrent decisions
    do not herd.  Returns the argmax destination sid (ties by transfer
    time) or None when nothing beats ``max(t_cost, min_benefit_s)``."""
    cur = state.site(job.site)
    best: Optional[Tuple[float, float, int]] = None  # (-benefit, t_transfer, sid)
    for dest in state.sites:
        if dest.sid == job.site:
            continue
        if not ok_row[dest.sid]:
            continue
        window = dest.window_remaining_s
        t_transfer = float(t_transfer_row[dest.sid])
        t_cost = t_transfer + job.t_load_s + fz.T_DOWNTIME_S
        cur_green_s = cur.window_remaining_s if cur.renewable_active else 0.0
        dest_green_s = min(window, job.remaining_compute_s)
        grid_seconds_avoided = max(
            0.0, dest_green_s - min(cur_green_s, job.remaining_compute_s))
        dest_load = (dest.busy + dest.queued
                     + reserved[dest.sid]) / max(dest.slots, 1)
        # symmetric congestion term: moving toward a less-loaded site is
        # itself a benefit (contention-aware placement, §V.D.2)
        benefit = (
            gamma * grid_seconds_avoided
            - beta * queue_penalty_s * (dest_load - cur.load)
        )
        if dest.free_slots - reserved[dest.sid] <= 0:
            benefit -= queue_penalty_s  # would have to queue
        if benefit <= max(t_cost, min_benefit_s):
            continue
        key = (-benefit, t_transfer, dest.sid)
        if best is None or key < best:
            best = key
    return best[2] if best is not None else None


# ---------------------------------------------------------------------------
# Vectorized kernels (SoA fast path; the scalar functions above are the
# parity oracles — tests/test_vectorized.py asserts identical Action lists)
# ---------------------------------------------------------------------------

def feasibility_grid_arrays(
    sizes, t_loads, bw_grid, windows, *, alpha: float, eps: float = 0.0,
    forecast_sigma_s: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Algorithm 1 stage 1 as one lean numpy pass over SoA columns.

    ``sizes``/``t_loads`` are ``(k, 1)``, ``bw_grid`` ``(k, n)``,
    ``windows`` ``(n,)`` or ``(1, n)``.  Bit-identical to
    :func:`algorithm1_grid` (which routes through ``fz.evaluate`` and its
    NamedTuple) but without the per-call dispatch and intermediate
    verdicts.  Returns ``(ok_grid, t_transfer_grid)``.
    """
    with np.errstate(divide="ignore"):
        t_transfer = 8.0 * sizes / bw_grid
    t_cost = t_transfer + t_loads + fz.T_DOWNTIME_S
    energy_ok = (fz.P_SYS_KW / fz.P_NODE_KW) * t_transfer < windows
    not_c = t_transfer < fz.CLASS_B_MAX_S
    if eps > 0.0 and forecast_sigma_s > 0.0:
        # stochastic gate (§VI.H): deterministic check against the lower
        # eps-quantile of the window (fz.stochastic_feasible, numpy path)
        window_lo = windows + _norm_ppf_cached(eps) * forecast_sigma_s
        time_ok = t_cost < alpha * np.maximum(window_lo, 0.0)
    else:
        time_ok = t_cost < alpha * windows
    return time_ok & energy_ok & not_c, t_transfer


def benefit_grid_arrays(
    state: ClusterState, cand: np.ndarray, t_transfer_grid: np.ndarray, *,
    gamma: float, beta: float, queue_penalty_s: float,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stage 2's benefit, for every (candidate, destination) pair at once,
    with zero same-tick reservations (the common case — reservations only
    exist after a migration was already committed this tick, and those rare
    follow-up rows fall back to the scalar :func:`best_destination`).
    Arithmetic mirrors the scalar path op for op.  Returns
    ``(benefit_grid, t_cost_grid)``."""
    soa = state.soa
    W = state.site_window_s
    s_i = soa.site[cand]
    rem = soa.remaining_s[cand][:, None]
    t_cost = t_transfer_grid + soa.t_load_s[cand][:, None] + fz.T_DOWNTIME_S
    cur_green = np.where(state.site_renewable[s_i], W[s_i], 0.0)[:, None]
    dest_green = np.minimum(W[None, :], rem)
    avoided = np.maximum(0.0, dest_green - np.minimum(cur_green, rem))
    benefit = (gamma * avoided
               - (beta * queue_penalty_s)
               * (state.site_bq_load[None, :] - state.site_load[s_i][:, None]))
    benefit = np.where(state.site_free_slots[None, :] <= 0,
                       benefit - queue_penalty_s, benefit)
    return benefit, t_cost


def pick_best_grid(
    benefit: np.ndarray, t_transfer_grid: np.ndarray, valid: np.ndarray,
) -> np.ndarray:
    """Per-row argbest destination under the scalar tie-break key
    ``(-benefit, t_transfer, sid)`` — max benefit, ties by transfer time,
    then lowest site id.  Returns ``(k,)`` destination sids, ``-1`` where
    no destination is valid."""
    b = np.where(valid, benefit, -np.inf)
    mb = b.max(axis=1)
    tie = valid & (b == mb[:, None])
    tt = np.where(tie, t_transfer_grid, np.inf)
    tie = tie & (tt == tt.min(axis=1)[:, None])
    return np.where(np.isfinite(mb), tie.argmax(axis=1), -1)


_ARANGE: Dict[int, np.ndarray] = {}


def _arange(n: int) -> np.ndarray:
    got = _ARANGE.get(n)
    if got is None:
        got = _ARANGE[n] = np.arange(n)
    return got


def score_migrations(
    state: ClusterState, cand: np.ndarray, bw_grid, *, alpha: float,
    eps: float = 0.0, forecast_sigma_s: float = 0.0, gamma: float,
    beta: float, queue_penalty_s: float, min_benefit_s: float,
    s_i: Optional[np.ndarray] = None, sizes: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused stage 1 + stage 2 for the zero-reservation case: feasibility,
    benefit and argbest destination in one pass (the composition of
    :func:`feasibility_grid_arrays`, :func:`benefit_grid_arrays` and
    :func:`pick_best_grid`, inlined to share gathers on the per-tick hot
    path).  ``s_i``/``sizes`` accept the caller's pre-gathered columns.
    Returns ``(ok_grid, t_transfer_grid, dest0)``."""
    soa = state.soa
    W = state.site_window_s
    if s_i is None:
        s_i = soa.site[cand]
    if sizes is None:
        sizes = soa.ckpt_bytes[cand][:, None]
    with np.errstate(divide="ignore"):
        tt = 8.0 * sizes / bw_grid
    t_cost = tt + soa.t_load_s[cand][:, None] + fz.T_DOWNTIME_S
    energy_ok = (fz.P_SYS_KW / fz.P_NODE_KW) * tt < W[None, :]
    not_c = tt < fz.CLASS_B_MAX_S
    if eps > 0.0 and forecast_sigma_s > 0.0:
        window_lo = W[None, :] + _norm_ppf_cached(eps) * forecast_sigma_s
        time_ok = t_cost < alpha * np.maximum(window_lo, 0.0)
    else:
        time_ok = t_cost < alpha * W[None, :]
    ok = time_ok & energy_ok & not_c
    # stage 2 benefit (reservation-free), arithmetic mirroring the scalar
    # best_destination op for op
    rem = soa.remaining_s[cand][:, None]
    cur_green = np.where(state.site_renewable[s_i], W[s_i], 0.0)[:, None]
    avoided = np.maximum(
        0.0, np.minimum(W[None, :], rem) - np.minimum(cur_green, rem))
    benefit = (gamma * avoided
               - (beta * queue_penalty_s)
               * (state.site_bq_load[None, :] - state.site_load[s_i][:, None]))
    benefit = benefit + np.where(state.site_free_slots <= 0,
                                 -queue_penalty_s, 0.0)[None, :]
    valid = (ok
             & (_arange(len(W))[None, :] != s_i[:, None])
             & (benefit > np.maximum(t_cost, min_benefit_s)))
    if not valid.any():  # the common tick: nothing beats staying put
        return ok, tt, None
    return ok, tt, pick_best_grid(benefit, tt, valid)


def _row_view(soa: JobSoA, i: int) -> JobView:
    """Materialize one JobView row (the reserved-aware scalar fallback
    hands it to :func:`best_destination`)."""
    from repro.core.state import _STATE_NAMES

    return JobView(int(soa.jids[i]), int(soa.site[i]),
                   float(soa.ckpt_bytes[i]), float(soa.remaining_s[i]),
                   float(soa.t_load_s[i]), state=_STATE_NAMES[soa.state[i]],
                   eligible=bool(soa.eligible[i]),
                   power_frac=float(soa.power_frac[i]),
                   defer_until_s=float(soa.defer_until_s[i]))


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class Policy:
    name = "base"

    def decide(self, state: ClusterState) -> List[Action]:
        raise NotImplementedError

    def decide_batch(self, states: Sequence[ClusterState]) -> List[List[Action]]:
        """Decide for many independent cells at once (the batched sweep
        runner's entry point).  The default just loops :meth:`decide`;
        grid policies override it to score every cell's candidate rows in
        one fused :mod:`repro.core.policy_kernels` pass.  Policies must
        be stateless w.r.t. ``self`` (all built-ins are): the runner
        calls one instance for every cell of a config-identical group."""
        return [self.decide(s) for s in states]

    # Comparison harnesses use this instead of string-matching on the name.
    wants_oracle_forecast = False


@register_policy("static")
class StaticPolicy(Policy):
    """Fixed placement, no inter-site coordination (§VII.E baseline 1)."""

    def decide(self, state: ClusterState) -> List[Action]:
        return []


@register_policy("energy-only", aliases=("energyonly",))
class EnergyOnlyPolicy(Policy):
    """Migrate whenever renewable energy is available elsewhere, without
    feasibility constraints (§VII.E baseline 2). Herds onto the greenest
    site; initiates transfers that cannot finish inside windows."""

    def decide(self, state: ClusterState) -> List[Action]:
        """Vectorized: candidates are running+eligible jobs at dark sites;
        since a candidate's own site is never green, the per-job green list
        of the scalar oracle is one shared site set."""
        soa = state.soa
        if soa.count(STATE_RUNNING) == 0:
            return []
        renew = state.site_renewable
        cand = ((soa.state == STATE_RUNNING) & soa.eligible
                & ~renew[soa.site]).nonzero()[0]
        if not len(cand):
            return []
        # spread over whatever is green right now (hash placement), with
        # only a stale capacity check and NO feasibility filter (§VII.E:
        # 'lacks awareness of transfer-time or energy-cost limits'):
        # transfers near window end, Class C checkpoints and transient
        # over-subscription all happen.
        greens = np.flatnonzero(
            renew & (state.site_slots - state.site_busy > 0))
        if not len(greens):
            return []
        jids = soa.jids[cand]
        dests = greens[jids % len(greens)]
        return [Migrate(int(j), int(d)) for j, d in zip(jids, dests)]

    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """Per-job reference implementation (parity oracle)."""
        out: List[Action] = []
        for job in state.migratable():
            cur = state.site(job.site)
            if cur.renewable_active:
                continue  # already green
            greens = [
                s for s in state.sites
                if s.renewable_active and s.sid != job.site
                and (s.slots - s.busy) > 0  # STALE capacity: ignores in-flight
            ]
            if not greens:
                continue
            dest = greens[job.jid % len(greens)]
            out.append(Migrate(job.jid, dest.sid))
        return out


@register_policy("feasibility-aware", aliases=("feasibility", "ours"),
                 config=FeasibilityConfig)
@dataclass
class FeasibilityAwarePolicy(Policy):
    """Paper Algorithm 1 (§V.B).

    Stage 1 — strict feasibility filter per (job, destination):
        T_cost = T_transfer + T_load + 0.4 s
        reject if T_cost > α · window(d)            (time)
        reject if T_breakeven > window(d)           (energy)
        reject if class(w) == C                     (§VI.D)
    Stage 2 — optimization inside the feasible set:
        benefit(d) = expected grid-seconds avoided − queue penalty
        migrate to argmax benefit iff benefit > T_cost, ties by T_transfer.
    """

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    eps: float = 0.0
    forecast_sigma_s: float = 0.0
    fault_aware: bool = True

    def _params(self) -> pk.ScoreParams:
        return pk.ScoreParams(
            alpha=self.alpha, gamma=self.gamma, beta=self.beta,
            queue_penalty_s=self.queue_penalty_s,
            min_benefit_s=self.min_benefit_s, eps=self.eps,
            forecast_sigma_s=self.forecast_sigma_s)

    def _prep(self, state: ClusterState) -> Optional[np.ndarray]:
        """Candidate rows for one cell, or ``None`` when the tick is
        trivially migration-free (all-dark, nothing running)."""
        soa = state.soa
        # a migration must pass the energy gate T_BE < window (T_BE >= 0),
        # so no positive window anywhere means no feasible destination
        if not state.site_window_s.max() > 0.0:
            return None
        cand = ((soa.state == STATE_RUNNING) & soa.eligible).nonzero()[0]
        return cand if len(cand) else None

    def _fault_bw(self, state: ClusterState,
                  s_i: np.ndarray) -> Optional[np.ndarray]:
        """Bandwidth rows with fault-dead links zeroed, or ``None`` when
        no masking applies (fault-blind config, or no fault views seeded
        on the snapshot) — callers then use the advertised rows, keeping
        every fault-free digit byte-identical.  ``link_up`` composes
        endpoint blackouts with hard link failures, so a zeroed column
        also masks a blacked-out destination site (which otherwise
        advertises free slots and a live window — the trap a fault-blind
        policy walks into)."""
        if not self.fault_aware:
            return None
        lu = state.__dict__.get("link_up")
        if lu is None:
            return None
        return np.where(lu[s_i, :],
                        np.asarray(state.bandwidth_bps)[s_i, :], 0.0)

    def _commit(self, state: ClusterState, cand: np.ndarray,
                dest0: np.ndarray, ok: Optional[np.ndarray],
                tt: Optional[np.ndarray],
                bw_grid: Optional[np.ndarray] = None) -> List[Action]:
        """Turn argbest destinations into Actions under same-tick slot
        reservations, without leaving numpy.  Each commit to site ``d``
        bumps the reservation count and re-scores ONLY column ``d`` (a
        reserved column's benefit only drops, so every other row's
        argbest is provably unchanged); the later rows that pointed at
        ``d`` are then re-picked as one small grid.  Compiled backends
        hand in ``ok=tt=None`` and the numpy grids are materialized
        lazily on the first commit (rare).  Emits exactly the Action
        list of the scalar reservation walk in :meth:`decide_scalar`."""
        if not (dest0 >= 0).any():  # the common tick: nothing moves
            return []
        soa = state.soa
        jids = soa.jids
        out: List[Action] = []
        dest = np.asarray(dest0).astype(np.int64, copy=True)
        res: Optional[np.ndarray] = None  # built on first commit
        k = len(cand)
        # re-picks only ever shrink the committed set (columns only get
        # worse), so the rows worth visiting are fixed up front
        for r in np.flatnonzero(dest >= 0):
            d = int(dest[r])
            if d < 0:  # re-picked away by an earlier reservation
                continue
            out.append(Migrate(int(jids[cand[r]]), d))
            if res is None:
                # first commit this tick: materialize the grids the
                # reservation-aware column updates need
                if ok is None:
                    if bw_grid is None:
                        bw_grid = state.bandwidth_bps[soa.site[cand], :]
                    ok, tt = feasibility_grid_arrays(
                        soa.ckpt_bytes[cand][:, None],
                        soa.t_load_s[cand][:, None],
                        bw_grid,
                        state.site_window_s[None, :], alpha=self.alpha,
                        eps=self.eps,
                        forecast_sigma_s=self.forecast_sigma_s)
                benefit, t_cost = benefit_grid_arrays(
                    state, cand, tt, gamma=self.gamma, beta=self.beta,
                    queue_penalty_s=self.queue_penalty_s)
                W = state.site_window_s
                s_i = soa.site[cand]
                rem = soa.remaining_s[cand]
                cur_green = np.where(state.site_renewable[s_i], W[s_i], 0.0)
                load_src = state.site_load[s_i]
                bq_raw = state.site_bq_raw
                res = np.zeros(len(W), dtype=np.int64)
            res[d] += 1
            # column d under the new reservation count, with the exact
            # scalar float-op order of best_destination
            dest_load = (int(bq_raw[d]) + int(res[d])) / max(
                int(state.site_slots[d]), 1)
            avoided = np.maximum(
                0.0, np.minimum(W[d], rem) - np.minimum(cur_green, rem))
            col = (self.gamma * avoided
                   - self.beta * self.queue_penalty_s
                   * (dest_load - load_src))
            if int(state.site_free_slots[d]) - int(res[d]) <= 0:
                col = col - self.queue_penalty_s  # would have to queue
            benefit[:, d] = col
            if r + 1 < k:
                stale = np.flatnonzero(dest[r + 1:] == d) + (r + 1)
                if len(stale):
                    valid = (ok[stale]
                             & (s_i[stale, None] != _arange(len(W))[None, :])
                             & (benefit[stale] > np.maximum(
                                 t_cost[stale], self.min_benefit_s)))
                    dest[stale] = pick_best_grid(
                        benefit[stale], tt[stale], valid)
        return out

    def decide(self, state: ClusterState) -> List[Action]:
        """Vectorized Algorithm 1: one whole-grid pass over the SoA
        columns (numpy by default, the fused jit/pallas kernel when that
        backend is selected); rows decided after a same-tick reservation
        (rare) fall back to the scalar stage 2.  Emits exactly the Action
        list of :meth:`decide_scalar`."""
        cand = self._prep(state)
        if cand is None:
            return []
        soa = state.soa
        bw = self._fault_bw(state, soa.site[cand])
        if pk.backend() != "numpy":
            dest0 = pk.score_rows([pk.rows_from_state(state, cand, bw)],
                                  self._params())[0]
            return self._commit(state, cand, dest0, None, None, bw)
        ok, tt, dest0 = score_migrations(
            state, cand,
            bw if bw is not None else state.bandwidth_bps[soa.site[cand], :],
            alpha=self.alpha, eps=self.eps,
            forecast_sigma_s=self.forecast_sigma_s, gamma=self.gamma,
            beta=self.beta, queue_penalty_s=self.queue_penalty_s,
            min_benefit_s=self.min_benefit_s)
        if dest0 is None:
            return []
        return self._commit(state, cand, dest0, ok, tt, bw)

    def decide_batch(self, states: Sequence[ClusterState]) -> List[List[Action]]:
        """All cells' candidate rows scored in ONE fused kernel pass
        (bit-identical to per-cell :meth:`decide` — see
        :mod:`repro.core.policy_kernels` on padding lanes)."""
        cands = [self._prep(s) for s in states]
        live = [i for i, c in enumerate(cands) if c is not None]
        bws = [self._fault_bw(states[i], states[i].soa.site[cands[i]])
               for i in live]
        if any(b is not None for b in bws):
            # batch_from_states takes bw_grids all-or-nothing: fill the
            # unmasked cells with their advertised rows (element-identical)
            bws = [b if b is not None
                   else np.asarray(states[i].bandwidth_bps)[
                       states[i].soa.site[cands[i]], :]
                   for i, b in zip(live, bws)]
        else:
            bws = None
        dests = iter(pk.score_states([states[i] for i in live],
                                     [cands[i] for i in live],
                                     self._params(), bws))
        bw_by_cell = dict(zip(live, bws)) if bws is not None else {}
        out: List[List[Action]] = []
        for i, (s, c) in enumerate(zip(states, cands)):
            d0 = None if c is None else next(dests)
            out.append([] if d0 is None
                       else self._commit(s, c, d0, None, None,
                                         bw_by_cell.get(i)))
        return out

    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """The per-job reference implementation (parity oracle for
        :meth:`decide`)."""
        candidates = state.migratable()
        if not candidates:
            return []
        bw = self._fault_bw(
            state, np.array([j.site for j in candidates], dtype=np.int64))
        ok_grid, t_transfer_grid = algorithm1_grid(
            state, candidates, alpha=self.alpha, eps=self.eps,
            forecast_sigma_s=self.forecast_sigma_s, bw_grid=bw)
        out: List[Action] = []
        # Track slot reservations within this tick so we do not herd.
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        for i, job in enumerate(candidates):
            dest = best_destination(
                state, job, ok_grid[i], t_transfer_grid[i], reserved,
                gamma=self.gamma, beta=self.beta,
                queue_penalty_s=self.queue_penalty_s,
                min_benefit_s=self.min_benefit_s)
            if dest is not None:
                out.append(Migrate(job.jid, dest))
                reserved[dest] += 1
        return out


@register_policy("oracle", config=FeasibilityConfig)
@dataclass
class OraclePolicy(FeasibilityAwarePolicy):
    """Feasibility-aware under perfect (σ=0) forecasts (Table VIII row 4).
    The zero-noise forecaster is selected by the harness via
    ``wants_oracle_forecast``."""

    wants_oracle_forecast = True


@register_policy("grid-throttle", config=ThrottleConfig)
@dataclass
class GridThrottlePolicy(Policy):
    """Beyond-paper demand response: run at reduced power whenever a site is
    on grid electricity, full power inside renewable windows.  Exercises the
    ``Throttle`` action; never migrates."""

    power_frac: float = 0.5

    def decide(self, state: ClusterState) -> List[Action]:
        soa = state.soa
        if soa.count(STATE_RUNNING) == 0:
            return []
        want = np.where(state.site_renewable[soa.site], 1.0, self.power_frac)
        mask = ((soa.state == STATE_RUNNING)
                & (np.abs(soa.power_frac - want) > 1e-9))
        return [Throttle(int(j), float(w))
                for j, w in zip(soa.jids[mask], want[mask])]

    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """Per-job reference implementation (parity oracle)."""
        out: List[Action] = []
        for job in state.running():
            green = state.site(job.site).renewable_active
            want = 1.0 if green else self.power_frac
            if abs(job.power_frac - want) > 1e-9:
                out.append(Throttle(job.jid, want))
        return out


@register_policy("plan-ahead", aliases=("planahead",), config=PlanAheadConfig)
@dataclass
class PlanAheadPolicy(Policy):
    """Forecast-driven planner: Algorithm 1's filter evaluated against the
    *forecast* fabric, plus multi-step Pause/Resume and Defer plans over
    the window horizon (``state.forecast``).

    Four stages per tick:

    1. **Migrate** — Algorithm 1 (hard feasibility filter + utility
       maximization), with the bandwidth grid hardened against forecast
       link outages: a transfer that would still be in flight when an
       outage begins on its link is planned at the outage's degraded
       capacity, not today's matrix.  Every chosen migration must also
       pass an *arrival* check at the post-admission ``(flows+1)`` rate —
       the transfer must land ``arrival_margin_s`` inside the destination
       window and before any forecast outage on its link, so planned
       moves do not become failed migrations.  Jobs at green sites are
       pre-emptively evacuated only when the forecast says their uplink
       browns out before the window ends and their checkpoint could no
       longer drain afterwards.
    2. **Pause** — running jobs burning grid power at dark sites are
       parked when the forecast promises a window within
       ``pause_horizon_s`` (the Pause-for-window sequence PR 1 left open).
    3. **Resume** — paused jobs restart when their site turns green, or
       when the window they were waiting for evaporates from the
       forecast (no stranding).
    4. **Defer** — queued jobs at dark sites are held until the forecast
       window start (bounded by ``max_wait_s``), one Defer per
       (job, window) via ``JobView.defer_until_s``.

    Degrades gracefully to reactive feasibility-aware + defer behaviour
    when ``state.forecast`` is None.
    """

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    max_wait_s: float = 4 * 3600.0
    pause_horizon_s: float = 4 * 3600.0
    min_pause_compute_s: float = 1800.0
    arrival_margin_s: float = 1800.0
    fault_aware: bool = True

    def _params(self) -> pk.ScoreParams:
        return pk.ScoreParams(
            alpha=self.alpha, gamma=self.gamma, beta=self.beta,
            queue_penalty_s=self.queue_penalty_s,
            min_benefit_s=self.min_benefit_s)

    # ---- stage 1 (vectorized): migration -----------------------------------
    def _mig_prep(self, state: ClusterState) -> Optional[tuple]:
        """Candidate selection, evacuation pre-skip and outage hardening
        for one cell: ``(cand, s_i, sizes, bw_grid)``, or ``None`` when
        the tick is trivially migration-free."""
        t = state.t
        fc = state.forecast
        soa = state.soa
        W = state.site_window_s
        # a migration must pass the energy gate T_BE < window (T_BE >= 0),
        # so no positive window anywhere means no feasible destination
        if not W.max() > 0.0 or soa.count(STATE_RUNNING) == 0:
            return None
        cand = ((soa.state == STATE_RUNNING) & soa.eligible).nonzero()[0]
        if not len(cand):
            return None
        # pre-skip (pre-emptive-evacuation scan, vectorized): green
        # candidates stay put unless the forecast says their uplink browns
        # out before the current window ends; the grids below only score
        # the survivors
        s_i = soa.site[cand]
        green = state.site_renewable[s_i]
        if fc is None:
            keep = ~green
        else:
            uplink = fc.next_uplink_outage_grid(t)
            keep = ~(green & ((soa.remaining_s[cand] <= W[s_i])
                              | (uplink[s_i] > t + W[s_i])))
        if not keep.all():
            cand = cand[keep]
            if not len(cand):
                return None
            s_i = s_i[keep]
        sizes = soa.ckpt_bytes[cand][:, None]
        bw_grid = state.bandwidth_bps[s_i, :]  # fancy indexing: a copy
        # forecast hardening: plan any transfer that would cross the first
        # forecast outage on its link at the outage's degraded capacity
        if fc is not None:
            o_start, _, o_cap = fc.next_outage_grid(t)
            os_rows = o_start[s_i, :]
            with np.errstate(divide="ignore"):
                tt0 = 8.0 * sizes / bw_grid
            cross = (os_rows < t + tt0) & (bw_grid > 0.0)
            bw_grid = np.where(cross, np.minimum(bw_grid, o_cap[s_i, :]),
                               bw_grid)
        # fault masking: links the fault views mark dead (hard failure or
        # a blacked-out endpoint) carry zero plan rate — the destination
        # becomes infeasible exactly like a zero-capacity brownout
        if self.fault_aware:
            lu = state.__dict__.get("link_up")
            if lu is not None:
                bw_grid = np.where(lu[s_i, :], bw_grid, 0.0)
        return cand, s_i, sizes, bw_grid

    def _migrations(self, state: ClusterState, planned: set) -> List[Action]:
        """Whole-grid stage 1: outage hardening, feasibility, evacuation
        scan and destination scoring as single grid passes over the SoA
        (numpy by default, the fused compiled kernel when selected); only
        committed migrations (rare) run scalar follow-up work
        (post-admission arrival check, reservation-aware re-scoring)."""
        prep = self._mig_prep(state)
        if prep is None:
            return []
        cand, s_i, sizes, bw_grid = prep
        if pk.backend() != "numpy":
            dest0 = pk.score_rows(
                [pk.rows_from_state(state, cand, bw_grid)],
                self._params())[0]
            return self._mig_commit(state, planned, cand, s_i, bw_grid,
                                    dest0, None, None)
        ok, tt, dest0 = score_migrations(
            state, cand, bw_grid, alpha=self.alpha, gamma=self.gamma,
            beta=self.beta, queue_penalty_s=self.queue_penalty_s,
            min_benefit_s=self.min_benefit_s, s_i=s_i, sizes=sizes)
        if dest0 is None:
            return []
        return self._mig_commit(state, planned, cand, s_i, bw_grid, dest0,
                                ok, tt)

    def _mig_commit(self, state: ClusterState, planned: set,
                    cand: np.ndarray, s_i: np.ndarray, bw_grid: np.ndarray,
                    dest0: np.ndarray, ok: Optional[np.ndarray],
                    tt: Optional[np.ndarray]) -> List[Action]:
        """Argbest destinations -> Actions: post-admission arrival checks
        plus same-tick slot reservations (first commit switches remaining
        rows to the reservation-aware scalar stage 2; compiled backends
        hand in ``ok=tt=None`` and the numpy grids — against the SAME
        outage-hardened ``bw_grid`` — are recomputed lazily then).

        Until the first commit every row is judged against the tick's
        *initial* ``flows``, so the arrival checks are independent and
        run as one vector pass over the ``dest0 >= 0`` rows (the slow
        part of fleet-scale decide used to be this loop walking every
        candidate in Python just to skip the ``dest0 < 0`` majority);
        the per-row gates are op-for-op the scalar oracle's, so the
        first passing row — and hence the whole Action list — is
        unchanged."""
        if not (dest0 >= 0).any():  # the common tick: nothing moves
            return []
        t = state.t
        fc = state.forecast
        soa = state.soa
        W = state.site_window_s
        start_after = (fc.next_outage_start_after_grid(t)
                       if fc is not None else None)
        # fold forecast fault starts into the arrival gate: a transfer
        # must land before the first thing — brownout OR blackout/link
        # failure — that would kill its plan rate
        if start_after is not None and self.fault_aware:
            fg = fc.next_fault_start_grid(t)
            if fg is not None:
                start_after = np.minimum(start_after, fg)

        out: List[Action] = []
        flows = list(state.transfers)

        # ---- vectorized pre-commit pass over the argbest rows
        sel = np.nonzero(dest0 >= 0)[0]
        d_sel = dest0[sel].astype(np.int64)
        s_sel = s_i[sel].astype(np.int64)
        rates = np.array([
            state.post_admission_bps(int(s), int(d), flows)
            for s, d in zip(s_sel, d_sel)])
        pos = rates > 0.0
        t_arr = t + 8.0 * soa.ckpt_bytes[cand[sel]] / np.where(pos, rates,
                                                               1.0)
        good = pos & ~(t_arr + self.arrival_margin_s > t + W[d_sel])
        if start_after is not None:
            good &= ~(start_after[s_sel, d_sel] < t_arr)
        if not good.any():  # every argbest row failed its arrival check
            return []
        first_q = int(np.nonzero(good)[0][0])
        k0 = int(sel[first_q])  # cand-index of the first commit
        i0 = int(cand[k0])
        dest_sid = int(d_sel[first_q])
        src = int(s_sel[first_q])
        jid = int(soa.jids[i0])
        out.append(Migrate(jid, dest_sid))
        flows.append((src, dest_sid))
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        reserved[dest_sid] += 1
        planned.add(jid)

        # ---- reservation-aware scalar stage 2 for the remaining rows
        # (the commit above invalidated the vector pass's flow snapshot)
        for k in range(k0 + 1, len(cand)):
            i = cand[k]
            if ok is None:
                ok, tt = feasibility_grid_arrays(
                    soa.ckpt_bytes[cand][:, None],
                    soa.t_load_s[cand][:, None], bw_grid, W[None, :],
                    alpha=self.alpha)
            dest_sid = best_destination(
                state, _row_view(soa, i), ok[k], tt[k], reserved,
                gamma=self.gamma, beta=self.beta,
                queue_penalty_s=self.queue_penalty_s,
                min_benefit_s=self.min_benefit_s)
            if dest_sid is None:
                continue
            src = int(s_i[k])
            # arrival check at the post-admission rate — counting both the
            # in-flight transfers and the migrations committed earlier this
            # tick (see the scalar oracle for the full rationale)
            rate = state.post_admission_bps(src, dest_sid, flows)
            if rate <= 0.0:
                continue
            t_arrive = t + 8.0 * float(soa.ckpt_bytes[i]) / rate
            if t_arrive + self.arrival_margin_s > t + W[dest_sid]:
                continue
            if fc is not None and start_after[src, dest_sid] < t_arrive:
                continue
            jid = int(soa.jids[i])
            out.append(Migrate(jid, dest_sid))
            flows.append((src, dest_sid))
            reserved[dest_sid] += 1
            planned.add(jid)
        return out

    # ---- stage 1 (scalar oracle) -------------------------------------------
    def _migrations_scalar(self, state: ClusterState, planned: set) -> List[Action]:
        t = state.t
        fc = state.forecast
        candidates = state.migratable()
        if not candidates:
            return []
        n_sites = state.n_sites
        cand_sites = np.array([j.site for j in candidates], dtype=np.int64)
        bw_grid = np.array(np.asarray(state.bandwidth_bps)[cand_sites, :],
                           copy=True)
        # forecast hardening: plan any transfer that would cross the first
        # forecast outage on its link at the outage's degraded capacity
        outage_at = {}
        if fc is not None:
            for s in set(int(x) for x in cand_sites):
                for d in range(n_sites):
                    if d != s:
                        outage_at[(s, d)] = fc.next_outage(s, d, t)
            for i, job in enumerate(candidates):
                for d in range(n_sites):
                    o = outage_at.get((job.site, d))
                    bw = bw_grid[i, d]
                    if o is None or bw <= 0.0:
                        continue
                    t_transfer = 8.0 * job.ckpt_bytes / bw
                    if o.start_s < t + t_transfer:  # would cross the outage
                        bw_grid[i, d] = min(bw, o.capacity_bps)
        # fault masking (scalar twin of _mig_prep's): dead links score 0
        if self.fault_aware:
            lu = state.__dict__.get("link_up")
            if lu is not None:
                bw_grid = np.where(lu[cand_sites, :], bw_grid, 0.0)
        ok_grid, t_transfer_grid = algorithm1_grid(
            state, candidates, alpha=self.alpha, bw_grid=bw_grid)

        out: List[Action] = []
        flows = list(state.transfers)
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        for i, job in enumerate(candidates):
            cur = state.site(job.site)
            if cur.renewable_active:
                if job.remaining_compute_s <= cur.window_remaining_s:
                    continue  # finishes green where it is
                # pre-emptive evacuation: only when the uplink is forecast
                # to brown out before this window ends — afterwards the
                # checkpoint could no longer drain at plan rate
                if fc is None:
                    continue
                uplink_out = fc.next_uplink_outage_start_s(job.site, t)
                if uplink_out > t + cur.window_remaining_s:
                    continue  # fabric stays clean: migrate reactively later
            dest_sid = best_destination(
                state, job, ok_grid[i], t_transfer_grid[i], reserved,
                gamma=self.gamma, beta=self.beta,
                queue_penalty_s=self.queue_penalty_s,
                min_benefit_s=self.min_benefit_s)
            if dest_sid is None:
                continue
            # arrival check at the post-admission rate — counting both the
            # in-flight transfers and the migrations committed earlier this
            # tick: the transfer must land inside the destination window
            # with margin, and before any forecast outage on its link
            # (otherwise the rate estimate is fiction and the move becomes
            # a failed migration)
            rate = state.post_admission_bps(job.site, dest_sid, flows)
            if rate <= 0.0:
                continue
            t_transfer = 8.0 * job.ckpt_bytes / rate
            t_arrive = t + t_transfer
            dest_window_end = t + state.site(dest_sid).window_remaining_s
            if t_arrive + self.arrival_margin_s > dest_window_end:
                continue
            if fc is not None:
                # only a FUTURE outage start the transfer would cross
                # invalidates the rate estimate — an outage already in
                # progress is baked into the (degraded) capacities behind
                # `rate`, but it must not mask a back-to-back successor
                nxt = fc.next_outage_start_after(job.site, dest_sid, t)
                if self.fault_aware:
                    nxt = min(nxt, fc.next_fault_start_after(
                        job.site, dest_sid, t))
                if nxt < t_arrive:
                    continue
            out.append(Migrate(job.jid, dest_sid))
            flows.append((job.site, dest_sid))
            reserved[dest_sid] += 1
            planned.add(job.jid)
        return out

    def decide(self, state: ClusterState) -> List[Action]:
        """Vectorized four-stage plan (emits exactly the Action list of
        :meth:`decide_scalar`): stage 1 via :meth:`_migrations`, stages
        2–4 as SoA masks against per-site forecast grids instead of
        per-job scalar horizon queries."""
        planned: set = set()
        out: List[Action] = list(self._migrations(state, planned))
        return self._stages234(state, planned, out)

    def decide_batch(self, states: Sequence[ClusterState]) -> List[List[Action]]:
        """Stage 1 of every cell scored in ONE fused kernel pass; the
        (cheap, already-vectorized) stages 2–4 run per cell."""
        preps = [self._mig_prep(s) for s in states]
        live = [i for i, p in enumerate(preps) if p is not None]
        dests = iter(pk.score_states(
            [states[i] for i in live], [preps[i][0] for i in live],
            self._params(), bw_grids=[preps[i][3] for i in live]))
        out: List[List[Action]] = []
        for s, p in zip(states, preps):
            planned: set = set()
            migs: List[Action] = []
            if p is not None:
                cand, s_i, _sizes, bw_grid = p
                d0 = next(dests)
                if d0 is not None:
                    migs = self._mig_commit(s, planned, cand, s_i,
                                            bw_grid, d0, None, None)
            out.append(self._stages234(s, planned, migs))
        return out

    def _stages234(self, state: ClusterState, planned: set,
                   out: List[Action]) -> List[Action]:
        t = state.t
        fc = state.forecast
        soa = state.soa

        st = soa.state
        n_running = soa.count(STATE_RUNNING)
        n_queued = soa.count(STATE_QUEUED)
        green_j = (state.site_renewable[soa.site]
                   if n_running or n_queued else None)
        nws = (fc.next_window_start_grid(t)
               if fc is not None and (n_running or n_queued) else None)

        # ---- stage 2: Pause-for-window (running jobs on grid power)
        if fc is not None and n_running:
            start_j = nws[soa.site]
            pause = ((st == STATE_RUNNING) & ~green_j
                     & (soa.remaining_s >= self.min_pause_compute_s)
                     & (start_j > t) & (start_j <= t + self.pause_horizon_s))
            for k in pause.nonzero()[0]:
                jid = int(soa.jids[k])
                if jid not in planned:
                    out.append(Pause(jid))

        # ---- stage 3: Resume at the (forecast) window start
        if soa.count(STATE_PAUSED):
            paused = (st == STATE_PAUSED).nonzero()[0]
            if fc is None:
                resume = np.ones(len(paused), dtype=bool)
            else:
                # resume when the site turned green, or the window we
                # parked for moved out of reach (no stranding)
                cn = fc.window_open_or_next_start_grid(t)
                resume = (state.site_renewable[soa.site[paused]]
                          | (cn[soa.site[paused]] > t + self.pause_horizon_s))
            for k in paused[resume]:
                out.append(Resume(int(soa.jids[k])))

        # ---- stage 4: Defer queued jobs across the dark span
        if n_queued:
            start_s = nws if fc is not None else state.site_next_window_s
            start_j = start_s[soa.site]
            defer = ((st == STATE_QUEUED) & ~(soa.defer_until_s > t)
                     & ~green_j & (start_j > t)
                     & (start_j <= t + self.max_wait_s))
            for k in defer.nonzero()[0]:
                out.append(Defer(int(soa.jids[k]), float(start_j[k])))
        return out

    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """The per-job reference implementation (parity oracle for
        :meth:`decide`)."""
        t = state.t
        fc = state.forecast
        planned: set = set()
        out: List[Action] = list(self._migrations_scalar(state, planned))

        # ---- stage 2: Pause-for-window (running jobs on grid power)
        if fc is not None:
            for job in state.running():
                if job.jid in planned:
                    continue
                site = state.site(job.site)
                if site.renewable_active:
                    continue
                if job.remaining_compute_s < self.min_pause_compute_s:
                    continue
                start = fc.next_window_start_s(job.site, t)
                if t < start <= t + self.pause_horizon_s:
                    out.append(Pause(job.jid))

        # ---- stage 3: Resume at the (forecast) window start
        for job in state.paused():
            site = state.site(job.site)
            if site.renewable_active:
                out.append(Resume(job.jid))
                continue
            if fc is None:
                out.append(Resume(job.jid))
                continue
            w = fc.next_window(job.site, t)
            if w is None or w.start_s > t + self.pause_horizon_s:
                # the window we parked for moved out of reach — stop waiting
                out.append(Resume(job.jid))

        # ---- stage 4: Defer queued jobs across the dark span
        for job in state.queued():
            if job.held(t):
                continue  # one Defer per (job, window)
            site = state.site(job.site)
            if site.renewable_active:
                continue
            start = (fc.next_window_start_s(job.site, t) if fc is not None
                     else site.next_window_start_s)
            if t < start <= t + self.max_wait_s:
                out.append(Defer(job.jid, start))
        return out


@register_policy("receding-horizon", aliases=("receding", "rh"),
                 config=RecedingHorizonConfig)
@dataclass
class RecedingHorizonPolicy(Policy):
    """Signal-aware receding-horizon planner: every tick, a small
    enumerated *multi-window plan search* per job, scored in forecast
    gCO2 (``state.forecast`` signal stacks) instead of grid-seconds —
    the replacement for plan-ahead's greedy per-tick choice the ROADMAP
    called for.

    For each grid-powered running job the planner enumerates branches:

      * **stay** — run to completion in place; cost = forecast gCO2 of
        the grid portion of ``[t, t + rem]``;
      * **park(k)** — Pause now, resume at the k-th forecast window
        (k < ``plan_windows``, start within ``max_park_s``); cost = gCO2
        of running from the window start plus ``delay_cost_g_per_s`` per
        second of completion delay;
      * **migrate(d)** — Algorithm-1-feasible destinations only, with
        plan-ahead's post-admission arrival check; cost = transfer-leg
        carbon at the source plus the run cost at ``d`` from arrival
        plus the delay penalty.

    The cheapest branch wins (ties keep the earlier-enumerated branch:
    stay, then parks by window order, then destinations by sid) and only
    a ``min_benefit_g`` improvement over *stay* triggers an action —
    re-planned from scratch every tick against the sliding forecast
    (receding horizon), so a plan that stops paying is abandoned, not
    followed.  Paused jobs re-run the same search (Resume when *stay*
    wins or the site turned green — no stranding); queued jobs at dark
    sites Defer to the cheapest of the next ``plan_windows`` windows
    (which may skip a short dirty-tail window for a cleaner later one).
    Finally, running jobs on grid power are Throttled to
    ``dr_power_frac`` while the local carbon signal tops
    ``peak_threshold_g`` — or to the requested cap during an active
    demand-response curtail request — and restored to full power
    otherwise: power and speed scale together, so throttling never
    changes a job's total energy, it *shifts* the draw out of exactly
    the hours the carbon accounting prices highest.

    Degrades gracefully: without signals the cost helpers weight grid
    time at a constant 1 (a grid-seconds minimizer); without a forecast
    it only resumes stranded paused jobs.
    """

    alpha: float = fz.ALPHA
    plan_windows: int = 4
    delay_cost_g_per_s: float = 0.01
    min_benefit_g: float = 60.0
    min_park_compute_s: float = 1800.0
    max_park_s: float = 12 * 3600.0
    max_wait_s: float = 6 * 3600.0
    arrival_margin_s: float = 1800.0
    peak_threshold_g: float = 430.0
    dr_power_frac: float = 0.3
    price_weight_g_per_usd: float = 0.0
    battery_aware: bool = False
    fault_aware: bool = True

    # ---- shared branch-cost helpers (both decide paths call exactly
    # these, so cost floats are identical by construction) -------------------
    def _battery_ctx(self, state: ClusterState):
        """``(per-site SoC kWh, BatteryConfig)`` when battery-aware
        planning is on and the cluster reports storage; ``(None, None)``
        otherwise — the None path threads through every cost helper
        without a single extra float op, so battery-off decisions stay
        bit-identical to the pre-battery planner."""
        if not self.battery_aware or state.battery is None:
            return None, None
        return state.site_battery_soc, state.battery

    def _run_cost_g(self, fc, site: int, t0: float, rem: float,
                    soc=None, batt=None) -> float:
        """gCO2-equivalent of running ``rem`` compute-seconds at ``site``
        from ``t0`` (forecast windows cover their overlap for free;
        with battery context, stored kWh discount the dark portion)."""
        g = fc.grid_carbon_g(site, t0, t0 + rem, fz.P_NODE_KW)
        if self.price_weight_g_per_usd > 0.0:
            g += self.price_weight_g_per_usd * fc.grid_price_usd(
                site, t0, t0 + rem, fz.P_NODE_KW)
        if soc is not None:
            g -= fc.battery_cover_g(site, t0, t0 + rem, fz.P_NODE_KW,
                                    float(soc[site]), batt)
        return g

    def _park_branches(self, fc, site: int, rem: float, t: float,
                       bound_s: float, soc=None, batt=None):
        """``(cost, window_start)`` for waiting at ``site`` for each of
        the next ``plan_windows`` forecast windows starting within
        ``bound_s`` (reveal-gated at the forecast horizon), start-sorted."""
        out = []
        limit = t + min(bound_s, fc.horizon_s)
        for w in fc.site_windows[site]:
            if w.start_s <= t:
                continue
            if w.start_s > limit:
                break
            cost = (self._run_cost_g(fc, site, w.start_s, rem, soc, batt)
                    + self.delay_cost_g_per_s * (w.start_s - t))
            out.append((cost, w.start_s))
            if len(out) >= self.plan_windows:
                break
        return out

    def _should_stay_parked(self, fc, site: int, rem: float,
                            t: float, soc=None, batt=None) -> bool:
        """Re-planned park decision for an already-paused job: keep
        waiting only while some park branch is still *strictly* cheaper
        than resuming now (no margin — the asymmetric hysteresis band
        that stops Pause/Resume flapping)."""
        if rem < self.min_park_compute_s:
            return False
        stay = self._run_cost_g(fc, site, t, rem, soc, batt)
        for cost, _start in self._park_branches(fc, site, rem, t,
                                                self.max_park_s, soc, batt):
            if cost < stay:
                return True
        return False

    def _want_power(self, green: bool, curtail_frac: float,
                    carbon_now: float) -> float:
        """Demand-response power target: full inside windows; the
        operator's cap during an active curtail request; throttled
        through local carbon peaks; full otherwise."""
        if green:
            return 1.0
        if curtail_frac < 1.0:
            return curtail_frac
        if carbon_now >= self.peak_threshold_g:
            return self.dr_power_frac
        return 1.0

    # ---- whole-grid branch-cost tensors (the PR 7 vectorized plan
    # search).  Each helper mirrors its scalar twin op for op — masked
    # lanes evaluate on dummy arguments and are where-masked to inf, so
    # every live lane's float is bit-identical to the scalar call and
    # the branch argmin reproduces the scalar first-strictly-smaller
    # scan (numpy argmin keeps the first occurrence).  ----------------------
    def _run_cost_g_rows(self, fc, sites: np.ndarray, t0s: np.ndarray,
                         rems: np.ndarray, soc=None, batt=None) -> np.ndarray:
        """Elementwise :meth:`_run_cost_g` over broadcastable arrays."""
        g = fc.grid_carbon_g_rows(sites, t0s, t0s + rems, fz.P_NODE_KW)
        if self.price_weight_g_per_usd > 0.0:
            g = g + self.price_weight_g_per_usd * fc.grid_price_usd_rows(
                sites, t0s, t0s + rems, fz.P_NODE_KW)
        if soc is not None:
            g = g - fc.battery_cover_g_rows(
                sites, t0s, t0s + rems, fz.P_NODE_KW, soc[sites], batt)
        return g

    def _park_cost_rows(self, fc, sites: np.ndarray, rems: np.ndarray,
                        t: float, bound_s: float, soc=None, batt=None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """All rows' :meth:`_park_branches` as ``(m, Kw)`` cost / start
        tensors (inf on lanes the scalar would not enumerate: windows
        already open, past the bound, or beyond ``plan_windows``)."""
        starts, _ = fc._window_mats
        ws = starts[sites]  # (m, Kw), +inf padded, start-sorted
        limit = t + min(bound_s, fc.horizon_s)
        elig = (ws > t) & (ws <= limit)
        take = elig & (np.cumsum(elig, axis=1) <= self.plan_windows)
        st = np.where(take, ws, t)
        cost = (self._run_cost_g_rows(fc, sites[:, None], st, rems[:, None],
                                      soc, batt)
                + self.delay_cost_g_per_s * (st - t))
        return (np.where(take, cost, np.inf),
                np.where(take, ws, np.inf))

    def _plan_grid(self, state: ClusterState, fc, cand: np.ndarray,
                   s_i: np.ndarray, ok: np.ndarray, flows: list,
                   reserved: Dict[int, int], soc=None,
                   batt=None) -> List[Action]:
        """Stage 1 as one ``(jobs × branches)`` cost tensor: columns are
        [parks in window order, migrates by sid] — the scalar
        enumeration order, so first-occurrence argmin ≡ the scalar
        strict-< scan.  The tensor assumes the tick's *initial*
        ``flows``/``reserved``; a committed migration invalidates that
        for later rows, so the remaining rows fall back to the scalar
        :meth:`_plan_one` (Pause commits mutate nothing and keep the
        grid valid)."""
        t = state.t
        soa = state.soa
        m = len(cand)
        n = state.n_sites
        rem = soa.remaining_s[cand]
        ckpt = soa.ckpt_bytes[cand]
        W = state.site_window_s
        free = state.site_free_slots
        t_row = np.full(m, t)
        stay = self._run_cost_g_rows(fc, s_i, t_row, rem, soc, batt)

        pcost, _ = self._park_cost_rows(fc, s_i, rem, t, self.max_park_s,
                                        soc, batt)
        pcost = np.where(rem[:, None] >= self.min_park_compute_s,
                         pcost, np.inf)
        kw = pcost.shape[1]

        # migrate branches: the scalar's sequential gates as one mask
        rate = np.empty((m, n))
        rate_rows: Dict[int, np.ndarray] = {}
        for r in range(m):
            src = int(s_i[r])
            row = rate_rows.get(src)
            if row is None:
                row = rate_rows[src] = np.array([
                    state.post_admission_bps(src, d, flows)
                    for d in range(n)])
            rate[r] = row
        feas = (ok & (np.arange(n)[None, :] != s_i[:, None])
                & (free[None, :] > 0) & (rate > 0.0))
        t_arr = t + 8.0 * ckpt[:, None] / np.where(feas, rate, 1.0)
        feas &= ~(t_arr + self.arrival_margin_s > t + W[None, :])
        nxt = fc.next_outage_start_after_grid(t)[s_i, :]
        if self.fault_aware:
            fg = fc.next_fault_start_grid(t)
            if fg is not None:
                nxt = np.minimum(nxt, fg[s_i, :])
        feas &= ~(nxt < t_arr)
        ta = np.where(feas, t_arr, t)
        s_rep = np.broadcast_to(s_i[:, None], (m, n))
        t_rep = np.broadcast_to(t_row[:, None], (m, n))
        transfer = fz.P_SYS_KW / 3600.0 * fc.carbon_integral_rows(
            s_rep, t_rep, ta)
        if self.price_weight_g_per_usd > 0.0:
            transfer = transfer + (self.price_weight_g_per_usd
                                   * fz.P_SYS_KW / 3600.0
                                   * fc.price_integral_rows(s_rep, t_rep, ta))
        d_rep = np.broadcast_to(np.arange(n)[None, :], (m, n))
        mcost = ((transfer + self._run_cost_g_rows(fc, d_rep, ta,
                                                   rem[:, None], soc, batt))
                 + self.delay_cost_g_per_s * (ta - t))
        mcost = np.where(feas, mcost, np.inf)

        costs = np.concatenate([pcost, mcost], axis=1)
        k = np.argmin(costs, axis=1)
        bc = costs[np.arange(m), k]
        act = bc < stay - self.min_benefit_g  # inf lanes never pass

        out: List[Action] = []
        fallback = False
        for r, i in enumerate(cand):
            jid = int(soa.jids[i])
            if fallback:
                a = self._plan_one(
                    state, fc, jid, int(s_i[r]), float(ckpt[r]),
                    float(rem[r]), ok[r], W, free, flows, reserved,
                    soc, batt)
                if a is not None:
                    out.append(a)
                continue
            if not act[r]:
                continue
            if k[r] < kw:
                out.append(Pause(jid))
            else:
                d = int(k[r] - kw)
                out.append(Migrate(jid, d))
                flows.append((int(s_i[r]), d))
                reserved[d] += 1
                fallback = True
        return out

    def _plan_one(self, state: ClusterState, fc, jid: int, site: int,
                  ckpt_bytes: float, rem: float, ok_row, window_s,
                  free_slots, flows, reserved, soc=None,
                  batt=None) -> Optional[Action]:
        """The per-candidate plan search (stage 1).  ``ok_row`` is the
        job's Algorithm-1 feasibility row; ``window_s``/``free_slots``
        are per-site arrays.  Returns the winning first action (or None
        for *stay*) and updates ``flows``/``reserved`` on a commit."""
        t = state.t
        stay = self._run_cost_g(fc, site, t, rem, soc, batt)
        best_cost = float("inf")
        best: Optional[Tuple] = None
        if rem >= self.min_park_compute_s:
            for cost, _start in self._park_branches(fc, site, rem, t,
                                                    self.max_park_s,
                                                    soc, batt):
                if cost < best_cost:
                    best_cost, best = cost, ("pause",)
        for d in range(state.n_sites):
            if d == site or not ok_row[d]:
                continue
            if free_slots[d] - reserved[d] <= 0:
                continue
            rate = state.post_admission_bps(site, d, flows)
            if rate <= 0.0:
                continue
            t_arr = t + 8.0 * ckpt_bytes / rate
            # plan-ahead's arrival checks: land inside the destination
            # window with margin, before any forecast outage on the link
            if t_arr + self.arrival_margin_s > t + float(window_s[d]):
                continue
            nxt = fc.next_outage_start_after(site, d, t)
            if self.fault_aware:
                nxt = min(nxt, fc.next_fault_start_after(site, d, t))
            if nxt < t_arr:
                continue
            transfer_g = fz.P_SYS_KW / 3600.0 * fc.carbon_integral(
                site, t, t_arr)
            if self.price_weight_g_per_usd > 0.0:
                # the $ the simulator will bill for the transfer leg — the
                # same weighting _run_cost_g applies to the run legs
                transfer_g += (self.price_weight_g_per_usd
                               * fz.P_SYS_KW / 3600.0
                               * fc.price_integral(site, t, t_arr))
            cost = (transfer_g
                    + self._run_cost_g(fc, d, t_arr, rem, soc, batt)
                    + self.delay_cost_g_per_s * (t_arr - t))
            if cost < best_cost:
                best_cost, best = cost, ("migrate", d)
        if best is None or not best_cost < stay - self.min_benefit_g:
            return None
        if best[0] == "pause":
            return Pause(jid)
        d = best[1]
        flows.append((site, d))
        reserved[d] += 1
        return Migrate(jid, d)

    # ---- vectorized decide -------------------------------------------------
    def decide(self, state: ClusterState) -> List[Action]:
        """SoA fast path (emits exactly :meth:`decide_scalar`'s Action
        list): candidate masks, feasibility and the demand-response
        power targets are whole-grid numpy passes; the K-branch plan
        search runs per surviving candidate through the shared cost
        helpers (few candidates pass the masks on a typical tick)."""
        t = state.t
        fc = state.forecast
        soa = state.soa
        st = soa.state
        out: List[Action] = []
        acted: set = set()
        m = len(soa)
        if m == 0:
            return out
        green_j = state.site_renewable[soa.site]
        soc, batt = self._battery_ctx(state)

        # ---- stage 1: plan search for grid-powered running jobs
        if fc is not None and soa.count(STATE_RUNNING):
            cand = ((st == STATE_RUNNING) & soa.eligible
                    & ~green_j).nonzero()[0]
            if len(cand):
                s_i = soa.site[cand]
                bw = state.bandwidth_bps[s_i, :]
                if self.fault_aware:
                    lu = state.__dict__.get("link_up")
                    if lu is not None:
                        # dead links (hard failure / blacked-out endpoint)
                        # plan at rate 0 — infeasible like a dark brownout
                        bw = np.where(lu[s_i, :], bw, 0.0)
                ok, _tt = feasibility_grid_arrays(
                    soa.ckpt_bytes[cand][:, None],
                    soa.t_load_s[cand][:, None],
                    bw,
                    state.site_window_s[None, :], alpha=self.alpha)
                flows = list(state.transfers)
                reserved = {s: 0 for s in range(state.n_sites)}
                for act in self._plan_grid(state, fc, cand, s_i, ok,
                                           flows, reserved, soc, batt):
                    out.append(act)
                    acted.add(act.jid)

        # ---- stage 2: paused jobs — resume, or keep waiting (re-planned)
        if soa.count(STATE_PAUSED):
            paused = (st == STATE_PAUSED).nonzero()[0]
            if fc is None:
                resume = np.ones(len(paused), dtype=bool)
            else:
                # batched _should_stay_parked: keep waiting only while
                # some park branch is still strictly cheaper than
                # resuming now (same no-margin hysteresis)
                sites_p = soa.site[paused]
                rem_p = soa.remaining_s[paused]
                stay_p = self._run_cost_g_rows(
                    fc, sites_p, np.full(len(paused), t), rem_p, soc, batt)
                pcost, _ = self._park_cost_rows(fc, sites_p, rem_p, t,
                                                self.max_park_s, soc, batt)
                keep = ((rem_p >= self.min_park_compute_s)
                        & (pcost < stay_p[:, None]).any(axis=1))
                resume = green_j[paused] | ~keep
            for i, r in zip(paused, resume):
                if r:
                    out.append(Resume(int(soa.jids[i])))

        # ---- stage 3: queued jobs — Defer to the cheapest nearby window
        if fc is not None and soa.count(STATE_QUEUED):
            queued = ((st == STATE_QUEUED) & ~(soa.defer_until_s > t)
                      & ~green_j).nonzero()[0]
            if len(queued):
                sites_q = soa.site[queued]
                rem_q = soa.remaining_s[queued]
                stay_q = self._run_cost_g_rows(
                    fc, sites_q, np.full(len(queued), t), rem_q, soc, batt)
                pcost, pstart = self._park_cost_rows(fc, sites_q, rem_q, t,
                                                     self.max_wait_s,
                                                     soc, batt)
                kq = np.argmin(pcost, axis=1)
                rr = np.arange(len(queued))
                bc, bs = pcost[rr, kq], pstart[rr, kq]
                go = np.isfinite(bs) & (bc < stay_q - self.min_benefit_g)
                for i, g, s0 in zip(queued, go, bs):
                    if g:
                        out.append(Defer(int(soa.jids[i]), float(s0)))

        # ---- stage 4: demand response — throttle through peaks/DR spans
        if soa.count(STATE_RUNNING):
            if fc is None:
                carb = np.zeros(state.n_sites)
                cfrac = np.ones(state.n_sites)
            else:
                carb = fc.carbon_grid(t)
                cfrac = fc.curtail_frac_grid(t)
            green_s = state.site_renewable
            # one _want_power per site (n_sites is small), not a numpy
            # re-implementation — a single copy of the target logic is
            # what keeps the two decide paths in lockstep by construction
            want_site = np.array([
                self._want_power(bool(green_s[s]), float(cfrac[s]),
                                 float(carb[s]))
                for s in range(state.n_sites)])
            want_j = want_site[soa.site]
            mask = ((st == STATE_RUNNING)
                    & (np.abs(soa.power_frac - want_j) > 1e-9))
            for i in mask.nonzero()[0]:
                jid = int(soa.jids[i])
                if jid not in acted:
                    out.append(Throttle(jid, float(want_j[i])))
        return out

    # ---- scalar oracle -----------------------------------------------------
    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """The per-job reference implementation (parity oracle for
        :meth:`decide`)."""
        t = state.t
        fc = state.forecast
        out: List[Action] = []
        acted: set = set()
        soc, batt = self._battery_ctx(state)

        # ---- stage 1: plan search for grid-powered running jobs
        if fc is not None:
            cands = [j for j in state.migratable()
                     if not state.site(j.site).renewable_active]
            if cands:
                bw = None
                if self.fault_aware:
                    lu = state.__dict__.get("link_up")
                    if lu is not None:
                        s_c = np.array([j.site for j in cands],
                                       dtype=np.int64)
                        bw = np.where(
                            lu[s_c, :],
                            np.asarray(state.bandwidth_bps)[s_c, :], 0.0)
                ok_grid, _tt = algorithm1_grid(state, cands,
                                               alpha=self.alpha, bw_grid=bw)
                window_s = [s.window_remaining_s for s in state.sites]
                free_slots = [s.free_slots for s in state.sites]
                flows = list(state.transfers)
                reserved = {s.sid: 0 for s in state.sites}
                for i, job in enumerate(cands):
                    act = self._plan_one(
                        state, fc, job.jid, job.site, job.ckpt_bytes,
                        job.remaining_compute_s, ok_grid[i], window_s,
                        free_slots, flows, reserved, soc, batt)
                    if act is not None:
                        out.append(act)
                        acted.add(act.jid)

        # ---- stage 2: paused jobs — resume, or keep waiting (re-planned)
        for job in state.paused():
            green = state.site(job.site).renewable_active
            if green or fc is None or not self._should_stay_parked(
                    fc, job.site, job.remaining_compute_s, t, soc, batt):
                out.append(Resume(job.jid))

        # ---- stage 3: queued jobs — Defer to the cheapest nearby window
        if fc is not None:
            for job in state.queued():
                if job.held(t):
                    continue
                if state.site(job.site).renewable_active:
                    continue
                rem = job.remaining_compute_s
                stay = self._run_cost_g(fc, job.site, t, rem, soc, batt)
                best_cost, best_start = float("inf"), None
                for cost, start in self._park_branches(fc, job.site, rem, t,
                                                       self.max_wait_s,
                                                       soc, batt):
                    if cost < best_cost:
                        best_cost, best_start = cost, start
                if best_start is not None and \
                        best_cost < stay - self.min_benefit_g:
                    out.append(Defer(job.jid, best_start))

        # ---- stage 4: demand response — throttle through peaks/DR spans
        for job in state.running():
            if job.jid in acted:
                continue
            green = state.site(job.site).renewable_active
            if fc is None:
                cfrac, carbon = 1.0, 0.0
            else:
                c = fc.active_curtail(job.site, t)
                cfrac = c.power_frac if c is not None else 1.0
                carbon = fc.carbon_value(job.site, t)
            want = self._want_power(green, cfrac, carbon)
            if abs(job.power_frac - want) > 1e-9:
                out.append(Throttle(job.jid, want))
        return out


@register_policy("defer-to-window", config=DeferConfig)
@dataclass
class DeferToWindowPolicy(Policy):
    """Beyond-paper: hold queued jobs at dark sites until the site's next
    forecast window start (bounded by ``max_wait_s``), so they begin on
    renewable power.  Exercises the ``Defer`` action."""

    max_wait_s: float = 4 * 3600.0

    def decide(self, state: ClusterState) -> List[Action]:
        t = state.t
        soa = state.soa
        if soa.count(STATE_QUEUED) == 0:
            return []
        start = state.site_next_window_s[soa.site]
        # held jobs (defer_until_s still in the future) are skipped —
        # re-issuing Defer every tick is pure action noise (one Defer per
        # (job, window); a job resurfaces here when the hold expires)
        mask = ((soa.state == STATE_QUEUED) & ~(soa.defer_until_s > t)
                & ~state.site_renewable[soa.site]
                & (start > t) & (start <= t + self.max_wait_s))
        return [Defer(int(j), float(s))
                for j, s in zip(soa.jids[mask], start[mask])]

    def decide_scalar(self, state: ClusterState) -> List[Action]:
        """Per-job reference implementation (parity oracle)."""
        out: List[Action] = []
        for job in state.queued():
            if job.held(state.t):
                continue
            site = state.site(job.site)
            if site.renewable_active:
                continue
            start = site.next_window_start_s
            if state.t < start <= state.t + self.max_wait_s:
                out.append(Defer(job.jid, start))
        return out


__all__ = [
    "Action", "ClusterState", "DeferConfig", "DeferToWindowPolicy",
    "EnergyOnlyPolicy", "FeasibilityAwarePolicy", "FeasibilityConfig",
    "GridThrottlePolicy", "JobView", "OraclePolicy", "OrchestratorContext",
    "PlanAheadConfig", "PlanAheadPolicy", "Policy", "PolicyConfig",
    "RecedingHorizonConfig", "RecedingHorizonPolicy", "SiteView",
    "StaticPolicy", "ThrottleConfig", "available_policies",
    "benefit_grid_arrays", "feasibility_grid_arrays", "make_policy",
    "pick_best_grid", "policy_config_cls", "register_policy",
]
