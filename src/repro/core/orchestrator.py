"""Migration policies, including the paper's feasibility-aware scheduler
(Algorithm 1).

All policies share one interface: ``decide(ctx) -> [(job_id, dest_site)]``
evaluated at every orchestrator tick (Δt).  The simulator provides the
context: running jobs (with *measured* checkpoint sizes), per-site
renewable forecasts, effective inter-site bandwidths, and site load.

  Static            never migrates (Table VI row 1)
  EnergyOnly        chases renewable windows, no feasibility filter (row 2)
  FeasibilityAware  Algorithm 1: hard feasibility filter, then utility
                    maximization within the feasible set (row 3)
  Oracle            FeasibilityAware with σ=0 forecasts (Table VIII row 4)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import feasibility as fz


@dataclass
class JobView:
    jid: int
    site: int
    ckpt_bytes: float
    remaining_compute_s: float
    t_load_s: float = fz.T_LOAD_S


@dataclass
class SiteView:
    sid: int
    slots: int
    busy: int  # running jobs
    queued: int
    renewable_active: bool
    window_remaining_s: float  # forecast
    incoming: int = 0  # in-flight migrations committed to this site

    @property
    def load(self) -> float:
        return (self.busy + self.queued + self.incoming) / max(self.slots, 1)

    @property
    def free_slots(self) -> int:
        return max(0, self.slots - self.busy - self.incoming)


@dataclass
class OrchestratorContext:
    t: float
    jobs: List[JobView]
    sites: List[SiteView]
    bandwidth_bps: np.ndarray  # (n_sites, n_sites) effective measured WAN bw

    def site(self, sid: int) -> SiteView:
        return self.sites[sid]


Decision = Tuple[int, int]  # (job_id, destination site)


class Policy:
    name = "base"

    def decide(self, ctx: OrchestratorContext) -> List[Decision]:
        raise NotImplementedError


class StaticPolicy(Policy):
    """Fixed placement, no inter-site coordination (§VII.E baseline 1)."""

    name = "static"

    def decide(self, ctx: OrchestratorContext) -> List[Decision]:
        return []


class EnergyOnlyPolicy(Policy):
    """Migrate whenever renewable energy is available elsewhere, without
    feasibility constraints (§VII.E baseline 2). Herds onto the greenest
    site; initiates transfers that cannot finish inside windows."""

    name = "energy-only"

    def decide(self, ctx: OrchestratorContext) -> List[Decision]:
        out: List[Decision] = []
        for job in ctx.jobs:
            cur = ctx.site(job.site)
            if cur.renewable_active:
                continue  # already green
            greens = [
                s for s in ctx.sites
                if s.renewable_active and s.sid != job.site
                and (s.slots - s.busy) > 0  # STALE capacity: ignores in-flight
            ]
            if not greens:
                continue
            # spread over whatever is green right now (hash placement), with
            # only a stale capacity check and NO feasibility filter (§VII.E:
            # 'lacks awareness of transfer-time or energy-cost limits'):
            # transfers near window end, Class C checkpoints and transient
            # over-subscription all happen.
            dest = greens[job.jid % len(greens)]
            out.append((job.jid, dest.sid))
        return out


@dataclass
class FeasibilityAwarePolicy(Policy):
    """Paper Algorithm 1 (§V.B).

    Stage 1 — strict feasibility filter per (job, destination):
        T_cost = T_transfer + T_load + 0.4 s
        reject if T_cost > α · window(d)            (time)
        reject if T_breakeven > window(d)           (energy)
        reject if class(w) == C                     (§VI.D)
    Stage 2 — optimization inside the feasible set:
        benefit(d) = expected grid-seconds avoided − queue penalty
        migrate to argmax benefit iff benefit > T_cost, ties by T_transfer.
    """

    name = "feasibility-aware"
    alpha: float = fz.ALPHA
    gamma: float = 1.0  # renewable weight (benefit term)
    beta: float = 1.0  # congestion weight
    queue_penalty_s: float = 7200.0  # expected wait per unit load
    min_benefit_s: float = 1500.0  # hysteresis: don't move for marginal wins
    eps: float = 0.0  # >0 enables stochastic feasibility (§VI.H)
    forecast_sigma_s: float = 0.0

    def decide(self, ctx: OrchestratorContext) -> List[Decision]:
        out: List[Decision] = []
        # Track slot reservations within this tick so we do not herd.
        reserved: Dict[int, int] = {s.sid: 0 for s in ctx.sites}
        for job in ctx.jobs:
            cur = ctx.site(job.site)
            best: Optional[Tuple[float, float, int]] = None  # (-benefit, t_transfer, sid)
            for dest in ctx.sites:
                if dest.sid == job.site:
                    continue
                bw = float(ctx.bandwidth_bps[job.site, dest.sid])
                window = dest.window_remaining_s
                # ---- Stage 1: feasibility filter ----
                if self.eps > 0.0 and self.forecast_sigma_s > 0.0:
                    ok = bool(
                        fz.stochastic_feasible(
                            job.ckpt_bytes, bw, window, self.forecast_sigma_s,
                            eps=self.eps, alpha=self.alpha, t_load_s=job.t_load_s,
                        )
                    )
                    v = fz.evaluate(job.ckpt_bytes, bw, window, alpha=self.alpha,
                                    t_load_s=job.t_load_s)
                    ok = ok and bool(v.energy_ok) and int(v.workload_class) != 2
                else:
                    v = fz.evaluate(job.ckpt_bytes, bw, window, alpha=self.alpha,
                                    t_load_s=job.t_load_s)
                    ok = bool(v.feasible)
                if not ok:
                    continue
                t_transfer = float(fz.transfer_time_s(job.ckpt_bytes, bw))
                t_cost = t_transfer + job.t_load_s + fz.T_DOWNTIME_S
                # ---- Stage 2: benefit inside the feasible set ----
                cur_green_s = cur.window_remaining_s if cur.renewable_active else 0.0
                dest_green_s = min(window, job.remaining_compute_s)
                grid_seconds_avoided = max(0.0, dest_green_s - min(cur_green_s, job.remaining_compute_s))
                dest_load = (dest.busy + dest.queued + reserved[dest.sid]) / max(dest.slots, 1)
                # symmetric congestion term: moving toward a less-loaded site
                # is itself a benefit (contention-aware placement, §V.D.2)
                benefit = (
                    self.gamma * grid_seconds_avoided
                    - self.beta * self.queue_penalty_s * (dest_load - cur.load)
                )
                if dest.free_slots - reserved[dest.sid] <= 0:
                    benefit -= self.queue_penalty_s  # would have to queue
                if benefit <= max(t_cost, self.min_benefit_s):
                    continue
                key = (-benefit, t_transfer, dest.sid)
                if best is None or key < best:
                    best = key
            if best is not None:
                out.append((job.jid, best[2]))
                reserved[best[2]] += 1
        return out


def make_policy(name: str, **kw) -> Policy:
    name = name.lower()
    if name == "static":
        return StaticPolicy()
    if name in ("energy-only", "energy_only", "energyonly"):
        return EnergyOnlyPolicy()
    if name in ("feasibility-aware", "feasibility", "ours"):
        return FeasibilityAwarePolicy(**kw)
    if name == "oracle":
        p = FeasibilityAwarePolicy(**kw)
        p.name = "oracle"
        return p
    raise KeyError(name)
