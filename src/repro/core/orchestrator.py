"""Migration policies, including the paper's feasibility-aware scheduler
(Algorithm 1), behind a typed event-driven control API.

Contract: ``Policy.decide(state: ClusterState) -> list[Action]`` evaluated
at every orchestrator tick (Δt).  The :class:`~repro.core.state.ClusterState`
snapshot carries live jobs (with *measured* checkpoint sizes), per-site
renewable forecasts, the advertised WAN bandwidth matrix (per-NIC fair
share), and site load; actions are the typed verbs of
:mod:`repro.core.actions` (``Migrate``/``Defer``/``Pause``/``Resume``/
``Throttle``).

Policies live in a registry: decorate a class with
``@register_policy("name", aliases=(...), config=SomePolicyConfig)`` and it
becomes constructible via ``make_policy(name, config=..., **overrides)`` and
usable from ``run_policy_comparison``, benchmarks and examples.  Structured
``PolicyConfig`` dataclasses carry per-policy knobs (e.g. stochastic
feasibility ``eps``/``forecast_sigma_s``) through every entry point.

Built-ins:

  static            never migrates (Table VI row 1)
  energy-only       chases renewable windows, no feasibility filter (row 2)
  feasibility-aware Algorithm 1: hard feasibility filter, then utility
                    maximization within the feasible set (row 3)
  oracle            feasibility-aware with σ=0 forecasts (Table VIII row 4)
  grid-throttle     beyond-paper demand response: Throttle jobs on grid
                    power, restore full power inside renewable windows
  defer-to-window   beyond-paper: Defer queued jobs at dark sites until the
                    site's next forecast window start
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Type

from repro.core import feasibility as fz
from repro.core.actions import Action, Defer, Migrate, Pause, Resume, Throttle
from repro.core.state import ClusterState, JobView, SiteView

# Backwards-looking alias: the pre-redesign name for the snapshot type.
OrchestratorContext = ClusterState


# ---------------------------------------------------------------------------
# Policy configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicyConfig:
    """Base for structured per-policy parameters (empty for static/energy)."""


@dataclass(frozen=True)
class FeasibilityConfig(PolicyConfig):
    """Algorithm 1 knobs (§V.B, §VI.H)."""

    alpha: float = fz.ALPHA
    gamma: float = 1.0  # renewable weight (benefit term)
    beta: float = 1.0  # congestion weight
    queue_penalty_s: float = 7200.0  # expected wait per unit load
    min_benefit_s: float = 1500.0  # hysteresis: don't move for marginal wins
    eps: float = 0.0  # >0 enables stochastic feasibility (§VI.H)
    forecast_sigma_s: float = 0.0


@dataclass(frozen=True)
class ThrottleConfig(PolicyConfig):
    power_frac: float = 0.5  # demand-response level on grid power


@dataclass(frozen=True)
class DeferConfig(PolicyConfig):
    max_wait_s: float = 4 * 3600.0  # never hold a queued job longer than this


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["Policy"]] = {}
_ALIASES: Dict[str, str] = {}
_CONFIGS: Dict[str, Type[PolicyConfig]] = {}


def register_policy(name: str, *, aliases: Tuple[str, ...] = (),
                    config: Type[PolicyConfig] = PolicyConfig):
    """Class decorator: add a Policy to the registry under ``name``
    (stored normalized — lowercase, dashes — so lookups always hit)."""

    key = _norm(name)

    def deco(cls: Type["Policy"]) -> Type["Policy"]:
        cls.name = key
        _REGISTRY[key] = cls
        _CONFIGS[key] = config
        for a in aliases:
            _ALIASES[_norm(a)] = key
        return cls

    return deco


def _norm(name: str) -> str:
    return name.lower().replace("_", "-")


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


def policy_config_cls(name: str) -> Type[PolicyConfig]:
    return _CONFIGS[_resolve(name)]


def _resolve(name: str) -> str:
    key = _norm(name)
    key = _ALIASES.get(key, key)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; available: {', '.join(available_policies())}"
        )
    return key


def make_policy(name: str, config: Optional[PolicyConfig] = None, **kw) -> "Policy":
    """Instantiate a registered policy.

    ``config`` is a :class:`PolicyConfig` matching the policy (its fields are
    splatted into the constructor); ``**kw`` overrides individual fields.
    """
    key = _resolve(name)
    if config is not None:
        kw = {**dataclasses.asdict(config), **kw}
    return _REGISTRY[key](**kw)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class Policy:
    name = "base"

    def decide(self, state: ClusterState) -> List[Action]:
        raise NotImplementedError

    # Comparison harnesses use this instead of string-matching on the name.
    wants_oracle_forecast = False


@register_policy("static")
class StaticPolicy(Policy):
    """Fixed placement, no inter-site coordination (§VII.E baseline 1)."""

    def decide(self, state: ClusterState) -> List[Action]:
        return []


@register_policy("energy-only", aliases=("energyonly",))
class EnergyOnlyPolicy(Policy):
    """Migrate whenever renewable energy is available elsewhere, without
    feasibility constraints (§VII.E baseline 2). Herds onto the greenest
    site; initiates transfers that cannot finish inside windows."""

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.migratable():
            cur = state.site(job.site)
            if cur.renewable_active:
                continue  # already green
            greens = [
                s for s in state.sites
                if s.renewable_active and s.sid != job.site
                and (s.slots - s.busy) > 0  # STALE capacity: ignores in-flight
            ]
            if not greens:
                continue
            # spread over whatever is green right now (hash placement), with
            # only a stale capacity check and NO feasibility filter (§VII.E:
            # 'lacks awareness of transfer-time or energy-cost limits'):
            # transfers near window end, Class C checkpoints and transient
            # over-subscription all happen.
            dest = greens[job.jid % len(greens)]
            out.append(Migrate(job.jid, dest.sid))
        return out


@register_policy("feasibility-aware", aliases=("feasibility", "ours"),
                 config=FeasibilityConfig)
@dataclass
class FeasibilityAwarePolicy(Policy):
    """Paper Algorithm 1 (§V.B).

    Stage 1 — strict feasibility filter per (job, destination):
        T_cost = T_transfer + T_load + 0.4 s
        reject if T_cost > α · window(d)            (time)
        reject if T_breakeven > window(d)           (energy)
        reject if class(w) == C                     (§VI.D)
    Stage 2 — optimization inside the feasible set:
        benefit(d) = expected grid-seconds avoided − queue penalty
        migrate to argmax benefit iff benefit > T_cost, ties by T_transfer.
    """

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    eps: float = 0.0
    forecast_sigma_s: float = 0.0

    def decide(self, state: ClusterState) -> List[Action]:
        import numpy as np

        candidates = state.migratable()
        if not candidates:
            return []
        # ---- Stage 1, vectorized: one feasibility evaluation over the whole
        # (job × destination) grid per tick, using the snapshot's advertised
        # bandwidth matrix (per-NIC fair share).
        sizes = np.array([j.ckpt_bytes for j in candidates])[:, None]
        t_loads = np.array([j.t_load_s for j in candidates])[:, None]
        bw_grid = np.asarray(state.bandwidth_bps)[
            np.array([j.site for j in candidates], dtype=np.int64), :
        ]  # (n_jobs, n_sites)
        windows = state.site_window_s[None, :]
        v = fz.evaluate(sizes, bw_grid, windows, alpha=self.alpha,
                        t_load_s=t_loads)
        if self.eps > 0.0 and self.forecast_sigma_s > 0.0:
            ok_grid = (
                np.asarray(
                    fz.stochastic_feasible(
                        sizes, bw_grid, windows, self.forecast_sigma_s,
                        eps=self.eps, alpha=self.alpha, t_load_s=t_loads,
                    )
                )
                & np.asarray(v.energy_ok)
                & (np.asarray(v.workload_class) != 2)
            )
        else:
            ok_grid = np.asarray(v.feasible)
        t_transfer_grid = np.asarray(v.t_transfer_s)

        out: List[Action] = []
        # Track slot reservations within this tick so we do not herd.
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        for i, job in enumerate(candidates):
            cur = state.site(job.site)
            best: Optional[Tuple[float, float, int]] = None  # (-benefit, t_transfer, sid)
            for dest in state.sites:
                if dest.sid == job.site:
                    continue
                if not ok_grid[i, dest.sid]:
                    continue
                window = dest.window_remaining_s
                t_transfer = float(t_transfer_grid[i, dest.sid])
                t_cost = t_transfer + job.t_load_s + fz.T_DOWNTIME_S
                # ---- Stage 2: benefit inside the feasible set ----
                cur_green_s = cur.window_remaining_s if cur.renewable_active else 0.0
                dest_green_s = min(window, job.remaining_compute_s)
                grid_seconds_avoided = max(0.0, dest_green_s - min(cur_green_s, job.remaining_compute_s))
                dest_load = (dest.busy + dest.queued + reserved[dest.sid]) / max(dest.slots, 1)
                # symmetric congestion term: moving toward a less-loaded site
                # is itself a benefit (contention-aware placement, §V.D.2)
                benefit = (
                    self.gamma * grid_seconds_avoided
                    - self.beta * self.queue_penalty_s * (dest_load - cur.load)
                )
                if dest.free_slots - reserved[dest.sid] <= 0:
                    benefit -= self.queue_penalty_s  # would have to queue
                if benefit <= max(t_cost, self.min_benefit_s):
                    continue
                key = (-benefit, t_transfer, dest.sid)
                if best is None or key < best:
                    best = key
            if best is not None:
                out.append(Migrate(job.jid, best[2]))
                reserved[best[2]] += 1
        return out


@register_policy("oracle", config=FeasibilityConfig)
@dataclass
class OraclePolicy(FeasibilityAwarePolicy):
    """Feasibility-aware under perfect (σ=0) forecasts (Table VIII row 4).
    The zero-noise forecaster is selected by the harness via
    ``wants_oracle_forecast``."""

    wants_oracle_forecast = True


@register_policy("grid-throttle", config=ThrottleConfig)
@dataclass
class GridThrottlePolicy(Policy):
    """Beyond-paper demand response: run at reduced power whenever a site is
    on grid electricity, full power inside renewable windows.  Exercises the
    ``Throttle`` action; never migrates."""

    power_frac: float = 0.5

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.running():
            green = state.site(job.site).renewable_active
            want = 1.0 if green else self.power_frac
            if abs(job.power_frac - want) > 1e-9:
                out.append(Throttle(job.jid, want))
        return out


@register_policy("defer-to-window", config=DeferConfig)
@dataclass
class DeferToWindowPolicy(Policy):
    """Beyond-paper: hold queued jobs at dark sites until the site's next
    forecast window start (bounded by ``max_wait_s``), so they begin on
    renewable power.  Exercises the ``Defer`` action."""

    max_wait_s: float = 4 * 3600.0

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.queued():
            site = state.site(job.site)
            if site.renewable_active:
                continue
            start = site.next_window_start_s
            if state.t < start <= state.t + self.max_wait_s:
                out.append(Defer(job.jid, start))
        return out


__all__ = [
    "Action", "ClusterState", "DeferConfig", "DeferToWindowPolicy",
    "EnergyOnlyPolicy", "FeasibilityAwarePolicy", "FeasibilityConfig",
    "GridThrottlePolicy", "JobView", "OraclePolicy", "OrchestratorContext",
    "Policy", "PolicyConfig", "SiteView", "StaticPolicy", "ThrottleConfig",
    "available_policies", "make_policy", "policy_config_cls",
    "register_policy",
]
