"""Migration policies, including the paper's feasibility-aware scheduler
(Algorithm 1), behind a typed event-driven control API.

Contract: ``Policy.decide(state: ClusterState) -> list[Action]`` evaluated
at every orchestrator tick (Δt).  The :class:`~repro.core.state.ClusterState`
snapshot carries live jobs (with *measured* checkpoint sizes), per-site
renewable forecasts, the advertised WAN bandwidth matrix (per-NIC fair
share), and site load; actions are the typed verbs of
:mod:`repro.core.actions` (``Migrate``/``Defer``/``Pause``/``Resume``/
``Throttle``).

Policies live in a registry: decorate a class with
``@register_policy("name", aliases=(...), config=SomePolicyConfig)`` and it
becomes constructible via ``make_policy(name, config=..., **overrides)`` and
usable from ``run_policy_comparison``, benchmarks and examples.  Structured
``PolicyConfig`` dataclasses carry per-policy knobs (e.g. stochastic
feasibility ``eps``/``forecast_sigma_s``) through every entry point.

Built-ins:

  static            never migrates (Table VI row 1)
  energy-only       chases renewable windows, no feasibility filter (row 2)
  feasibility-aware Algorithm 1: hard feasibility filter, then utility
                    maximization within the feasible set (row 3)
  oracle            feasibility-aware with σ=0 forecasts (Table VIII row 4)
  grid-throttle     beyond-paper demand response: Throttle jobs on grid
                    power, restore full power inside renewable windows
  defer-to-window   beyond-paper: Defer queued jobs at dark sites until the
                    site's next forecast window start
  plan-ahead        beyond-paper: multi-step plans over ``state.forecast``
                    — Algorithm 1 hardened against forecast link outages,
                    Pause-for-window sequences, pre-emptive evacuation
                    ahead of uplink brownouts, horizon-bounded Defer
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Type

from repro.core import feasibility as fz
from repro.core.actions import Action, Defer, Migrate, Pause, Resume, Throttle
from repro.core.state import ClusterState, JobView, SiteView

# Backwards-looking alias: the pre-redesign name for the snapshot type.
OrchestratorContext = ClusterState


# ---------------------------------------------------------------------------
# Policy configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PolicyConfig:
    """Base for structured per-policy parameters (empty for static/energy)."""


@dataclass(frozen=True)
class FeasibilityConfig(PolicyConfig):
    """Algorithm 1 knobs (§V.B, §VI.H)."""

    alpha: float = fz.ALPHA
    gamma: float = 1.0  # renewable weight (benefit term)
    beta: float = 1.0  # congestion weight
    queue_penalty_s: float = 7200.0  # expected wait per unit load
    min_benefit_s: float = 1500.0  # hysteresis: don't move for marginal wins
    eps: float = 0.0  # >0 enables stochastic feasibility (§VI.H)
    forecast_sigma_s: float = 0.0


@dataclass(frozen=True)
class ThrottleConfig(PolicyConfig):
    power_frac: float = 0.5  # demand-response level on grid power


@dataclass(frozen=True)
class DeferConfig(PolicyConfig):
    max_wait_s: float = 4 * 3600.0  # never hold a queued job longer than this


@dataclass(frozen=True)
class PlanAheadConfig(PolicyConfig):
    """Knobs for the forecast-driven planner (Algorithm 1 + lookahead)."""

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    max_wait_s: float = 4 * 3600.0  # Defer bound (as defer-to-window)
    pause_horizon_s: float = 4 * 3600.0  # Pause-for-window lookahead
    min_pause_compute_s: float = 1800.0  # don't park nearly-done jobs
    arrival_margin_s: float = 1800.0  # forecast-noise margin on arrivals


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type["Policy"]] = {}
_ALIASES: Dict[str, str] = {}
_CONFIGS: Dict[str, Type[PolicyConfig]] = {}


def register_policy(name: str, *, aliases: Tuple[str, ...] = (),
                    config: Type[PolicyConfig] = PolicyConfig):
    """Class decorator: add a Policy to the registry under ``name``
    (stored normalized — lowercase, dashes — so lookups always hit)."""

    key = _norm(name)

    def deco(cls: Type["Policy"]) -> Type["Policy"]:
        cls.name = key
        _REGISTRY[key] = cls
        _CONFIGS[key] = config
        for a in aliases:
            _ALIASES[_norm(a)] = key
        return cls

    return deco


def _norm(name: str) -> str:
    return name.lower().replace("_", "-")


def available_policies() -> List[str]:
    return sorted(_REGISTRY)


def policy_config_cls(name: str) -> Type[PolicyConfig]:
    return _CONFIGS[_resolve(name)]


def _resolve(name: str) -> str:
    key = _norm(name)
    key = _ALIASES.get(key, key)
    if key not in _REGISTRY:
        raise KeyError(
            f"unknown policy {name!r}; available: {', '.join(available_policies())}"
        )
    return key


def make_policy(name: str, config: Optional[PolicyConfig] = None, **kw) -> "Policy":
    """Instantiate a registered policy.

    ``config`` is a :class:`PolicyConfig` matching the policy (its fields are
    splatted into the constructor); ``**kw`` overrides individual fields.
    """
    key = _resolve(name)
    if config is not None:
        kw = {**dataclasses.asdict(config), **kw}
    return _REGISTRY[key](**kw)


# ---------------------------------------------------------------------------
# Algorithm 1 building blocks (shared by feasibility-aware and plan-ahead)
# ---------------------------------------------------------------------------


def algorithm1_grid(state: ClusterState, candidates: List[JobView], *,
                    alpha: float, eps: float = 0.0,
                    forecast_sigma_s: float = 0.0, bw_grid=None):
    """Stage 1, vectorized: one feasibility evaluation over the whole
    (candidate × destination) grid per tick.  ``bw_grid`` overrides the
    snapshot's advertised rows (plan-ahead hardens them against forecast
    outages first); ``eps`` > 0 with ``forecast_sigma_s`` > 0 swaps the
    deterministic time gate for the stochastic one (§VI.H).  Returns
    ``(ok_grid, t_transfer_grid)``."""
    import numpy as np

    sizes = np.array([j.ckpt_bytes for j in candidates])[:, None]
    t_loads = np.array([j.t_load_s for j in candidates])[:, None]
    if bw_grid is None:
        bw_grid = np.asarray(state.bandwidth_bps)[
            np.array([j.site for j in candidates], dtype=np.int64), :
        ]  # (n_candidates, n_sites)
    windows = state.site_window_s[None, :]
    v = fz.evaluate(sizes, bw_grid, windows, alpha=alpha, t_load_s=t_loads)
    if eps > 0.0 and forecast_sigma_s > 0.0:
        ok_grid = (
            np.asarray(
                fz.stochastic_feasible(
                    sizes, bw_grid, windows, forecast_sigma_s,
                    eps=eps, alpha=alpha, t_load_s=t_loads,
                )
            )
            & np.asarray(v.energy_ok)
            & (np.asarray(v.workload_class) != 2)
        )
    else:
        ok_grid = np.asarray(v.feasible)
    return ok_grid, np.asarray(v.t_transfer_s)


def best_destination(state: ClusterState, job: JobView, ok_row,
                     t_transfer_row, reserved: Dict[int, int], *,
                     gamma: float, beta: float, queue_penalty_s: float,
                     min_benefit_s: float) -> Optional[int]:
    """Stage 2: utility maximization inside the feasible set.

        benefit(d) = γ · expected grid-seconds avoided
                     − β · queue penalty · (load(d) − load(s))

    ``reserved`` tracks same-tick slot commitments so concurrent decisions
    do not herd.  Returns the argmax destination sid (ties by transfer
    time) or None when nothing beats ``max(t_cost, min_benefit_s)``."""
    cur = state.site(job.site)
    best: Optional[Tuple[float, float, int]] = None  # (-benefit, t_transfer, sid)
    for dest in state.sites:
        if dest.sid == job.site:
            continue
        if not ok_row[dest.sid]:
            continue
        window = dest.window_remaining_s
        t_transfer = float(t_transfer_row[dest.sid])
        t_cost = t_transfer + job.t_load_s + fz.T_DOWNTIME_S
        cur_green_s = cur.window_remaining_s if cur.renewable_active else 0.0
        dest_green_s = min(window, job.remaining_compute_s)
        grid_seconds_avoided = max(
            0.0, dest_green_s - min(cur_green_s, job.remaining_compute_s))
        dest_load = (dest.busy + dest.queued
                     + reserved[dest.sid]) / max(dest.slots, 1)
        # symmetric congestion term: moving toward a less-loaded site is
        # itself a benefit (contention-aware placement, §V.D.2)
        benefit = (
            gamma * grid_seconds_avoided
            - beta * queue_penalty_s * (dest_load - cur.load)
        )
        if dest.free_slots - reserved[dest.sid] <= 0:
            benefit -= queue_penalty_s  # would have to queue
        if benefit <= max(t_cost, min_benefit_s):
            continue
        key = (-benefit, t_transfer, dest.sid)
        if best is None or key < best:
            best = key
    return best[2] if best is not None else None


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class Policy:
    name = "base"

    def decide(self, state: ClusterState) -> List[Action]:
        raise NotImplementedError

    # Comparison harnesses use this instead of string-matching on the name.
    wants_oracle_forecast = False


@register_policy("static")
class StaticPolicy(Policy):
    """Fixed placement, no inter-site coordination (§VII.E baseline 1)."""

    def decide(self, state: ClusterState) -> List[Action]:
        return []


@register_policy("energy-only", aliases=("energyonly",))
class EnergyOnlyPolicy(Policy):
    """Migrate whenever renewable energy is available elsewhere, without
    feasibility constraints (§VII.E baseline 2). Herds onto the greenest
    site; initiates transfers that cannot finish inside windows."""

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.migratable():
            cur = state.site(job.site)
            if cur.renewable_active:
                continue  # already green
            greens = [
                s for s in state.sites
                if s.renewable_active and s.sid != job.site
                and (s.slots - s.busy) > 0  # STALE capacity: ignores in-flight
            ]
            if not greens:
                continue
            # spread over whatever is green right now (hash placement), with
            # only a stale capacity check and NO feasibility filter (§VII.E:
            # 'lacks awareness of transfer-time or energy-cost limits'):
            # transfers near window end, Class C checkpoints and transient
            # over-subscription all happen.
            dest = greens[job.jid % len(greens)]
            out.append(Migrate(job.jid, dest.sid))
        return out


@register_policy("feasibility-aware", aliases=("feasibility", "ours"),
                 config=FeasibilityConfig)
@dataclass
class FeasibilityAwarePolicy(Policy):
    """Paper Algorithm 1 (§V.B).

    Stage 1 — strict feasibility filter per (job, destination):
        T_cost = T_transfer + T_load + 0.4 s
        reject if T_cost > α · window(d)            (time)
        reject if T_breakeven > window(d)           (energy)
        reject if class(w) == C                     (§VI.D)
    Stage 2 — optimization inside the feasible set:
        benefit(d) = expected grid-seconds avoided − queue penalty
        migrate to argmax benefit iff benefit > T_cost, ties by T_transfer.
    """

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    eps: float = 0.0
    forecast_sigma_s: float = 0.0

    def decide(self, state: ClusterState) -> List[Action]:
        candidates = state.migratable()
        if not candidates:
            return []
        ok_grid, t_transfer_grid = algorithm1_grid(
            state, candidates, alpha=self.alpha, eps=self.eps,
            forecast_sigma_s=self.forecast_sigma_s)
        out: List[Action] = []
        # Track slot reservations within this tick so we do not herd.
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        for i, job in enumerate(candidates):
            dest = best_destination(
                state, job, ok_grid[i], t_transfer_grid[i], reserved,
                gamma=self.gamma, beta=self.beta,
                queue_penalty_s=self.queue_penalty_s,
                min_benefit_s=self.min_benefit_s)
            if dest is not None:
                out.append(Migrate(job.jid, dest))
                reserved[dest] += 1
        return out


@register_policy("oracle", config=FeasibilityConfig)
@dataclass
class OraclePolicy(FeasibilityAwarePolicy):
    """Feasibility-aware under perfect (σ=0) forecasts (Table VIII row 4).
    The zero-noise forecaster is selected by the harness via
    ``wants_oracle_forecast``."""

    wants_oracle_forecast = True


@register_policy("grid-throttle", config=ThrottleConfig)
@dataclass
class GridThrottlePolicy(Policy):
    """Beyond-paper demand response: run at reduced power whenever a site is
    on grid electricity, full power inside renewable windows.  Exercises the
    ``Throttle`` action; never migrates."""

    power_frac: float = 0.5

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.running():
            green = state.site(job.site).renewable_active
            want = 1.0 if green else self.power_frac
            if abs(job.power_frac - want) > 1e-9:
                out.append(Throttle(job.jid, want))
        return out


@register_policy("plan-ahead", aliases=("planahead",), config=PlanAheadConfig)
@dataclass
class PlanAheadPolicy(Policy):
    """Forecast-driven planner: Algorithm 1's filter evaluated against the
    *forecast* fabric, plus multi-step Pause/Resume and Defer plans over
    the window horizon (``state.forecast``).

    Four stages per tick:

    1. **Migrate** — Algorithm 1 (hard feasibility filter + utility
       maximization), with the bandwidth grid hardened against forecast
       link outages: a transfer that would still be in flight when an
       outage begins on its link is planned at the outage's degraded
       capacity, not today's matrix.  Every chosen migration must also
       pass an *arrival* check at the post-admission ``(flows+1)`` rate —
       the transfer must land ``arrival_margin_s`` inside the destination
       window and before any forecast outage on its link, so planned
       moves do not become failed migrations.  Jobs at green sites are
       pre-emptively evacuated only when the forecast says their uplink
       browns out before the window ends and their checkpoint could no
       longer drain afterwards.
    2. **Pause** — running jobs burning grid power at dark sites are
       parked when the forecast promises a window within
       ``pause_horizon_s`` (the Pause-for-window sequence PR 1 left open).
    3. **Resume** — paused jobs restart when their site turns green, or
       when the window they were waiting for evaporates from the
       forecast (no stranding).
    4. **Defer** — queued jobs at dark sites are held until the forecast
       window start (bounded by ``max_wait_s``), one Defer per
       (job, window) via ``JobView.defer_until_s``.

    Degrades gracefully to reactive feasibility-aware + defer behaviour
    when ``state.forecast`` is None.
    """

    alpha: float = fz.ALPHA
    gamma: float = 1.0
    beta: float = 1.0
    queue_penalty_s: float = 7200.0
    min_benefit_s: float = 1500.0
    max_wait_s: float = 4 * 3600.0
    pause_horizon_s: float = 4 * 3600.0
    min_pause_compute_s: float = 1800.0
    arrival_margin_s: float = 1800.0

    # ---- stage 1: migration ------------------------------------------------
    def _migrations(self, state: ClusterState, planned: set) -> List[Action]:
        import numpy as np

        t = state.t
        fc = state.forecast
        candidates = state.migratable()
        if not candidates:
            return []
        n_sites = state.n_sites
        cand_sites = np.array([j.site for j in candidates], dtype=np.int64)
        bw_grid = np.array(np.asarray(state.bandwidth_bps)[cand_sites, :],
                           copy=True)
        # forecast hardening: plan any transfer that would cross the first
        # forecast outage on its link at the outage's degraded capacity
        outage_at = {}
        if fc is not None:
            for s in set(int(x) for x in cand_sites):
                for d in range(n_sites):
                    if d != s:
                        outage_at[(s, d)] = fc.next_outage(s, d, t)
            for i, job in enumerate(candidates):
                for d in range(n_sites):
                    o = outage_at.get((job.site, d))
                    bw = bw_grid[i, d]
                    if o is None or bw <= 0.0:
                        continue
                    t_transfer = 8.0 * job.ckpt_bytes / bw
                    if o.start_s < t + t_transfer:  # would cross the outage
                        bw_grid[i, d] = min(bw, o.capacity_bps)
        ok_grid, t_transfer_grid = algorithm1_grid(
            state, candidates, alpha=self.alpha, bw_grid=bw_grid)

        out: List[Action] = []
        flows = list(state.transfers)
        reserved: Dict[int, int] = {s.sid: 0 for s in state.sites}
        for i, job in enumerate(candidates):
            cur = state.site(job.site)
            if cur.renewable_active:
                if job.remaining_compute_s <= cur.window_remaining_s:
                    continue  # finishes green where it is
                # pre-emptive evacuation: only when the uplink is forecast
                # to brown out before this window ends — afterwards the
                # checkpoint could no longer drain at plan rate
                if fc is None:
                    continue
                uplink_out = fc.next_uplink_outage_start_s(job.site, t)
                if uplink_out > t + cur.window_remaining_s:
                    continue  # fabric stays clean: migrate reactively later
            dest_sid = best_destination(
                state, job, ok_grid[i], t_transfer_grid[i], reserved,
                gamma=self.gamma, beta=self.beta,
                queue_penalty_s=self.queue_penalty_s,
                min_benefit_s=self.min_benefit_s)
            if dest_sid is None:
                continue
            # arrival check at the post-admission rate — counting both the
            # in-flight transfers and the migrations committed earlier this
            # tick: the transfer must land inside the destination window
            # with margin, and before any forecast outage on its link
            # (otherwise the rate estimate is fiction and the move becomes
            # a failed migration)
            rate = state.post_admission_bps(job.site, dest_sid, flows)
            if rate <= 0.0:
                continue
            t_transfer = 8.0 * job.ckpt_bytes / rate
            t_arrive = t + t_transfer
            dest_window_end = t + state.site(dest_sid).window_remaining_s
            if t_arrive + self.arrival_margin_s > dest_window_end:
                continue
            if fc is not None:
                # only a FUTURE outage start the transfer would cross
                # invalidates the rate estimate — an outage already in
                # progress is baked into the (degraded) capacities behind
                # `rate`, but it must not mask a back-to-back successor
                if fc.next_outage_start_after(job.site, dest_sid,
                                              t) < t_arrive:
                    continue
            out.append(Migrate(job.jid, dest_sid))
            flows.append((job.site, dest_sid))
            reserved[dest_sid] += 1
            planned.add(job.jid)
        return out

    def decide(self, state: ClusterState) -> List[Action]:
        t = state.t
        fc = state.forecast
        planned: set = set()
        out: List[Action] = list(self._migrations(state, planned))

        # ---- stage 2: Pause-for-window (running jobs on grid power)
        if fc is not None:
            for job in state.running():
                if job.jid in planned:
                    continue
                site = state.site(job.site)
                if site.renewable_active:
                    continue
                if job.remaining_compute_s < self.min_pause_compute_s:
                    continue
                start = fc.next_window_start_s(job.site, t)
                if t < start <= t + self.pause_horizon_s:
                    out.append(Pause(job.jid))

        # ---- stage 3: Resume at the (forecast) window start
        for job in state.paused():
            site = state.site(job.site)
            if site.renewable_active:
                out.append(Resume(job.jid))
                continue
            if fc is None:
                out.append(Resume(job.jid))
                continue
            w = fc.next_window(job.site, t)
            if w is None or w.start_s > t + self.pause_horizon_s:
                # the window we parked for moved out of reach — stop waiting
                out.append(Resume(job.jid))

        # ---- stage 4: Defer queued jobs across the dark span
        for job in state.queued():
            if job.held(t):
                continue  # one Defer per (job, window)
            site = state.site(job.site)
            if site.renewable_active:
                continue
            start = (fc.next_window_start_s(job.site, t) if fc is not None
                     else site.next_window_start_s)
            if t < start <= t + self.max_wait_s:
                out.append(Defer(job.jid, start))
        return out


@register_policy("defer-to-window", config=DeferConfig)
@dataclass
class DeferToWindowPolicy(Policy):
    """Beyond-paper: hold queued jobs at dark sites until the site's next
    forecast window start (bounded by ``max_wait_s``), so they begin on
    renewable power.  Exercises the ``Defer`` action."""

    max_wait_s: float = 4 * 3600.0

    def decide(self, state: ClusterState) -> List[Action]:
        out: List[Action] = []
        for job in state.queued():
            if job.held(state.t):
                # already holding for a window — re-issuing Defer every tick
                # is pure action noise (one Defer per (job, window); the
                # job resurfaces here when the hold expires)
                continue
            site = state.site(job.site)
            if site.renewable_active:
                continue
            start = site.next_window_start_s
            if state.t < start <= state.t + self.max_wait_s:
                out.append(Defer(job.jid, start))
        return out


__all__ = [
    "Action", "ClusterState", "DeferConfig", "DeferToWindowPolicy",
    "EnergyOnlyPolicy", "FeasibilityAwarePolicy", "FeasibilityConfig",
    "GridThrottlePolicy", "JobView", "OraclePolicy", "OrchestratorContext",
    "PlanAheadConfig", "PlanAheadPolicy", "Policy", "PolicyConfig",
    "SiteView", "StaticPolicy", "ThrottleConfig", "available_policies",
    "make_policy", "policy_config_cls", "register_policy",
]
