"""Feasibility-domain model for migratory AI workloads (paper §IV + §VI).

A workload w = (S, τ) migrating from site s to site d over WAN bandwidth
B_{s,d} is governed by:

  time:     T_transfer + T_load + T_downtime < α · T_energy(d)      (eq. 1)
  energy:   T_breakeven = P_sys · T_transfer / P_node < T_energy(d) (§IV.D)

with T_transfer = 8·S / B  (S bytes, B bits/s).  Classification (§VI.D):

  class A:  T_transfer < 60 s      (freely migratable)
  class B:  60 s ≤ T_transfer < 300 s  (conditional: needs α-window check)
  class C:  T_transfer ≥ 300 s     (never migrated)

Everything is vectorized and backend-dispatched: jax inputs keep the jnp
path (grids for the Fig. 2 phase diagram lower to a single fused kernel);
plain floats / numpy arrays take a pure-numpy path, because the
orchestrator evaluates a small (jobs × sites) grid *every tick* and jnp
dispatch plus shape-driven recompiles dominated the whole simulation there
(≈6.5 s of a 6.6 s 7-day run before the split).  Zero bandwidth (no link)
yields an infinite transfer time, i.e. infeasible, without warnings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

ArrayLike = Union[float, np.ndarray, jax.Array]

# --- paper constants (Table V + §IV) ---------------------------------------
ALPHA = 0.1  # acceptable disruption fraction of the renewable window
T_DOWNTIME_S = 0.4  # stop-the-world (PhoenixOS [17])
T_LOAD_S = 10.3  # checkpoint load (ServerlessLLM [19])
P_SYS_KW = 1.8  # combined system power during transfer (§IV.D)
P_NODE_KW = 0.75  # compute-node power (§IV.D)
CLASS_A_MAX_S = 60.0
CLASS_B_MAX_S = 300.0

GB = 1e9


class FeasibilityVerdict(NamedTuple):
    feasible: ArrayLike  # bool: time AND energy constraints hold
    time_ok: ArrayLike
    energy_ok: ArrayLike
    t_transfer_s: ArrayLike
    t_cost_s: ArrayLike  # transfer + load + downtime
    t_breakeven_s: ArrayLike
    workload_class: ArrayLike  # 0=A, 1=B, 2=C


def _use_jax(*xs) -> bool:
    return any(isinstance(x, jax.Array) for x in xs)


def transfer_time_s(size_bytes: ArrayLike, bandwidth_bps: ArrayLike) -> ArrayLike:
    """T_transfer = 8 S / B  (paper §V).  B = 0 (no link) -> inf."""
    if _use_jax(size_bytes, bandwidth_bps):
        return 8.0 * size_bytes / bandwidth_bps
    size = np.asarray(size_bytes, dtype=np.float64)
    bw = np.asarray(bandwidth_bps, dtype=np.float64)
    with np.errstate(divide="ignore"):
        return 8.0 * size / bw


def migration_cost_s(
    size_bytes: ArrayLike,
    bandwidth_bps: ArrayLike,
    t_load_s: ArrayLike = T_LOAD_S,
    t_downtime_s: float = T_DOWNTIME_S,
) -> ArrayLike:
    return transfer_time_s(size_bytes, bandwidth_bps) + t_load_s + t_downtime_s


def migration_energy_kwh(
    size_bytes: ArrayLike, bandwidth_bps: ArrayLike, p_sys_kw: float = P_SYS_KW
) -> ArrayLike:
    """E_mig = P_sys · T_transfer  (eq. 2)."""
    return p_sys_kw * transfer_time_s(size_bytes, bandwidth_bps) / 3600.0


def breakeven_time_s(
    size_bytes: ArrayLike,
    bandwidth_bps: ArrayLike,
    p_sys_kw: float = P_SYS_KW,
    p_node_kw: float = P_NODE_KW,
) -> ArrayLike:
    """T_BE = E_mig / P_node — minimum renewable runtime to amortize the
    migration energy (§IV.D / §VI.B)."""
    return (p_sys_kw / p_node_kw) * transfer_time_s(size_bytes, bandwidth_bps)


def _classify_from_time(t_transfer: ArrayLike, xp) -> ArrayLike:
    """§VI.D class from a precomputed T_transfer (0=A, 1=B, 2=C)."""
    return xp.where(t_transfer < CLASS_A_MAX_S, 0,
                    xp.where(t_transfer < CLASS_B_MAX_S, 1, 2)).astype(xp.int32)


def classify(size_bytes: ArrayLike, bandwidth_bps: ArrayLike) -> ArrayLike:
    """0=A, 1=B, 2=C per the §VI.D T_transfer thresholds."""
    t = transfer_time_s(size_bytes, bandwidth_bps)
    xp = jnp if isinstance(t, jax.Array) else np
    return _classify_from_time(xp.asarray(t), xp)


def classify_by_size(size_bytes: ArrayLike) -> ArrayLike:
    """Table IV size bands (equivalent to the time thresholds at ~1 Gbps):
    A < 10 GB, B 10–100 GB, C > 100 GB."""
    s = jnp.asarray(size_bytes, jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    return jnp.where(s < 10 * GB, 0, jnp.where(s <= 100 * GB, 1, 2)).astype(jnp.int32)


def evaluate(
    size_bytes: ArrayLike,
    bandwidth_bps: ArrayLike,
    window_s: ArrayLike,
    *,
    alpha: float = ALPHA,
    t_load_s: ArrayLike = T_LOAD_S,
    t_downtime_s: float = T_DOWNTIME_S,
    p_sys_kw: float = P_SYS_KW,
    p_node_kw: float = P_NODE_KW,
) -> FeasibilityVerdict:
    """Full feasibility verdict for (w, s→d) triples. Broadcasts.
    ``transfer_time_s`` picks the backend (numpy for numpy/python inputs —
    this runs once per orchestrator tick on the whole (jobs x sites) grid,
    where jnp dispatch used to dominate the simulation); everything else
    derives from T_transfer in that same backend."""
    t_transfer = transfer_time_s(size_bytes, bandwidth_bps)
    xp = jnp if _use_jax(t_transfer, window_s, t_load_s) else np
    t_cost = t_transfer + t_load_s + t_downtime_s
    t_be = (p_sys_kw / p_node_kw) * t_transfer  # = breakeven_time_s
    cls = _classify_from_time(t_transfer, xp)
    time_ok = t_cost < alpha * xp.asarray(window_s)
    energy_ok = t_be < window_s
    feasible = xp.logical_and(xp.logical_and(time_ok, energy_ok), cls != 2)
    return FeasibilityVerdict(feasible, time_ok, energy_ok, t_transfer,
                              t_cost, t_be, cls)


# ---------------------------------------------------------------------------
# Stochastic renewable windows (§VI.H)
# ---------------------------------------------------------------------------


def _norm_ppf(p: ArrayLike) -> ArrayLike:
    """Standard normal inverse CDF via erfinv."""
    return jnp.sqrt(2.0) * jax.scipy.special.erfinv(2.0 * jnp.asarray(p) - 1.0)


def stochastic_feasible(
    size_bytes: ArrayLike,
    bandwidth_bps: ArrayLike,
    window_forecast_s: ArrayLike,
    window_sigma_s: ArrayLike,
    *,
    eps: float = 0.05,
    alpha: float = ALPHA,
    t_load_s: float = T_LOAD_S,
    t_downtime_s: float = T_DOWNTIME_S,
) -> ArrayLike:
    """P[T_mig + T_load + T_dt < α·T̃_d | T̂_d] ≥ 1 − ε with a Gaussian
    forecast-error model T̃ ~ N(T̂, σ²): equivalent to checking the
    deterministic condition against the lower ε-quantile of the window."""
    t_cost = migration_cost_s(size_bytes, bandwidth_bps, t_load_s, t_downtime_s)
    if _use_jax(t_cost, window_forecast_s, window_sigma_s):
        window_lo = window_forecast_s + _norm_ppf(eps) * window_sigma_s  # ε-quantile
        return t_cost < alpha * jnp.maximum(window_lo, 0.0)
    import statistics

    ppf = statistics.NormalDist().inv_cdf(eps)
    window_lo = (np.asarray(window_forecast_s, dtype=np.float64)
                 + ppf * np.asarray(window_sigma_s, dtype=np.float64))
    return t_cost < alpha * np.maximum(window_lo, 0.0)


# ---------------------------------------------------------------------------
# Phase diagram (Fig. 2) and utility model (§VI.F-G)
# ---------------------------------------------------------------------------


def phase_diagram(
    sizes_gb: np.ndarray,
    bandwidths_gbps: np.ndarray,
    window_s: float = 2.5 * 3600,
    alpha: float = ALPHA,
):
    """Grid of (class, T_transfer, feasible) over checkpoint-size × WAN-bw —
    the paper's Fig. 2. Returns dict of (len(sizes), len(bws)) arrays."""
    S = jnp.asarray(sizes_gb, jnp.float32)[:, None] * GB
    B = jnp.asarray(bandwidths_gbps, jnp.float32)[None, :] * 1e9
    v = evaluate(S, B, window_s, alpha=alpha)
    return {
        "t_transfer_s": np.asarray(v.t_transfer_s),
        "class": np.asarray(v.workload_class),
        "feasible": np.asarray(v.feasible),
        "t_breakeven_s": np.asarray(v.t_breakeven_s),
    }


@dataclass(frozen=True)
class UtilityWeights:
    gamma: float = 1.0  # renewable-availability weight  (§VI.F)
    beta: float = 1.0  # congestion/load weight


def site_utility(renewable: ArrayLike, load: ArrayLike, w: UtilityWeights = UtilityWeights()):
    """U(w, d) = γ·R(d) − β·L(d)."""
    return w.gamma * jnp.asarray(renewable) - w.beta * jnp.asarray(load)


def feasible_destinations(
    size_bytes: float,
    bandwidths_bps: np.ndarray,  # (n_sites,) from current site
    windows_s: np.ndarray,  # (n_sites,) remaining renewable windows
    *,
    alpha: float = ALPHA,
) -> np.ndarray:
    """D_feasible(w, s) = {d | class(w) != C  ∧  T_mig < α·T_d}  (§VI.E)."""
    v = evaluate(size_bytes, jnp.asarray(bandwidths_bps), jnp.asarray(windows_s), alpha=alpha)
    return np.asarray(v.feasible)
