"""Grid-signals subsystem: per-site time-varying carbon-intensity and
price traces (the paper's extended vision §VIII "integration with
grid-level control and demand-response ecosystems"; cf. Zhang et al.'s
carbon-aware compute-power scheduling and Wiesner et al.'s curtailment-
window studies — both show carbon/price signals change the optimal
schedule versus pure energy minimization).

The energy-accounting spine historically collapsed everything to a single
grid-kWh scalar, so no policy could distinguish a dirty-peak hour from a
clean-but-curtailed one.  This module adds the missing axis:

  * :class:`SignalStack` — piecewise-constant per-site signal traces in
    the same searchsorted/epoch-cached batched-query shape as
    :class:`~repro.core.traces.TraceStack`: shared hourly breakpoints,
    ``(n_sites, K)`` value matrix, cumulative-integral rows so any
    ``∫ signal dt`` over ``[t0, t1]`` is two O(log K) lookups — which is
    what lets the next-event engine integrate gCO2/$ *analytically* per
    inter-event span (exact for piecewise-constant signals, like its kWh
    accounting).
  * :class:`GridSignals` — the carbon (gCO2/kWh) + price ($/kWh) pair a
    simulation run carries, plus derived demand-response
    :class:`CurtailRequest` events (grid-operator "shed load now" spans,
    derived from carbon-peak hours — DR notices track system stress).
  * :func:`generate_signals` — deterministic duck-curve generator
    (morning/evening carbon peaks, midday solar trough, per-site spread),
    parameterized by a scenario-composable :class:`SignalProfile`.

Accounting invariants (tests/test_signals.py):

  * grid kWh is untouched — signal accounting is a parallel integral,
    never a rewrite of the energy path;
  * per-site ``grid_gco2``/``grid_cost`` sums equal the fleet totals
    exactly (each gram is billed to exactly one site);
  * the event engine's analytic per-span integrals equal a fixed-dt
    Riemann sum in the limit, and are *exact* whenever the signal is
    piecewise-constant (our generator always is).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from functools import cached_property
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

HOUR = 3600.0


@dataclass(frozen=True, slots=True)
class CurtailRequest:
    """A demand-response curtail-request span: the grid operator asks
    ``site`` to cap compute power at ``power_frac`` of nominal during
    ``[start_s, end_s)``.  Requests are *advisory* — the simulator never
    enforces them; a policy that honours them (receding-horizon does, via
    ``Throttle``) shifts energy out of exactly the hours the grid is
    dirtiest, which is what the carbon accounting rewards."""

    start_s: float
    end_s: float
    site: int
    power_frac: float = 0.5

    def active(self, t: float) -> bool:
        return self.start_s <= t < self.end_s


@dataclass(frozen=True)
class SignalProfile:
    """Shape of the grid-signal process (scenario-composable; defaults
    approximate a CAISO-like duck curve: solar floods midday, the evening
    ramp is the dirty peak)."""

    # carbon intensity, gCO2/kWh
    carbon_base: float = 320.0
    carbon_morning: float = 110.0  # ~08:00 ramp bump
    carbon_evening: float = 240.0  # ~19:00 peak bump
    carbon_midday_dip: float = 130.0  # ~13:00 solar trough
    carbon_noise: float = 20.0
    carbon_min: float = 40.0
    carbon_site_spread: float = 0.10  # +- multiplicative per-site spread
    # wholesale price, $/kWh
    price_base: float = 0.12
    price_coupling: float = 0.8  # fraction of relative carbon swing tracked
    price_noise: float = 0.008
    price_min: float = 0.0
    price_site_spread: float = 0.10
    # demand-response: curtail-request spans wherever carbon >= threshold
    curtail_threshold: Optional[float] = None  # gCO2/kWh; None = no DR
    curtail_frac: float = 0.5  # requested power cap during a DR span


@dataclass(frozen=True, eq=False)
class SignalStack:
    """Piecewise-constant per-site signal traces behind batched queries.

    ``edges`` are the shared breakpoints (strictly increasing,
    ``(K+1,)``); ``values[s, k]`` holds the signal on
    ``[edges[k], edges[k+1])``; ``cum[s, k]`` is ``∫`` from ``edges[0]``
    to ``edges[k]``.  Outside the covered range the signal extrapolates
    as a constant (first/last segment value) — simulations run past the
    trace horizon for the late-job tail and must keep integrating.
    """

    edges: np.ndarray  # (K+1,)
    values: np.ndarray  # (n_sites, K)
    cum: np.ndarray  # (n_sites, K+1)

    @classmethod
    def from_values(cls, edges: np.ndarray, values: np.ndarray) -> "SignalStack":
        edges = np.asarray(edges, dtype=np.float64)
        values = np.atleast_2d(np.asarray(values, dtype=np.float64))
        if edges.ndim != 1 or len(edges) != values.shape[1] + 1:
            raise ValueError("need len(edges) == values.shape[1] + 1")
        seg = np.diff(edges)
        if not (seg > 0).all():
            raise ValueError("edges must be strictly increasing")
        cum = np.zeros((values.shape[0], len(edges)))
        np.cumsum(values * seg[None, :], axis=1, out=cum[:, 1:])
        return cls(edges, values, cum)

    @property
    def n_sites(self) -> int:
        return len(self.values)

    def _seg(self, t: float) -> int:
        """Segment index covering ``t`` (clamped: constant extrapolation)."""
        k = bisect.bisect_right(self._edge_list, t) - 1
        return min(max(k, 0), self.values.shape[1] - 1)

    @cached_property
    def _edge_list(self) -> List[float]:
        return [float(v) for v in self.edges]

    @cached_property
    def _epoch_cache(self) -> dict:
        return {}

    # -- point queries -------------------------------------------------------
    def value(self, site: int, t: float) -> float:
        """Signal value at ``t`` for one site."""
        return float(self.values[site, self._seg(t)])

    def value_grid(self, t: float) -> np.ndarray:
        """(n_sites,) signal values at ``t`` — cached per breakpoint epoch
        (piecewise-constant: every ``t`` in a segment shares the column).
        Treat as read-only."""
        k = self._seg(t)
        got = self._epoch_cache.get(k)
        if got is None:
            got = self._epoch_cache[k] = self.values[:, k]
        return got

    # -- analytic integrals --------------------------------------------------
    def _cum_at(self, site: int, x: float) -> float:
        """``∫ signal dt`` from ``edges[0]`` to ``x`` (constant
        extrapolation outside the covered range)."""
        e = self._edge_list
        if x <= e[0]:
            return float((x - e[0]) * self.values[site, 0])
        if x >= e[-1]:
            return float(self.cum[site, -1]
                         + (x - e[-1]) * self.values[site, -1])
        k = bisect.bisect_right(e, x) - 1
        return float(self.cum[site, k] + (x - e[k]) * self.values[site, k])

    def integral(self, site: int, t0: float, t1: float) -> float:
        """Exact ``∫ signal dt`` over ``[t0, t1]`` (0 when t1 <= t0)."""
        if t1 <= t0:
            return 0.0
        return self._cum_at(site, t1) - self._cum_at(site, t0)

    def _cum_at_grid(self, x: float) -> np.ndarray:
        e = self._edge_list
        if x <= e[0]:
            return (x - e[0]) * self.values[:, 0]
        if x >= e[-1]:
            return self.cum[:, -1] + (x - e[-1]) * self.values[:, -1]
        k = bisect.bisect_right(e, x) - 1
        return self.cum[:, k] + (x - e[k]) * self.values[:, k]

    def integral_grid(self, t0: float, t1: float) -> np.ndarray:
        """(n_sites,) batched :meth:`integral` over a shared span."""
        if t1 <= t0:
            return np.zeros(self.n_sites)
        return self._cum_at_grid(t1) - self._cum_at_grid(t0)

    def cum_at_rows(self, sites: np.ndarray, xs: np.ndarray) -> np.ndarray:
        """Elementwise :meth:`_cum_at` over broadcastable ``(site, x)``
        arrays — the op-for-op batched mirror (same branch expressions,
        same float order), so results are bit-identical to the scalar.
        Used by the receding-horizon planner's whole-grid cost tensors."""
        sites = np.asarray(sites)
        xs = np.asarray(xs, dtype=np.float64)
        sites, xs = np.broadcast_arrays(sites, xs)
        e = self.edges
        k = np.searchsorted(e, xs, side="right") - 1
        kc = np.clip(k, 0, self.values.shape[1] - 1)
        lo = (xs - e[0]) * self.values[sites, 0]
        hi = self.cum[sites, -1] + (xs - e[-1]) * self.values[sites, -1]
        mid = self.cum[sites, kc] + (xs - e[kc]) * self.values[sites, kc]
        return np.where(xs <= e[0], lo, np.where(xs >= e[-1], hi, mid))

    def integral_rows(self, sites: np.ndarray, t0s: np.ndarray,
                      t1s: np.ndarray) -> np.ndarray:
        """Elementwise :meth:`integral` over broadcastable ``(site, t0,
        t1)`` arrays (0 where ``t1 <= t0``, exactly like the scalar)."""
        sites = np.asarray(sites)
        t0s = np.asarray(t0s, dtype=np.float64)
        t1s = np.asarray(t1s, dtype=np.float64)
        sites, t0s, t1s = np.broadcast_arrays(sites, t0s, t1s)
        return np.where(t1s <= t0s, 0.0,
                        self.cum_at_rows(sites, t1s)
                        - self.cum_at_rows(sites, t0s))

    def mean(self, site: int, t0: float, t1: float) -> float:
        return self.integral(site, t0, t1) / (t1 - t0) if t1 > t0 else \
            self.value(site, t0)

    def integral_where_ge(
        self, site: int, t0: float, t1: float, floor: float,
    ) -> Tuple[float, float]:
        """``(∫ v·1[v >= floor] dt, Σ time with v >= floor)`` over
        ``[t0, t1]`` — the segment-gated integral the sell-back
        accounting bills export revenue with (a prosumer only exports
        into segments whose price clears the floor; with ``floor=0``
        this is exactly the negative-price guard).  Piecewise-exact,
        constant extrapolation outside the covered range."""
        if t1 <= t0:
            return 0.0, 0.0
        e = self._edge_list
        vals = self.values[site]
        last = len(vals) - 1
        k0 = min(max(bisect.bisect_right(e, t0) - 1, 0), last)
        k1 = min(max(bisect.bisect_right(e, t1) - 1, 0), last)
        tot = 0.0
        dur = 0.0
        for k in range(k0, k1 + 1):
            a = t0 if k == k0 else e[k]
            b = t1 if k == k1 else e[k + 1]
            if b <= a:
                continue
            v = float(vals[k])
            if v >= floor:
                tot += v * (b - a)
                dur += b - a
        return tot, dur


def grid_signal_integral(
    stack: SignalStack, site: int,
    green_overlaps: Iterable[Tuple[float, float]], t0: float, t1: float,
) -> float:
    """``∫ signal dt`` over the NON-renewable portion of ``[t0, t1]`` —
    the total integral minus the integral over the (clipped, disjoint)
    renewable-window overlaps.  Exact for piecewise-constant signals; this
    is the quantity the event engine bills per span:
    ``gCO2 = P_kW / 3600 · grid_signal_integral(carbon, ...)``."""
    tot = stack.integral(site, t0, t1)
    for a, b in green_overlaps:
        tot -= stack.integral(site, max(t0, a), min(t1, b))
    return tot


@dataclass(frozen=True, eq=False)
class GridSignals:
    """The per-run signal bundle: carbon + price stacks over the same
    site fleet, plus derived demand-response curtail-request events
    (start-sorted)."""

    carbon: SignalStack  # gCO2/kWh
    price: SignalStack  # $/kWh
    curtailments: Tuple[CurtailRequest, ...] = ()

    @property
    def n_sites(self) -> int:
        return self.carbon.n_sites


def _compress_true_runs(mask: np.ndarray) -> List[Tuple[int, int]]:
    """Runs of consecutive True entries as [k0, k1) index pairs."""
    runs: List[Tuple[int, int]] = []
    start = None
    for k, hot in enumerate(mask):
        if hot and start is None:
            start = k
        elif not hot and start is not None:
            runs.append((start, k))
            start = None
    if start is not None:
        runs.append((start, len(mask)))
    return runs


def curtail_requests_from_carbon(
    carbon: SignalStack, threshold: float, power_frac: float,
) -> Tuple[CurtailRequest, ...]:
    """Derive demand-response spans from the carbon trace: every maximal
    run of segments with ``carbon >= threshold`` at a site becomes one
    :class:`CurtailRequest` (DR notices track system stress, which the
    carbon signal proxies)."""
    out: List[CurtailRequest] = []
    edges = carbon.edges
    for s in range(carbon.n_sites):
        for k0, k1 in _compress_true_runs(carbon.values[s] >= threshold):
            out.append(CurtailRequest(float(edges[k0]), float(edges[k1]),
                                      s, power_frac))
    out.sort(key=lambda c: (c.start_s, c.site))
    return tuple(out)


def _bump(hod: np.ndarray, center: float, width: float) -> np.ndarray:
    """Diurnal Gaussian bump on hour-of-day (wrap-around distance)."""
    d = np.abs(hod - center)
    d = np.minimum(d, 24.0 - d)
    return np.exp(-0.5 * (d / width) ** 2)


def generate_signals(
    n_sites: int = 5,
    days: int = 7,
    *,
    seed: int = 0,
    profile: Optional[SignalProfile] = None,
    **overrides,
) -> GridSignals:
    """Deterministic hourly carbon/price traces for a site fleet.

    Hourly piecewise-constant duck curve per site: morning and evening
    carbon bumps, a midday solar trough, a per-site multiplicative spread
    (geographic grid mix) and i.i.d. hourly noise; price tracks the
    relative carbon swing through ``price_coupling`` plus its own spread/
    noise.  Traces cover ``2 * days`` (the simulator runs the late-job
    tail to twice the horizon) and extrapolate as constants beyond.

    Deterministic per ``(seed, profile)`` and independent of every other
    RNG stream in the run (own ``default_rng([seed, 131])`` seeding) —
    adding signals to a simulation changes no existing draw.
    """
    import dataclasses as _dc

    prof = profile or SignalProfile()
    if overrides:
        prof = _dc.replace(prof, **overrides)
    n_hours = 2 * days * 24
    edges = np.arange(n_hours + 1, dtype=np.float64) * HOUR
    hod = (np.arange(n_hours, dtype=np.float64) + 0.5) % 24.0
    shape = (prof.carbon_morning * _bump(hod, 8.0, 1.5)
             + prof.carbon_evening * _bump(hod, 19.0, 2.0)
             - prof.carbon_midday_dip * _bump(hod, 13.0, 2.5))
    rng = np.random.default_rng([seed, 131])
    carbon = np.empty((n_sites, n_hours))
    price = np.empty((n_sites, n_hours))
    for s in range(n_sites):
        c_scale = 1.0 + prof.carbon_site_spread * float(rng.uniform(-1, 1))
        p_scale = 1.0 + prof.price_site_spread * float(rng.uniform(-1, 1))
        c = (prof.carbon_base * c_scale + shape
             + rng.normal(0.0, prof.carbon_noise, n_hours))
        carbon[s] = np.maximum(prof.carbon_min, c)
        rel = (carbon[s] - prof.carbon_base) / prof.carbon_base
        p = (prof.price_base * p_scale * (1.0 + prof.price_coupling * rel)
             + rng.normal(0.0, prof.price_noise, n_hours))
        price[s] = np.maximum(prof.price_min, p)
    carbon_stack = SignalStack.from_values(edges, carbon)
    price_stack = SignalStack.from_values(edges, price)
    curtail: Tuple[CurtailRequest, ...] = ()
    if prof.curtail_threshold is not None:
        curtail = curtail_requests_from_carbon(
            carbon_stack, prof.curtail_threshold, prof.curtail_frac)
    return GridSignals(carbon=carbon_stack, price=price_stack,
                       curtailments=curtail)


__all__ = [
    "CurtailRequest", "GridSignals", "SignalProfile", "SignalStack",
    "curtail_requests_from_carbon", "generate_signals",
    "grid_signal_integral",
]
