"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential scan), following arXiv:2405.04517.

mLSTM stabilized recurrence (per head):
    m_t = max(f̂_t + m_{t-1}, ĩ_t)                       (f̂ = log-forget)
    C_t = e^{f̂_t + m_{t-1} - m_t} C_{t-1} + e^{ĩ_t - m_t} v_t k_tᵀ
    n_t = e^{f̂_t + m_{t-1} - m_t} n_{t-1} + e^{ĩ_t - m_t} k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, e^{-m_t})          (q scaled dh^-1/2)

Chunk-parallel form: with b_t = Σ_{τ≤t} f̂_τ inside a chunk,
    m_t = b_t + max(m_0 - b_0·0, cummax_τ≤t (ĩ_τ - b_τ))
so the stabilizer is a `lax.cummax`, and both the intra-chunk contribution
(decay-matrix masked q·kᵀ) and the inter-chunk contribution (carried C) are
plain matmuls.  The recurrent form (`*_recurrent`) is kept as the oracle for
property tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation, truncated_normal
from repro.parallel.sharding import shd

CHUNK = 256
NEG = -1e30


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(key, d: int, num_heads: int, num_layers: int, dtype) -> dict:
    d_in = 2 * d  # projection factor 2
    dh = d_in // num_heads
    ks = jax.random.split(key, 8)
    out_std = 0.02 / max(1.0, (2.0 * num_layers) ** 0.5)
    return {
        "w_up": truncated_normal(ks[0], (d, 2 * d_in), 0.02, dtype),  # [x | z-gate]
        # block-diagonal per-head q/k/v maps (xLSTM §mLSTM block)
        "wq": truncated_normal(ks[1], (num_heads, dh, dh), 0.02, dtype),
        "wk": truncated_normal(ks[2], (num_heads, dh, dh), 0.02, dtype),
        "wv": truncated_normal(ks[3], (num_heads, dh, dh), 0.02, dtype),
        "wi": truncated_normal(ks[4], (d_in, num_heads), 0.02, dtype),
        "wf": truncated_normal(ks[5], (d_in, num_heads), 0.02, dtype),
        "bi": jnp.zeros((num_heads,), dtype),
        "bf": jnp.full((num_heads,), 3.0, dtype),  # open forget gates at init
        "skip": jnp.ones((d_in,), dtype),
        "w_down": truncated_normal(ks[6], (d_in, d), out_std, dtype),
    }


def _mlstm_qkvif(p, xi):
    b, s, d_in = xi.shape
    H, dh = p["wq"].shape[0], p["wq"].shape[1]
    xh = xi.reshape(b, s, H, dh)
    q = jnp.einsum("bshk,hkj->bshj", xh, p["wq"])
    k = jnp.einsum("bshk,hkj->bshj", xh, p["wk"])
    v = jnp.einsum("bshk,hkj->bshj", xh, p["wv"])
    i_raw = (xi @ p["wi"] + p["bi"]).astype(jnp.float32)  # (b,s,H)
    f_raw = (xi @ p["wf"] + p["bf"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw)
    return q, k, v, i_raw, logf


def _mlstm_chunk(carry, q, k, v, i_raw, logf):
    """One chunk. carry = (C (b,H,dh,dh), n (b,H,dh), m (b,H)).
    q,k,v: (b,l,H,dh) f32; i_raw, logf: (b,l,H) f32."""
    C0, n0, m0 = carry
    b, l, H, dh = q.shape
    scale = dh ** -0.5
    bcs = jnp.cumsum(logf, axis=1)  # (b,l,H) inclusive
    # stabilizer: m_t = b_t + max(m0, cummax(i_τ - b_τ))
    g = jax.lax.cummax(i_raw - bcs, axis=1)
    m = bcs + jnp.maximum(m0[:, None], g)  # (b,l,H)
    # intra-chunk decay matrix  D_tj = exp(b_t - b_j + i_j - m_t),  j <= t
    S = bcs[:, :, None, :] - bcs[:, None, :, :] + i_raw[:, None, :, :]  # (b,t,j,H)
    tri = jnp.tril(jnp.ones((l, l), bool))
    S = jnp.where(tri[None, :, :, None], S, NEG)
    D = jnp.exp(S - m[:, :, None, :])  # (b,t,j,H)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    scores = jnp.einsum("bthk,bjhk->btjh", qf, kf) * scale
    w = scores * D  # w_tj = D_tj * (q_t . k_j) * scale
    num_intra = jnp.einsum("btjh,bjhe->bthe", w, vf)
    den_intra = jnp.sum(w, axis=2)  # (b,t,H) == sum_j w_tj  (n_t . q_t intra)
    # inter-chunk: decay from carry  exp(m0 + b_t - m_t)
    dec = jnp.exp(m0[:, None] + bcs - m)  # (b,l,H)
    num_inter = jnp.einsum("bthk,bhke->bthe", qf * scale * dec[..., None], C0)
    den_inter = jnp.einsum("bthk,bhk->bth", qf * scale * dec[..., None], n0)
    num = num_intra + num_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
    # end-of-chunk carry
    bL = bcs[:, -1]  # (b,H)
    mL = m[:, -1]
    wC = jnp.exp(bL[:, None] - bcs + i_raw - mL[:, None])  # (b,l,H)
    C1 = jnp.exp(m0 + bL - mL)[:, :, None, None] * C0 + jnp.einsum(
        "blh,blhk,blhe->bhke", wC, k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n1 = jnp.exp(m0 + bL - mL)[:, :, None] * n0 + jnp.einsum("blh,blhk->bhk", wC, k.astype(jnp.float32))
    return (C1, n1, mL), h


def mlstm_cell(q, k, v, i_raw, logf, carry=None, chunk: int = CHUNK):
    """Chunk-parallel mLSTM over a full sequence.
    q,k,v: (b,s,H,dh); i_raw/logf: (b,s,H) f32. Returns (h (b,s,H,dh) f32, carry)."""
    b, s, H, dh = q.shape
    if carry is None:
        carry = (
            jnp.zeros((b, H, dh, dh), jnp.float32),
            jnp.zeros((b, H, dh), jnp.float32),
            jnp.full((b, H), -jnp.inf, jnp.float32),
        )
    l = min(chunk, s)
    n_chunks = max(1, s // l)
    assert s % l == 0

    resh = lambda t: t.reshape(b, n_chunks, l, *t.shape[2:]).swapaxes(0, 1)

    def step(c, xs):
        qc, kc, vc, ic, fc = xs
        c2, h = _mlstm_chunk(c, qc, kc, vc, ic, fc)
        return c2, h

    carry, hs = jax.lax.scan(step, carry, (resh(q), resh(k), resh(v), resh(i_raw), resh(logf)))
    h = hs.swapaxes(0, 1).reshape(b, s, H, dh)
    return h, carry


def mlstm_cell_recurrent(q, k, v, i_raw, logf, carry=None):
    """Step-by-step oracle (property tests compare against mlstm_cell)."""
    b, s, H, dh = q.shape
    if carry is None:
        carry = (
            jnp.zeros((b, H, dh, dh), jnp.float32),
            jnp.zeros((b, H, dh), jnp.float32),
            jnp.full((b, H), -jnp.inf, jnp.float32),
        )
    scale = dh ** -0.5

    def step(c, xs):
        C, n, m = c
        qt, kt, vt, it, ft = xs  # (b,H,dh) / (b,H)
        qt, kt, vt = (a.astype(jnp.float32) for a in (qt, kt, vt))
        m2 = jnp.maximum(ft + m, it)
        fdec = jnp.exp(ft + m - m2)[..., None]
        iin = jnp.exp(it - m2)[..., None]
        C2 = fdec[..., None] * C + iin[..., None] * jnp.einsum("bhk,bhe->bhke", kt, vt)
        n2 = fdec * n + iin * kt
        den = jnp.einsum("bhk,bhk->bh", n2, qt * scale)
        num = jnp.einsum("bhke,bhk->bhe", C2, qt * scale)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m2))[..., None]
        return (C2, n2, m2), h

    sw = lambda t: t.swapaxes(0, 1)
    carry, hs = jax.lax.scan(step, carry, (sw(q), sw(k), sw(v), sw(i_raw), sw(logf)))
    return hs.swapaxes(0, 1), carry


def apply_mlstm(p: dict, x: jax.Array, num_heads: int, state=None, decode: bool = False):
    """Full mLSTM block. x: (b, s, d) -> (b, s, d) [+ state if decode]."""
    xz = x @ p["w_up"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shd(xi, "batch", "seq", None)
    q, k, v, i_raw, logf = _mlstm_qkvif(p, xi)
    if decode:
        h, state = mlstm_cell_recurrent(q, k, v, i_raw, logf, carry=state)
    else:
        h, state = mlstm_cell(q, k, v, i_raw, logf, carry=state)
    b, s, H, dh = h.shape
    hflat = h.reshape(b, s, H * dh).astype(x.dtype) + xi * p["skip"]
    y = hflat * jax.nn.silu(z)
    y = shd(y, "batch", "seq", None)
    out = y @ p["w_down"]
    return (out, state) if decode else out


def mlstm_state_spec(batch: int, d: int, num_heads: int, long_context=False):
    d_in = 2 * d
    dh = d_in // num_heads
    specs = (
        jax.ShapeDtypeStruct((batch, num_heads, dh, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, num_heads, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, num_heads), jnp.float32),
    )
    ax = "kv_long" if long_context else "model"
    pspecs = (
        (None if long_context else "dp_batch", None, ax, None),
        (None if long_context else "dp_batch", None, ax),
        (None if long_context else "dp_batch", None),
    )
    return specs, pspecs


def init_mlstm_state(batch: int, d: int, num_heads: int):
    d_in = 2 * d
    dh = d_in // num_heads
    return (
        jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        jnp.zeros((batch, num_heads, dh), jnp.float32),
        jnp.full((batch, num_heads), -jnp.inf, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def init_slstm(key, d: int, num_heads: int, num_layers: int, dtype) -> dict:
    dh = d // num_heads
    ks = jax.random.split(key, 12)
    p = {}
    for i, g in enumerate("ifzo"):
        p[f"w{g}"] = truncated_normal(ks[i], (d, d), 0.02, dtype)
        p[f"r{g}"] = truncated_normal(ks[4 + i], (num_heads, dh, dh), 0.02 , dtype)
        p[f"b{g}"] = (jnp.full((d,), 3.0, dtype) if g == "f" else jnp.zeros((d,), dtype))
    dff = (d * 4) // 3
    p["ffn_wi"] = truncated_normal(ks[8], (d, dff), 0.02, dtype)
    p["ffn_wg"] = truncated_normal(ks[9], (d, dff), 0.02, dtype)
    p["ffn_wo"] = truncated_normal(ks[10], (dff, d), 0.02 / max(1.0, (2.0 * num_layers) ** 0.5), dtype)
    return p


def _slstm_scan(p, x, num_heads: int, state=None):
    """x: (b, s, d). Sequential scan (sLSTM is not parallelizable)."""
    b, s, d = x.shape
    dh = d // num_heads
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = {"c": z, "n": z + 1e-6, "h": z, "m": jnp.zeros((b, d), jnp.float32)}

    pre = {g: x @ p[f"w{g}"] + p[f"b{g}"] for g in "ifzo"}  # (b,s,d) each

    def rmul(h, r):  # block-diagonal per-head recurrent matmul
        hh = h.reshape(b, num_heads, dh)
        return jnp.einsum("bhk,hkj->bhj", hh, r).reshape(b, d)

    def step(st, xs):
        xi, xf, xz, xo = xs
        h_prev = st["h"].astype(x.dtype)
        it = (xi + rmul(h_prev, p["ri"])).astype(jnp.float32)
        ft = (xf + rmul(h_prev, p["rf"])).astype(jnp.float32)
        zt = jnp.tanh((xz + rmul(h_prev, p["rz"])).astype(jnp.float32))
        ot = jax.nn.sigmoid((xo + rmul(h_prev, p["ro"])).astype(jnp.float32))
        logf = jax.nn.log_sigmoid(ft)
        m2 = jnp.maximum(logf + st["m"], it)
        i_ = jnp.exp(it - m2)
        f_ = jnp.exp(logf + st["m"] - m2)
        c2 = f_ * st["c"] + i_ * zt
        n2 = f_ * st["n"] + i_
        h2 = ot * c2 / jnp.maximum(n2, 1e-6)
        return {"c": c2, "n": n2, "h": h2, "m": m2}, h2

    sw = lambda t: t.swapaxes(0, 1)
    state, hs = jax.lax.scan(step, state, (sw(pre["i"]), sw(pre["f"]), sw(pre["z"]), sw(pre["o"])))
    return hs.swapaxes(0, 1).astype(x.dtype), state


def apply_slstm(p: dict, x: jax.Array, num_heads: int, act: str = "gelu", state=None, decode: bool = False):
    h, state = _slstm_scan(p, x, num_heads, state=state)
    # post gated FFN (pf 4/3)
    y = activation(act)(h @ p["ffn_wg"]) * (h @ p["ffn_wi"])
    out = y @ p["ffn_wo"]
    return (out, state) if decode else out


def slstm_state_spec(batch: int, d: int, long_context=False):
    sd = jax.ShapeDtypeStruct((batch, d), jnp.float32)
    ax = "kv_long" if long_context else "model"
    ps = (None if long_context else "dp_batch", ax)
    return (
        {"c": sd, "n": sd, "h": sd, "m": sd},
        {"c": ps, "n": ps, "h": ps, "m": ps},
    )


def init_slstm_state(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z}
