"""Mamba-1 selective SSM mixer (jamba's non-attention layers).

Training/prefill uses a chunked associative scan: sequence chunks are
processed with `jax.lax.associative_scan` (parallel within a chunk) and the
SSM state is carried across chunks with `jax.lax.scan`. This bounds the
materialized (b, chunk, d_inner, d_state) discretization tensors to one chunk
(VMEM/HBM-friendly) while remaining fully parallel inside the chunk.

Decode is the O(1) recurrent update.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import truncated_normal
from repro.parallel.sharding import shd

CHUNK = 256


def init_mamba(key, d: int, *, expand: int, d_state: int, d_conv: int, num_layers: int, dtype) -> dict:
    d_in = expand * d
    dt_rank = max(1, d // 16)
    keys = jax.random.split(key, 6)
    out_std = 0.02 / max(1.0, (2.0 * num_layers) ** 0.5)
    # S4D-real initialization for A.
    A = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, d_state))
    return {
        "in_proj": truncated_normal(keys[0], (d, 2 * d_in), 0.02, dtype),
        "conv_w": truncated_normal(keys[1], (d_conv, d_in), 0.02, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": truncated_normal(keys[2], (d_in, dt_rank + 2 * d_state), 0.02, dtype),
        "dt_proj": truncated_normal(keys[3], (dt_rank, d_in), dt_rank ** -0.5, dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01))).astype(dtype),
        "A_log": jnp.log(A),  # f32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": truncated_normal(keys[4], (d_in, d), out_std, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv along seq. x: (b, s, c), w: (k, c).
    If `state` (b, k-1, c) is given, it is the left context (decode)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (b, s+k-1, c)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return out + b, new_state


def _ssm_params(p, xc, d_state):
    """xc: (b, l, d_in) post-conv activations -> (dt, B, C) discretization."""
    dt_rank = p["dt_proj"].shape[0]
    proj = xc @ p["x_proj"]  # (b, l, dt_rank + 2N)
    dt_raw, B, C = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"].astype(jnp.float32))  # (b,l,d_in)
    return dt.astype(jnp.float32), B.astype(jnp.float32), C.astype(jnp.float32)


def _scan_chunk(h0, A, dt, B, C, x):
    """One chunk of the selective scan.
    h0: (b, d_in, N); dt: (b,l,d_in); B,C: (b,l,N); x: (b,l,d_in)."""
    Abar = jnp.exp(dt[..., None] * (-jnp.exp(A))[None, None])  # (b,l,d_in,N)
    Bx = (dt * x)[..., None] * B[:, :, None, :]  # (b,l,d_in,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_cum, h_intra = jax.lax.associative_scan(combine, (Abar, Bx), axis=1)
    h = h_intra + a_cum * h0[:, None]  # (b,l,d_in,N)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return h[:, -1], y


def apply_mamba(p: dict, x: jax.Array, *, d_state: int, act_dtype=None) -> jax.Array:
    """Full-sequence forward. x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    xz = x @ p["in_proj"]  # (b, s, 2*d_in)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = shd(xi, "batch", "seq", None)
    xc, _ = _causal_conv(xi, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt, B, C = _ssm_params(p, xc, d_state)
    xcf = xc.astype(jnp.float32)

    d_in = xi.shape[-1]
    n_chunks = max(1, s // CHUNK)
    l = s // n_chunks
    A = p["A_log"]

    def step(h, inputs):
        dt_c, B_c, C_c, x_c = inputs
        h2, y = _scan_chunk(h, A, dt_c, B_c, C_c, x_c)
        return h2, y

    resh = lambda t: t.reshape(b, n_chunks, l, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, d_in, d_state), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (resh(dt), resh(B), resh(C), resh(xcf)))
    y = ys.swapaxes(0, 1).reshape(b, s, d_in)
    y = y + xcf * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = shd(y, "batch", "seq", None)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode (recurrent)
# ---------------------------------------------------------------------------


def init_mamba_state(batch: int, d: int, *, expand: int, d_state: int, d_conv: int, dtype):
    d_in = expand * d
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, d_state), jnp.float32),
    }


def mamba_state_spec(batch, d, *, expand, d_state, d_conv, dtype, long_context=False):
    d_in = expand * d
    conv = jax.ShapeDtypeStruct((batch, d_conv - 1, d_in), dtype)
    ssm = jax.ShapeDtypeStruct((batch, d_in, d_state), jnp.float32)
    inner = ("kv_long",) if long_context else ("model",)
    return {"conv": conv, "ssm": ssm}, {
        "conv": (None if long_context else "dp_batch", None, inner[0]),
        "ssm": (None if long_context else "dp_batch", inner[0], None),
    }


def apply_mamba_decode(p: dict, x: jax.Array, state: dict, *, d_state: int):
    """x: (b, 1, d); state: {'conv','ssm'} -> (y (b,1,d), new_state)."""
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"], state=state["conv"])
    xc = jax.nn.silu(xc)
    dt, B, C = _ssm_params(p, xc, d_state)
    A = -jnp.exp(p["A_log"])  # (d_in, N)
    xcf = xc.astype(jnp.float32)
    Abar = jnp.exp(dt[:, 0, :, None] * A[None])  # (b, d_in, N)
    Bx = (dt[:, 0] * xcf[:, 0])[..., None] * B[:, 0, None, :]
    h = Abar * state["ssm"] + Bx  # (b, d_in, N)
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0]) + xcf[:, 0] * p["D"]
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_state.astype(state["conv"].dtype), "ssm": h}
