"""Decoder-only LM assembly: heterogeneous layer patterns (attention, Mamba,
m/sLSTM), MoE interleave, scan-over-layer-groups with configurable remat.

The layer stack is organized as ``num_groups`` repetitions of
``cfg.block_pattern``; group params are stacked on a leading dim and the
stack is applied with ``jax.lax.scan`` (one group's HLO, compiled once).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_embed,
    apply_mlp,
    apply_norm,
    apply_unembed,
    cross_entropy,
    init_embed,
    init_mlp,
    init_norm,
    softcap,
    truncated_normal,
)
from repro.parallel.sharding import shd

REMAT_POLICIES = {
    "none": "none",
    "full": "full",
    "dots": "dots",
}


def unroll_scan() -> bool:
    """Dry-run accounting mode: python-unroll the layer-group loop so XLA
    cost_analysis and the HLO collective parse see every layer (XLA counts a
    While body once). Controlled by REPRO_UNROLL_SCAN=1 (set by dryrun.py)."""
    return os.environ.get("REPRO_UNROLL_SCAN", "0") == "1"


def scan_or_unroll(body, carry, xs):
    """lax.scan, or an equivalent unrolled python loop (cost accounting)."""
    if not unroll_scan():
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _is_moe_pos(cfg: ModelConfig, i: int) -> bool:
    if not cfg.moe:
        return False
    return (not cfg.moe_pattern) or (i in cfg.moe_pattern)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, kind: str, moe_here: bool) -> dict:
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm_type, dt)}
    if kind.startswith("attn"):
        p["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
            num_layers=cfg.num_layers, dtype=dt,
        )
    elif kind == "mamba":
        p["mamba"] = mamba_lib.init_mamba(
            k1, cfg.d_model, expand=cfg.mamba_expand, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, num_layers=cfg.num_layers, dtype=dt,
        )
    elif kind == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(k1, cfg.d_model, cfg.num_heads, cfg.num_layers, dt)
        return p  # self-contained block
    elif kind == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(k1, cfg.d_model, cfg.num_heads, cfg.num_layers, dt)
        return p
    else:
        raise ValueError(kind)
    p["norm2"] = init_norm(cfg.d_model, cfg.norm_type, dt)
    if moe_here:
        p["moe"] = moe_lib.init_moe(
            k2, cfg.d_model, cfg.expert_d_ff, cfg.num_experts, cfg.num_layers, dt
        )
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.num_layers, dt)
    return p


def _mixer_kwargs(cfg: ModelConfig, kind: str) -> dict:
    return dict(
        rope_type=cfg.rope_type,
        rope_theta=cfg.rope_theta,
        mrope_sections=cfg.mrope_sections,
        qk_norm=cfg.qk_norm,
        mask_kind="window" if kind == "attn_local" else "causal",
        window=cfg.sliding_window if kind == "attn_local" else 0,
        attn_softcap=cfg.attn_softcap,
    )


def apply_block(p: dict, x, kind: str, cfg: ModelConfig, positions, moe_here: bool):
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind.startswith("attn"):
        mix = attn_lib.apply_attention(p["attn"], h, positions=positions, **_mixer_kwargs(cfg, kind))
    elif kind == "mamba":
        mix = mamba_lib.apply_mamba(p["mamba"], h, d_state=cfg.mamba_d_state)
    elif kind == "mlstm":
        return x + xlstm_lib.apply_mlstm(p["mlstm"], h, cfg.num_heads), aux
    elif kind == "slstm":
        return x + xlstm_lib.apply_slstm(p["slstm"], h, cfg.num_heads), aux
    else:
        raise ValueError(kind)
    x = x + mix
    h = apply_norm(p["norm2"], x, cfg.norm_type)
    if moe_here:
        y, aux = moe_lib.apply_moe(p["moe"], h, top_k=cfg.top_k, act=cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    x = x + y
    x = shd(x, "batch", "seq", "embed_act")
    return x, aux


def apply_block_decode(p, x, kind, cfg, positions, index, cache, moe_here, long_context):
    """One-token block step. Returns (x, new_cache)."""
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind.startswith("attn"):
        kw = _mixer_kwargs(cfg, kind)
        mix, cache = attn_lib.apply_attention_decode(
            p["attn"], h, cache, index, positions=positions,
            rope_type=kw["rope_type"], rope_theta=kw["rope_theta"],
            mrope_sections=kw["mrope_sections"], qk_norm=kw["qk_norm"],
            mask_kind=kw["mask_kind"], window=kw["window"],
            attn_softcap=kw["attn_softcap"], long_context=long_context,
        )
    elif kind == "mamba":
        mix, cache = mamba_lib.apply_mamba_decode(p["mamba"], h, cache, d_state=cfg.mamba_d_state)
    elif kind == "mlstm":
        y, cache = xlstm_lib.apply_mlstm(p["mlstm"], h, cfg.num_heads, state=cache, decode=True)
        return x + y, cache
    elif kind == "slstm":
        y, cache = xlstm_lib.apply_slstm(p["slstm"], h, cfg.num_heads, state=cache, decode=True)
        return x + y, cache
    else:
        raise ValueError(kind)
    x = x + mix
    h = apply_norm(p["norm2"], x, cfg.norm_type)
    if moe_here:
        y, _ = moe_lib.apply_moe(p["moe"], h, top_k=cfg.top_k, act=cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


# ---------------------------------------------------------------------------
# LM init / forward
# ---------------------------------------------------------------------------


def init_lm(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    ke, ku, kp, kg = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    params["embed"] = init_embed(ke, cfg.vocab_size, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        params["unembed"] = {"table": truncated_normal(ku, (cfg.d_model, cfg.vocab_size), 0.02, dt)}
    if cfg.learned_pos:
        params["pos_embed"] = {"table": truncated_normal(kp, (32768, cfg.d_model), 0.02, dt)}

    def init_group(gkey):
        ks = jax.random.split(gkey, len(cfg.block_pattern))
        return {
            f"b{i}": init_block(ks[i], cfg, kind, _is_moe_pos(cfg, i))
            for i, kind in enumerate(cfg.block_pattern)
        }

    gkeys = jax.random.split(kg, cfg.num_groups)
    params["groups"] = jax.vmap(init_group)(gkeys)
    params["final_norm"] = init_norm(cfg.d_model, cfg.norm_type, dt)
    return params


def embed_inputs(params, cfg: ModelConfig, batch: dict):
    if "embeds" in batch:
        x = batch["embeds"].astype(_dtype(cfg))
    else:
        x = apply_embed(params["embed"], batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.learned_pos:
        pos = batch["positions"] if "positions" in batch else jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        if pos.ndim == 3:
            pos = pos[..., 0]
        pe = jnp.take(params["pos_embed"]["table"], pos, axis=0)
        x = x + jnp.broadcast_to(pe, x.shape).astype(x.dtype)
    return shd(x, "batch", "seq", "embed_act")


def lm_forward(
    params: dict,
    batch: dict,
    cfg: ModelConfig,
    *,
    remat_policy: str = "full",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (b, s, vocab), aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def group_fn(carry, gp):
        x, aux = carry
        for i, kind in enumerate(cfg.block_pattern):
            x, a = apply_block(gp[f"b{i}"], x, kind, cfg, positions, _is_moe_pos(cfg, i))
            aux = aux + a
        return (x, aux), None

    body = _remat(group_fn, remat_policy)
    (x, aux), _ = scan_or_unroll(body, (x, jnp.zeros((), jnp.float32)), params["groups"])
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    table = params["embed"]["table"].T if cfg.tie_embeddings else params["unembed"]["table"]
    logits = apply_unembed(table, x)
    logits = softcap(logits, cfg.logit_softcap)
    return logits, aux


def lm_loss(params, batch, cfg: ModelConfig, *, remat_policy: str = "full"):
    logits, aux = lm_forward(params, batch, cfg, remat_policy=remat_policy)
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Cache / decode
# ---------------------------------------------------------------------------


def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int, long: bool):
    dt = _dtype(cfg)
    if kind.startswith("attn"):
        cache_len = min(max_len, cfg.sliding_window) if kind == "attn_local" and cfg.sliding_window else max_len
        shape = (batch, cache_len, cfg.num_kv_heads, cfg.resolved_head_dim)
        sds = {"k": jax.ShapeDtypeStruct(shape, dt), "v": jax.ShapeDtypeStruct(shape, dt)}
        ax = ("kv_long" if long else "kv_seq")
        ps = (None if long else "dp_batch", ax, None, None)
        return sds, {"k": ps, "v": ps}
    if kind == "mamba":
        return mamba_lib.mamba_state_spec(
            batch, cfg.d_model, expand=cfg.mamba_expand, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, dtype=dt, long_context=long,
        )
    if kind == "mlstm":
        return xlstm_lib.mlstm_state_spec(batch, cfg.d_model, cfg.num_heads, long_context=long)
    if kind == "slstm":
        return xlstm_lib.slstm_state_spec(batch, cfg.d_model, long_context=long)
    raise ValueError(kind)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, long_context: bool = False):
    """(ShapeDtypeStruct pytree, logical-pspec pytree) for the decode cache,
    with the leading stacked group dim."""

    def stack_sds(sds):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_groups,) + s.shape, s.dtype), sds
        )

    def stack_ps(ps):
        return jax.tree.map(
            lambda p: ("layers",) + tuple(p),
            ps,
            is_leaf=lambda x: isinstance(x, tuple) and (not x or not isinstance(x[0], tuple)),
        )

    specs, pspecs = {}, {}
    for i, kind in enumerate(cfg.block_pattern):
        sds, ps = _block_cache_spec(cfg, kind, batch, max_len, long_context)
        specs[f"b{i}"] = stack_sds(sds)
        pspecs[f"b{i}"] = stack_ps(ps)
    return specs, pspecs


def init_cache(cfg: ModelConfig, batch: int, max_len: int, long_context: bool = False):
    specs, _ = cache_specs(cfg, batch, max_len, long_context)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def lm_decode_step(
    params: dict,
    cache: dict,
    batch: dict,  # {'token': (b,) int32 | 'embeds': (b,1,d), 'index': scalar, ['positions']}
    cfg: ModelConfig,
    *,
    long_context: bool = False,
):
    """One-token decode. Returns (logits (b, vocab), new_cache)."""
    index = batch["index"].astype(jnp.int32)
    if "embeds" in batch:
        x = embed_inputs(params, cfg, {"embeds": batch["embeds"],
                                       **({"positions": batch["positions"]} if "positions" in batch else {})})
    else:
        tok = batch["token"][:, None]
        pb = {"tokens": tok}
        if "positions" in batch:
            pb["positions"] = batch["positions"]
        elif cfg.learned_pos:
            pb["positions"] = jnp.broadcast_to(index[None, None], (tok.shape[0], 1))
        x = embed_inputs(params, cfg, pb)
    b = x.shape[0]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)

    def group_fn(x, xs):
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(cfg.block_pattern):
            x, new_c[f"b{i}"] = apply_block_decode(
                gp[f"b{i}"], x, kind, cfg, positions, index, gc[f"b{i}"],
                _is_moe_pos(cfg, i), long_context,
            )
        return x, new_c

    x, new_cache = scan_or_unroll(group_fn, x, (params["groups"], cache))
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    table = params["embed"]["table"].T if cfg.tie_embeddings else params["unembed"]["table"]
    logits = apply_unembed(table, x)
    logits = softcap(logits, cfg.logit_softcap)
    return logits[:, 0], new_cache
