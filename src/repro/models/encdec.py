"""Whisper-style encoder-decoder transformer.

Per the assignment, the audio conv frontend is a STUB: ``input_specs()``
provides precomputed (batch, encoder_seq, d_model) frame embeddings
(sinusoidal positions folded in upstream). The decoder is a standard
causal transformer with cross-attention and learned absolute positions.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import (
    apply_embed, apply_mlp, apply_norm, apply_unembed, cross_entropy,
    init_embed, init_mlp, init_norm, truncated_normal,
)
from repro.models.transformer import _dtype, _remat, scan_or_unroll
from repro.parallel.sharding import shd


def _init_attn(key, cfg: ModelConfig, dtype):
    return attn_lib.init_attention(
        key, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias, qk_norm=False, num_layers=cfg.num_layers, dtype=dtype,
    )


def init_encdec(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    ke, kp, kenc, kdec = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embed(ke, cfg.vocab_size, cfg.d_model, dt),
        "pos_embed": {"table": truncated_normal(kp, (32768, cfg.d_model), 0.02, dt)},
    }

    def init_enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": init_norm(cfg.d_model, cfg.norm_type, dt),
            "attn": _init_attn(k1, cfg, dt),
            "norm2": init_norm(cfg.d_model, cfg.norm_type, dt),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.num_layers, dt),
        }

    def init_dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": init_norm(cfg.d_model, cfg.norm_type, dt),
            "attn": _init_attn(k1, cfg, dt),
            "norm2": init_norm(cfg.d_model, cfg.norm_type, dt),
            "cross_attn": _init_attn(k2, cfg, dt),
            "norm3": init_norm(cfg.d_model, cfg.norm_type, dt),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.num_layers, dt),
        }

    params["enc_groups"] = jax.vmap(init_enc_block)(jax.random.split(kenc, cfg.encoder_layers))
    params["enc_norm"] = init_norm(cfg.d_model, cfg.norm_type, dt)
    params["dec_groups"] = jax.vmap(init_dec_block)(jax.random.split(kdec, cfg.num_layers))
    params["final_norm"] = init_norm(cfg.d_model, cfg.norm_type, dt)
    return params


def encode(params, frames: jax.Array, cfg: ModelConfig, remat_policy: str = "full"):
    x = frames.astype(_dtype(cfg))
    x = shd(x, "batch", None, "embed_act")
    b, t = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def block(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        x = x + attn_lib.apply_attention(
            p["attn"], h, positions=positions, rope_type="none", rope_theta=0.0,
            mask_kind="full",
        )
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h, cfg.act), None

    x, _ = scan_or_unroll(_remat(block, remat_policy), x, params["enc_groups"])
    return apply_norm(params["enc_norm"], x, cfg.norm_type)


def decode_train(params, enc_out, tokens, cfg: ModelConfig, remat_policy: str = "full"):
    x = apply_embed(params["embed"], tokens)
    b, s = x.shape[0], x.shape[1]
    pe = jnp.take(params["pos_embed"]["table"], jnp.arange(s, dtype=jnp.int32), axis=0)
    x = x + pe[None].astype(x.dtype)
    x = shd(x, "batch", "seq", "embed_act")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        x = x + attn_lib.apply_attention(
            p["attn"], h, positions=positions, rope_type="none", rope_theta=0.0,
            mask_kind="causal",
        )
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        x = x + attn_lib.apply_cross_attention(p["cross_attn"], h, enc_out)
        h = apply_norm(p["norm3"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h, cfg.act), None

    x, _ = scan_or_unroll(_remat(block, remat_policy), x, params["dec_groups"])
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = apply_unembed(params["embed"]["table"].T, x)  # tied
    return logits


def encdec_forward(params, batch, cfg: ModelConfig, remat_policy: str = "full"):
    enc_out = encode(params, batch["frames"], cfg, remat_policy)
    logits = decode_train(params, enc_out, batch["tokens"], cfg, remat_policy)
    return logits, jnp.zeros((), jnp.float32)


def encdec_loss(params, batch, cfg: ModelConfig, *, remat_policy: str = "full"):
    logits, aux = encdec_forward(params, batch, cfg, remat_policy)
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def encdec_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    L = cfg.num_layers
    self_shape = (L, batch, max_len, nkv, hd)
    cross_shape = (L, batch, cfg.encoder_seq, nkv, hd)
    specs = {
        "self": {"k": jax.ShapeDtypeStruct(self_shape, dt), "v": jax.ShapeDtypeStruct(self_shape, dt)},
        "cross": {"k": jax.ShapeDtypeStruct(cross_shape, dt), "v": jax.ShapeDtypeStruct(cross_shape, dt)},
    }
    sp = ("layers", "dp_batch", "kv_seq", None, None)
    cp = ("layers", "dp_batch", None, None, None)
    pspecs = {"self": {"k": sp, "v": sp}, "cross": {"k": cp, "v": cp}}
    return specs, pspecs


def encdec_init_cache(params, frames, cfg: ModelConfig, batch: int, max_len: int):
    """Run the encoder and precompute per-layer cross K/V ('prefill')."""
    enc_out = encode(params, frames, cfg)

    def per_layer(p):
        k, v = attn_lib.cross_kv(p["cross_attn"], enc_out)
        return k, v

    ks, vs = jax.vmap(per_layer, in_axes=(0,))(params["dec_groups"])
    dt = _dtype(cfg)
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    z = jnp.zeros((cfg.num_layers, batch, max_len, nkv, hd), dt)
    return {"self": {"k": z, "v": z}, "cross": {"k": ks, "v": vs}}


def encdec_decode_step(params, cache, batch, cfg: ModelConfig):
    index = batch["index"].astype(jnp.int32)
    tok = batch["token"][:, None]
    x = apply_embed(params["embed"], tok)
    pe = jnp.take(params["pos_embed"]["table"], index[None, None], axis=0)
    x = x + jnp.broadcast_to(pe, x.shape).astype(x.dtype)
    positions = jnp.broadcast_to(index[None, None], (x.shape[0], 1)).astype(jnp.int32)

    def block(x, xs):
        p, self_c, cross_k, cross_v = xs
        h = apply_norm(p["norm1"], x, cfg.norm_type)
        y, self_c = attn_lib.apply_attention_decode(
            p["attn"], h, self_c, index, positions=positions,
            rope_type="none", rope_theta=0.0,
        )
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm_type)
        x = x + attn_lib.apply_cross_attention(p["cross_attn"], h, (cross_k, cross_v))
        h = apply_norm(p["norm3"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h, cfg.act), self_c

    x, new_self = scan_or_unroll(
        block, x,
        (params["dec_groups"], cache["self"], cache["cross"]["k"], cache["cross"]["v"]),
    )
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = apply_unembed(params["embed"]["table"].T, x)
    return logits[:, 0], {"self": new_self, "cross": cache["cross"]}
