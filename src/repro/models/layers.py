"""Shared primitive layers (pure-functional, params = nested dicts)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shd


def truncated_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, norm_type: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype=dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=dtype)
    return p


def apply_norm(p: dict, x: jax.Array, norm_type: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last dim with an explicit scale vector (qk-norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """tanh soft-capping (gemma2)."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, num_layers: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    out_std = 0.02 / max(1.0, (2.0 * num_layers) ** 0.5)
    return {
        "wi": truncated_normal(k1, (d, d_ff), 0.02, dtype),
        "wg": truncated_normal(k2, (d, d_ff), 0.02, dtype),
        "wo": truncated_normal(k3, (d_ff, d), out_std, dtype),
    }


def apply_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    h = activation(act)(x @ p["wg"]) * (x @ p["wi"])
    h = shd(h, "batch", "seq", "mlp_act")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d: int, dtype) -> dict:
    return {"table": truncated_normal(key, (vocab, d), 0.02, dtype)}


def apply_embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def apply_unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """x: (..., d) @ table.T -> logits.  ``table`` is (vocab, d) when tied
    (embed table) or (d, vocab) for a dedicated unembed matrix."""
    if table.shape[0] == x.shape[-1]:
        logits = x @ table
    else:
        logits = x @ table.T
    return shd(logits, "batch", "seq", "vocab_act")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level cross entropy in f32. labels < 0 are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
