"""Rotary position embeddings: standard RoPE and qwen2-vl M-RoPE."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jax.Array,  # (b, s, h, head_dim)
    positions: jax.Array,  # (b, s) int32
    theta: float,
) -> jax.Array:
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (b, s, half)
    cos = jnp.cos(angles)[:, :, None, :]  # (b, s, 1, half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # (b, s, h, head_dim)
    positions: jax.Array,  # (b, s, 3) int32 — temporal / height / width ids
    theta: float,
    sections: Tuple[int, ...],  # half-dim split, e.g. (16, 24, 24)
) -> jax.Array:
    """qwen2-vl multimodal RoPE: the rotary half-dim is partitioned into
    `sections`, each rotated by its own position stream (t/h/w)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # Build a (b, s, half) position matrix by picking the section's stream.
    section_id = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)]
    )  # (half,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(section_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )  # (b, s, half)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def apply_positional(x, positions, rope_type: str, theta: float, sections=()):
    if rope_type == "none":
        return x
    if rope_type == "mrope":
        if positions.ndim == 2:  # text-only fallback: same stream thrice
            positions = jnp.stack([positions] * 3, axis=-1)
        return apply_mrope(x, positions, theta, sections)
    if positions.ndim == 3:
        positions = positions[..., 0]
    return apply_rope(x, positions, theta)
