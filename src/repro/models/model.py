"""Unified model façade: build_model(cfg) -> Model with init / loss /
forward / decode / input_specs, covering decoder-only LMs, hybrids, SSMs and
the whisper encoder-decoder.

``input_specs(shape_name)`` returns ShapeDtypeStruct stand-ins + logical
partition specs for every model input — the dry-run lowers against these
without allocating anything (assignment §MULTI-POD DRY-RUN item 2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        if self.cfg.is_encdec:
            return encdec_lib.init_encdec(key, self.cfg)
        return tfm.init_lm(key, self.cfg)

    def init_eval_shape(self, key=None) -> dict:
        """Param ShapeDtypeStructs without allocation (dry-run)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # --------------------------------------------------------------- compute
    def loss(self, params, batch, *, remat_policy: str = "full"):
        if self.cfg.is_encdec:
            return encdec_lib.encdec_loss(params, batch, self.cfg, remat_policy=remat_policy)
        return tfm.lm_loss(params, batch, self.cfg, remat_policy=remat_policy)

    def forward(self, params, batch, *, remat_policy: str = "full"):
        if self.cfg.is_encdec:
            return encdec_lib.encdec_forward(params, batch, self.cfg, remat_policy)
        return tfm.lm_forward(params, batch, self.cfg, remat_policy=remat_policy)

    def decode_step(self, params, cache, batch, *, long_context: bool = False):
        if self.cfg.is_encdec:
            return encdec_lib.encdec_decode_step(params, cache, batch, self.cfg)
        return tfm.lm_decode_step(params, cache, batch, self.cfg, long_context=long_context)

    # ----------------------------------------------------------------- cache
    def cache_specs(self, batch: int, max_len: int, long_context: bool = False):
        if self.cfg.is_encdec:
            return encdec_lib.encdec_cache_specs(self.cfg, batch, max_len)
        return tfm.cache_specs(self.cfg, batch, max_len, long_context)

    def init_cache(self, batch: int, max_len: int, long_context: bool = False):
        specs, _ = self.cache_specs(batch, max_len, long_context)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)

    # ----------------------------------------------------------- input specs
    def input_specs(self, shape_name: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(ShapeDtypeStruct pytree, logical-axis pspec pytree) for the given
        assigned shape. Decode shapes include the KV cache / SSM state."""
        cfg = self.cfg
        shape = SHAPES[shape_name]
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        i32 = jnp.int32
        long = shape_name == "long_500k"

        if shape.kind in ("train", "prefill"):
            specs: Dict[str, Any] = {}
            pspecs: Dict[str, Any] = {}
            if cfg.is_encdec:
                specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
                pspecs["frames"] = ("batch", None, None)
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
                pspecs["tokens"] = ("batch", "seq")
            elif cfg.input_mode == "embeddings":
                specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
                pspecs["embeds"] = ("batch", "seq", None)
            else:
                specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
                pspecs["tokens"] = ("batch", "seq")
            if cfg.rope_type == "mrope":
                specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
                pspecs["positions"] = ("batch", "seq", None)
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
                pspecs["labels"] = ("batch", "seq")
            return specs, pspecs

        # decode: one new token against a cache of size S
        cache_sds, cache_ps = self.cache_specs(B, S, long_context=long)
        specs = {"cache": cache_sds, "index": jax.ShapeDtypeStruct((), i32)}
        pspecs = {"cache": cache_ps, "index": ()}
        if cfg.input_mode == "embeddings" and not cfg.is_encdec:
            specs["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
            pspecs["embeds"] = (None if long else "dp_batch", None, None)
        else:
            specs["token"] = jax.ShapeDtypeStruct((B,), i32)
            pspecs["token"] = (None if long else "dp_batch",)
        if cfg.rope_type == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((B, 1, 3), i32)
            pspecs["positions"] = (None if long else "dp_batch", None, None)
        return specs, pspecs

    # ------------------------------------------------------------ demo batch
    def dummy_batch(self, shape_name: str, seed: int = 0):
        """Concrete random batch matching input_specs (smoke tests/examples)."""
        specs, _ = self.input_specs(shape_name)
        key = jax.random.PRNGKey(seed)

        def gen(path, s):
            nonlocal key
            key, sub = jax.random.split(key)
            if jnp.issubdtype(s.dtype, jnp.integer):
                hi = self.cfg.vocab_size if s.shape else 1
                return jax.random.randint(sub, s.shape, 0, max(hi, 2), dtype=s.dtype)
            return jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)

        return jax.tree_util.tree_map_with_path(gen, specs)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
