"""GQA attention with RoPE/M-RoPE, sliding windows, soft-capping, qk-norm,
and a KV-cache decode path.

The quadratic reference math lives here (and doubles as the XLA path used on
CPU / in the dry-run); `repro.kernels.ops.flash_attention` is the Pallas TPU
fast path for train/prefill and is selected automatically on TPU backends.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import rope as rope_lib
from repro.models.layers import rms_norm_1d, truncated_normal
from repro.parallel.sharding import shd

NEG_INF = -2.0e38


def init_attention(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    *,
    qkv_bias: bool,
    qk_norm: bool,
    num_layers: int,
    dtype,
) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    out_std = 0.02 / max(1.0, (2.0 * num_layers) ** 0.5)
    p = {
        "wq": truncated_normal(kq, (d_model, num_heads, head_dim), 0.02, dtype),
        "wk": truncated_normal(kk, (d_model, num_kv_heads, head_dim), 0.02, dtype),
        "wv": truncated_normal(kv, (d_model, num_kv_heads, head_dim), 0.02, dtype),
        "wo": truncated_normal(ko, (num_heads, head_dim, d_model), out_std, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def _mask_bias(qpos, kpos, mask_kind: str, window: int) -> Optional[jax.Array]:
    """Additive mask bias broadcastable to (..., q, k). qpos/kpos int32."""
    if mask_kind == "full":
        return None
    ok = kpos[..., None, :] <= qpos[..., :, None]
    if mask_kind == "window" and window > 0:
        ok &= (qpos[..., :, None] - kpos[..., None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF)


def attend_ref(
    q: jax.Array,  # (b, s, nh, hd)
    k: jax.Array,  # (b, t, nkv, hd)
    v: jax.Array,  # (b, t, nkv, hd)
    *,
    mask_kind: str,
    window: int = 0,
    attn_softcap: float = 0.0,
    qpos: Optional[jax.Array] = None,  # (b, s)
    kpos: Optional[jax.Array] = None,  # (b, t)
    kv_valid: Optional[jax.Array] = None,  # (b, t) bool — decode cache validity
    kv_seq_axis: Optional[str] = None,  # keep scores sharded over the cache
    # sequence (flash-decode): partial softmax + tiny all-reduces instead of
    # all-gathering the K/V cache (§Perf decode optimization)
) -> jax.Array:
    """Quadratic GQA attention, f32 softmax. Returns (b, s, nh, hd)."""
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    if attn_softcap:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    if qpos is None:
        qpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if kpos is None:
        kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    bias = _mask_bias(qpos, kpos, mask_kind, window)  # (b, s, t) or None
    if bias is not None:
        scores = scores + bias[:, None, None, :, :]
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    if kv_seq_axis is not None:
        scores = shd(scores, "*", "*", "*", "*", kv_seq_axis)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, nh, hd)


def _project_qkv(p, x, kv_x, *, qk_norm):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if qk_norm:
        q = rms_norm_1d(q, p["q_norm"])
        k = rms_norm_1d(k, p["k_norm"])
    return q, k, v


def apply_attention(
    p: dict,
    x: jax.Array,  # (b, s, d)
    *,
    positions: jax.Array,  # (b, s) or (b, s, 3)
    rope_type: str,
    rope_theta: float,
    mrope_sections=(),
    qk_norm: bool = False,
    mask_kind: str = "causal",  # 'causal' | 'window' | 'full'
    window: int = 0,
    attn_softcap: float = 0.0,
) -> jax.Array:
    """Self-attention over a full sequence (train / prefill)."""
    q, k, v = _project_qkv(p, x, x, qk_norm=qk_norm)
    q = rope_lib.apply_positional(q, positions, rope_type, rope_theta, mrope_sections)
    k = rope_lib.apply_positional(k, positions, rope_type, rope_theta, mrope_sections)
    q = shd(q, "batch", "seq", "heads_act", "head_dim")
    # K/V explicitly replicated over the seq shards. §Perf iteration A3
    # tested leaving them unconstrained (hoping for a reduce-scatter
    # backward): REFUTED — GSPMD then chose all-to-all + larger gathers
    # (t_coll 15.0 -> 21.8 s on qwen1.5-32b/train_4k). Keep the constraint.
    if os.environ.get("REPRO_KV_REPLICATE", "1") == "1":
        k = shd(k, "batch", None, "heads_act", "head_dim")
        v = shd(v, "batch", None, "heads_act", "head_dim")

    from repro.kernels import ops as kernel_ops  # lazy: avoids cycle

    # Mask positions are *sequence indices* (dense left-aligned batches);
    # rope positions may be arbitrary (M-RoPE t/h/w streams).
    b, s = x.shape[0], x.shape[1]
    mask_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    out = kernel_ops.flash_attention(
        q, k, v,
        mask_kind=mask_kind, window=window, attn_softcap=attn_softcap,
        qpos=mask_pos, kpos=mask_pos,
    )
    out = shd(out, "batch", "seq", "heads_act", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def apply_cross_attention(
    p: dict,
    x: jax.Array,  # (b, s, d) decoder stream
    kv: jax.Array,  # (b, t, nkv, hd) x2 precomputed, or raw (b, t, d)
) -> jax.Array:
    if isinstance(kv, tuple):
        k, v = kv
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if "bq" in p:
            q = q + p["bq"]
    else:
        q, k, v = _project_qkv(p, x, kv, qk_norm=False)
    out = attend_ref(q, k, v, mask_kind="full")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_kv(p: dict, enc_out: jax.Array):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# Decode path (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def kv_cache_spec(batch, max_len, num_kv_heads, head_dim, dtype, long_context=False):
    shape = (batch, max_len, num_kv_heads, head_dim)
    seq_axis = "kv_long" if long_context else "kv_seq"
    spec = ("dp_batch" if not long_context else None, seq_axis, None, None)
    return jax.ShapeDtypeStruct(shape, dtype), spec


def apply_attention_decode(
    p: dict,
    x: jax.Array,  # (b, 1, d) current-token activations
    cache: dict,  # {'k','v'}: (b, T, nkv, hd)
    index: jax.Array,  # scalar int32 — write position (same for batch)
    *,
    positions: jax.Array,  # (b, 1) or (b, 1, 3)
    rope_type: str,
    rope_theta: float,
    mrope_sections=(),
    qk_norm: bool = False,
    mask_kind: str = "causal",
    window: int = 0,
    attn_softcap: float = 0.0,
    long_context: bool = False,
):
    q, k, v = _project_qkv(p, x, x, qk_norm=qk_norm)
    q = rope_lib.apply_positional(q, positions, rope_type, rope_theta, mrope_sections)
    k = rope_lib.apply_positional(k, positions, rope_type, rope_theta, mrope_sections)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), index, axis=1)
    seq_axis = "kv_long" if long_context else "kv_seq"
    batch_axis = None if long_context else "dp_batch"
    ck = shd(ck, batch_axis, seq_axis, None, None)
    cv = shd(cv, batch_axis, seq_axis, None, None)
    T = ck.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (x.shape[0], T))
    valid = kpos <= index
    # Mask position of the query is its cache slot, not its rope id.
    qpos = jnp.broadcast_to(index.astype(jnp.int32), (x.shape[0], 1))
    out = attend_ref(
        q, ck, cv,
        mask_kind="window" if mask_kind == "window" else "full",
        window=window, attn_softcap=attn_softcap,
        qpos=qpos, kpos=kpos, kv_valid=valid,
        kv_seq_axis=seq_axis if os.environ.get("REPRO_DECODE_SHARDED", "1") == "1" else None,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
