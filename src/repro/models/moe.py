"""Mixture-of-Experts MLP with top-k routing and expert parallelism.

Dense-dispatch formulation (Switch/Mixtral-reference style): tokens are
combined into per-expert buffers with an einsum against the dispatch mask.
The expert dim is sharded over 'model' (EP) — the resharding from the
sequence-sharded residual stream to the expert-sharded buffers lowers to an
all-to-all, which the roofline analysis attributes to the collective term.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import activation, truncated_normal
from repro.parallel.sharding import shd


def init_moe(key, d: int, d_ff: int, num_experts: int, num_layers: int, dtype) -> dict:
    kr, ki, kg, ko = jax.random.split(key, 4)
    out_std = 0.02 / max(1.0, (2.0 * num_layers) ** 0.5)
    return {
        "router": truncated_normal(kr, (d, num_experts), 0.02, jnp.float32),
        "wi": truncated_normal(ki, (num_experts, d, d_ff), 0.02, dtype),
        "wg": truncated_normal(kg, (num_experts, d, d_ff), 0.02, dtype),
        "wo": truncated_normal(ko, (num_experts, d_ff, d), out_std, dtype),
    }


def router_probs(p: dict, x: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (combine (b,s,E) f32, dispatch (b,s,E) bool, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (b,s,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, top_k)  # (b,s,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)
    num_experts = logits.shape[-1]
    dispatch = jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32).sum(axis=-2)  # (b,s,E)
    combine = jnp.einsum("bsk,bske->bse", top_vals, jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32))
    # Switch-style load-balance aux loss.
    frac_tokens = jnp.mean(dispatch, axis=(0, 1)) / top_k  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))  # (E,)
    aux = num_experts * jnp.sum(frac_tokens * frac_probs)
    return combine, dispatch, aux


def apply_moe(p: dict, x: jax.Array, *, top_k: int, act: str,
              impl: str = None) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_loss). impl: 'dense' (reference dispatch) or
    'capacity' (top-C gather per expert — the §Perf hillclimb winner;
    REPRO_MOE_IMPL overrides)."""
    import os

    impl = impl or os.environ.get("REPRO_MOE_IMPL", "dense")
    if impl == "capacity":
        return apply_moe_capacity(p, x, top_k=top_k, act=act)
    combine, dispatch, aux = router_probs(p, x, top_k)
    xin = x  # bf16
    # Dispatch: (E, b, s, d) buffers, expert dim sharded over 'model' (EP).
    expert_in = jnp.einsum("bse,bsd->ebsd", dispatch.astype(xin.dtype), xin)
    expert_in = shd(expert_in, "expert_act", "batch", None, None)
    h = activation(act)(jnp.einsum("ebsd,edf->ebsf", expert_in, p["wg"]))
    h = h * jnp.einsum("ebsd,edf->ebsf", expert_in, p["wi"])
    h = shd(h, "expert_act", "batch", None, None)
    expert_out = jnp.einsum("ebsf,efd->ebsd", h, p["wo"])
    expert_out = shd(expert_out, "expert_act", "batch", None, None)
    y = jnp.einsum("ebsd,bse->bsd", expert_out, combine.astype(xin.dtype))
    y = shd(y, "batch", "seq", None)
    return y, aux.astype(jnp.float32)


def apply_moe_capacity(
    p: dict, x: jax.Array, *, top_k: int, act: str,
    capacity_factor: float = 1.5, block: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Block-local capacity dispatch (§Perf iteration B2).

    Tokens are grouped into seq-blocks ALIGNED TO THE SEQUENCE SHARDS (block
    = 256 == seq_len/16 at train_4k), and each expert takes its top-C tokens
    *within each block* (C = block·top_k/E·cf). All gathers/scatters index
    inside one block, so no token ever crosses a shard boundary — unlike the
    naive global-top-C (iteration B1, refuted: it all-gathered the entire
    token stream). Buffer volume drops from E× to top_k·cf× of the tokens.
    Overflow tokens are dropped per-expert (Switch-style)."""
    b, s, d = x.shape
    combine, dispatch, aux = router_probs(p, x, top_k)  # (b,s,E) f32
    E = dispatch.shape[-1]
    bs = min(block, s)
    nb = s // bs
    assert s % bs == 0, (s, bs)
    cap = int(max(1, min(bs, round(bs * top_k / E * capacity_factor))))
    gates = (combine * dispatch).reshape(b, nb, bs, E)
    gT = jnp.swapaxes(gates, 2, 3)  # (b, nb, E, bs)
    topv, topi = jax.lax.top_k(gT, cap)  # (b, nb, E, C) — block-local ids
    keep = (topv > 0.0).astype(x.dtype)
    xb = x.reshape(b, nb, bs, d)
    xb = shd(xb, "batch", "seq", None, None)
    # gather within blocks: (b, nb, E, C, d)
    xin = jnp.take_along_axis(
        xb[:, :, None, :, :], topi[..., None], axis=3
    )
    xin = xin * keep[..., None]
    xin = shd(xin, "batch", "seq", "expert_act", None, None)
    h = activation(act)(jnp.einsum("bnecd,edf->bnecf", xin, p["wg"]))
    h = h * jnp.einsum("bnecd,edf->bnecf", xin, p["wi"])
    out = jnp.einsum("bnecf,efd->bnecd", h, p["wo"])
    out = out * (topv.astype(x.dtype) * keep)[..., None]
    # scatter-add back inside each block
    bi = jnp.arange(b)[:, None, None, None]
    ni = jnp.arange(nb)[None, :, None, None]
    y = jnp.zeros((b, nb, bs, d), x.dtype).at[bi, ni, topi].add(out)
    y = y.reshape(b, s, d)
    y = shd(y, "batch", "seq", None)
    return y, aux.astype(jnp.float32)
