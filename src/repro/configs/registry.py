"""--arch <id> registry over the 10 assigned architectures (+ paper-native
micro workloads used by the examples)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig

from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from repro.configs.phi3_5_moe import CONFIG as PHI35_MOE
from repro.configs.granite_moe_1b import CONFIG as GRANITE_MOE_1B
from repro.configs.jamba_v0_1 import CONFIG as JAMBA_V01
from repro.configs.qwen2_5_32b import CONFIG as QWEN25_32B
from repro.configs.qwen1_5_32b import CONFIG as QWEN15_32B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.qwen3_1_7b import CONFIG as QWEN3_17B
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_13B
from repro.configs.micro_lm import CONFIG as MICRO_LM, CONFIG_100M as MICRO_LM_100M

ARCHS: Dict[str, ModelConfig] = {
    "whisper-tiny": WHISPER_TINY,
    "qwen2-vl-7b": QWEN2_VL_7B,
    "phi3.5-moe-42b-a6.6b": PHI35_MOE,
    "granite-moe-1b-a400m": GRANITE_MOE_1B,
    "jamba-v0.1-52b": JAMBA_V01,
    "qwen2.5-32b": QWEN25_32B,
    "qwen1.5-32b": QWEN15_32B,
    "gemma2-2b": GEMMA2_2B,
    "qwen3-1.7b": QWEN3_17B,
    "xlstm-1.3b": XLSTM_13B,
    # paper-native single-node workloads (examples / simulator jobs)
    "micro-lm": MICRO_LM,
    "micro-lm-100m": MICRO_LM_100M,
}

ASSIGNED = tuple(k for k in ARCHS if not k.startswith("micro-lm"))


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]
