"""xlstm-1.3b [ssm] — mLSTM:sLSTM 7:1 interleave (xLSTM[7:1]), 48 blocks,
4 heads, no separate FFN in mLSTM blocks (d_ff=0 per assignment; the
projection factors live inside the blocks). [arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=0,
    rope_type="none",
    block_pattern=(
        "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm",
    ),
    source="arXiv:2405.04517 (unverified tier)",
)
