"""gemma2-2b [dense] — alternating local(4k sliding window)/global attention,
attention-logit softcap 50, final-logit softcap 30, head_dim 256, tied
embeddings with sqrt(d) embed scaling. [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("attn_local", "attn"),
    sliding_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2408.00118 (hf tier)",
)
