from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, param_count, active_param_count  # noqa: F401
from repro.configs.registry import ARCHS, ASSIGNED, get_config  # noqa: F401
