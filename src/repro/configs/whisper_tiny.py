"""whisper-tiny [audio] — enc-dec, conv frontend stubbed (input_specs feeds
precomputed (B, 1500, 384) frame embeddings). [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    encoder_layers=4,
    encoder_seq=1500,  # 30 s audio -> 1500 frames after the conv stub
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    rope_type="none",
    learned_pos=True,
    norm_type="layernorm",
    act="gelu",
    tie_embeddings=True,
    input_mode="tokens",  # decoder side; encoder side takes 'frames'
    source="arXiv:2212.04356 (unverified tier)",
)
