"""Model / shape configuration dataclasses and the assigned-shape registry.

Every assigned architecture is expressed as a ``ModelConfig``.  The model
builder (``repro.models.model``) consumes only this dataclass — adding an
architecture means adding one config file, nothing else.

Shapes follow the assignment:
    train_4k     seq_len=4096    global_batch=256   (training step)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (one-token decode, KV=32k)
    long_500k    seq_len=524288  global_batch=1     (long-context decode)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (seq_len, global_batch) input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

# Block kinds usable in ``block_pattern`` (the repeating layer-group unit):
#   'attn'         full causal self-attention + MLP
#   'attn_local'   sliding-window self-attention + MLP (gemma2 local layers)
#   'mamba'        Mamba-1 selective-SSM mixer + MLP
#   'mlstm'        xLSTM matrix-LSTM block (self-contained, no separate MLP)
#   'slstm'        xLSTM scalar-LSTM block (self-contained, gated FFN inside)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention flavour ---
    rope_type: str = "rope"  # 'rope' | 'mrope' | 'none'
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl: (16, 24, 24) half-dims
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0  # gemma2: 50.0 on attention logits
    logit_softcap: float = 0.0  # gemma2: 30.0 on final logits
    sliding_window: int = 0  # window for 'attn_local' blocks

    # --- layer pattern (repeating unit; len must divide num_layers) ---
    block_pattern: Tuple[str, ...] = ("attn",)
    # Which positions inside the repeating unit use a MoE MLP (jamba
    # alternates dense/MoE).  Empty + moe=True -> every MLP is MoE.
    moe_pattern: Tuple[int, ...] = ()

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # 0 -> d_ff
    router_aux_coef: float = 0.01

    # --- mamba (jamba) ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- norm / activation / embeddings ---
    norm_type: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    act: str = "silu"  # 'silu' | 'gelu'
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # whisper: 1500 precomputed frame embeddings
    learned_pos: bool = False  # whisper decoder absolute positions

    # --- modality frontend stub ---
    # 'tokens'      : int32 token ids -> embedding table
    # 'embeddings'  : precomputed (batch, seq, d_model) activations (vlm/audio)
    input_mode: str = "tokens"

    # --- numerics ---
    dtype: str = "bfloat16"

    # --- provenance ---
    source: str = ""

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % len(self.block_pattern) == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern len {len(self.block_pattern)}"
        )
        return self.num_layers // len(self.block_pattern)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_subquadratic(self) -> bool:
        """True if the layer stack is dominated by non-attention mixers
        (eligible for the long_500k shape per the assignment)."""
        n_attn = sum(1 for b in self.block_pattern if b.startswith("attn"))
        return n_attn < len(self.block_pattern) / 2

    def shapes(self) -> Tuple[str, ...]:
        """Assigned shapes applicable to this architecture (skips recorded
        in DESIGN.md §7 / EXPERIMENTS.md §Dry-run)."""
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.is_subquadratic:
            out.append("long_500k")
        return tuple(out)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_unit = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_unit * (2 if self.encoder_layers == 0 else 1) if n_unit > 1 else 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            moe_d_ff=64 if self.moe else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            encoder_layers=1 if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_seq else 0,
            sliding_window=16 if self.sliding_window else 0,
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),
            dtype="float32",
        )


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (exact for this implementation; used by the
    feasibility model before a model is ever instantiated)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.num_heads, cfg.num_kv_heads
    total = 0
    # embeddings
    total += cfg.vocab_size * d
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d
    if cfg.learned_pos:
        total += 32768 * d

    def attn_params() -> int:
        p = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        if cfg.qkv_bias:
            p += nh * hd + 2 * nkv * hd
        if cfg.qk_norm:
            p += 2 * hd
        return p

    def dense_mlp() -> int:
        return 3 * d * cfg.d_ff  # SwiGLU (gate, up, down)

    def moe_mlp() -> int:
        return cfg.num_experts * 3 * d * cfg.expert_d_ff + d * cfg.num_experts

    def mamba_params() -> int:
        d_in = cfg.mamba_expand * d
        dt_rank = max(1, d // 16)
        p = d * 2 * d_in  # in_proj
        p += d_in * cfg.mamba_d_conv + d_in  # conv1d + bias
        p += d_in * (dt_rank + 2 * cfg.mamba_d_state)  # x_proj
        p += dt_rank * d_in + d_in  # dt_proj
        p += d_in * cfg.mamba_d_state + d_in  # A_log, D
        p += d_in * d  # out_proj
        return p

    def mlstm_params() -> int:
        d_in = 2 * d
        dh = d_in // max(cfg.num_heads, 1)
        p = d * 2 * d_in  # up proj (x | z-gate)
        p += 3 * cfg.num_heads * dh * dh  # block-diagonal q,k,v
        p += 2 * d_in * cfg.num_heads + 2 * cfg.num_heads  # i/f gates
        p += d_in  # skip
        p += d_in * d  # down proj
        return p

    def slstm_params() -> int:
        p = 4 * d * d + 4 * d  # i,f,z,o projections
        p += 2 * d * (d * 4 // 3)  # gated FFN up/gate (pf 4/3)
        p += (d * 4 // 3) * d
        return p

    unit_cost = 0
    for i, kind in enumerate(cfg.block_pattern):
        if kind.startswith("attn"):
            unit_cost += attn_params() + 2 * d  # + norms
            if cfg.moe and (not cfg.moe_pattern or i in cfg.moe_pattern):
                unit_cost += moe_mlp()
            else:
                unit_cost += dense_mlp()
        elif kind == "mamba":
            unit_cost += mamba_params() + 2 * d
            if cfg.moe and (not cfg.moe_pattern or i in cfg.moe_pattern):
                unit_cost += moe_mlp()
            else:
                unit_cost += dense_mlp()
        elif kind == "mlstm":
            unit_cost += mlstm_params() + 2 * d
        elif kind == "slstm":
            unit_cost += slstm_params() + 2 * d
        else:
            raise ValueError(kind)
    total += cfg.num_groups * unit_cost
    # encoder (whisper): attn + cross-attn-free encoder blocks, decoder adds
    # cross attention per layer (counted roughly; exact count comes from the
    # instantiated pytree which the checkpoint manager measures).
    if cfg.is_encdec:
        enc = cfg.encoder_layers * (attn_params() + dense_mlp() + 2 * d)
        xattn = cfg.num_layers * (attn_params() + d)
        total += enc + xattn
    total += d  # final norm
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k of num_experts)."""
    if not cfg.moe:
        return param_count(cfg)
    full = param_count(cfg)
    d = cfg.d_model
    per_expert = 3 * d * cfg.expert_d_ff
    n_moe_layers = (
        cfg.num_groups * (len(cfg.moe_pattern) if cfg.moe_pattern else len(cfg.block_pattern))
    )
    inactive = n_moe_layers * (cfg.num_experts - cfg.top_k) * per_expert
    return int(full - inactive)
