"""jamba-v0.1-52b [hybrid] — Mamba:attention 7:1 interleave (attention at
position 4 of each 8-layer block), MoE every other layer (16 experts,
top-2). No positional encoding (Mamba provides order). [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    rope_type="none",
    block_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    moe=True,
    num_experts=16,
    top_k=2,
    moe_pattern=(1, 3, 5, 7),  # every other layer inside the 8-layer unit
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    source="arXiv:2403.19887 (hf tier)",
)
