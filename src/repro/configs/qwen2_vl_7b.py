"""qwen2-vl-7b [vlm] — M-RoPE, dynamic-resolution vision frontend stubbed
(input_specs feeds precomputed patch/text embeddings + 3-D t/h/w position
ids). [arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    rope_type="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # half-dim split over t/h/w streams
    qkv_bias=True,
    input_mode="embeddings",
    source="arXiv:2409.12191 (hf tier)",
)
