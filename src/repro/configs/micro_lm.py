"""Paper-native single-node workloads.

The paper's migratory jobs are single-GPU fine-tunes (ResNet-50 / GPT-2-
scale, 1-40 GB checkpoints). `micro-lm` (~25M) and `micro-lm-100m` (~100M)
are the concrete training jobs used by the end-to-end example
(examples/train_micro_lm.py) and as simulator job payloads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="micro-lm",
    family="dense",
    num_layers=8,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=32000,
    tie_embeddings=True,
    dtype="float32",
    source="paper-native micro workload",
)

CONFIG_100M = ModelConfig(
    name="micro-lm-100m",
    family="dense",
    num_layers=20,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=32000,
    tie_embeddings=True,
    dtype="float32",
    source="paper-native ~100M workload (examples/train_micro_lm.py)",
)
