"""Deterministic, resumable, shard-aware synthetic LM data pipeline.

Tokens follow a noisy affine recurrence (t_{i+1} = (a·t_i + b) mod V with
p_noise random replacements) so a model can actually learn structure — the
end-to-end example's loss demonstrably decreases.

Determinism + resumability: batch(step) is a pure function of (seed, step),
so a job restored from a step-K checkpoint — possibly on a different site
after a migration — resumes the exact token stream with no state file.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    a: int = 31
    b: int = 7
    p_noise: float = 0.1

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        noise = rng.random((B, S)) < self.p_noise
        rand = rng.integers(0, V, size=(B, S))
        for i in range(S):
            nxt = (self.a * toks[:, i] + self.b) % V
            toks[:, i + 1] = np.where(noise[:, i], rand[:, i], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}

    def sharded_batch(self, step: int, mesh: Mesh, pspec: P) -> Dict[str, jax.Array]:
        host = self.batch(step)
        sh = NamedSharding(mesh, pspec)
        return {k: jax.device_put(v, sh) for k, v in host.items()}


def make_global_batch(host_batch: Dict[str, np.ndarray], mesh: Mesh, pspecs) -> Dict[str, jax.Array]:
    out = {}
    for k, v in host_batch.items():
        out[k] = jax.device_put(v, NamedSharding(mesh, pspecs[k]))
    return out
