from repro.checkpoint.serializer import (  # noqa: F401
    serialize_tree, deserialize_tree, tree_bytes, CheckpointPayload,
)
from repro.checkpoint.manager import CheckpointManager, CheckpointInfo  # noqa: F401
