"""Pytree checkpoint serialization with exact byte accounting, int8
compression and delta encoding.

The serialized size IS the feasibility model's S_j — the orchestrator reads
it from CheckpointManager, never from an estimate (DESIGN.md §4). Modes:

  full        raw little-endian buffers (bf16/f32/int32 as stored)
  int8        per-256-block symmetric int8 (kernels/quantize) + f32 scales
              -> ~2x (bf16) / ~4x (f32) smaller, lossy but training-safe
  delta-int8  int8-quantized (x - base) against a base checkpoint the
              destination already holds — the paper §VIII 'compressed model
              deltas' / incremental checkpoints, usually another ~step-
              dependent win on top (identical leaves collapse to zeros).

Format: JSON manifest (paths, shapes, dtypes, mode, block) + concatenated
payload. Works on any pytree of jax/numpy arrays.
"""
from __future__ import annotations

import io
import json
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

BLOCK = 256
MAGIC = b"GRNCKPT1"


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_str(p), np.asarray(x)) for p, x in leaves]


def tree_bytes(tree) -> int:
    """Exact raw (mode='full') checkpoint payload size in bytes."""
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


@dataclass
class CheckpointPayload:
    manifest: Dict[str, Any]
    data: bytes

    @property
    def nbytes(self) -> int:
        return len(self.data) + len(json.dumps(self.manifest).encode())


def _quant_flat(flat: np.ndarray) -> Tuple[bytes, bytes, int]:
    """int8-quantize a flat f32 array (padded to BLOCK)."""
    n = flat.size
    pad = (-n) % BLOCK
    padded = np.pad(flat.astype(np.float32), (0, pad))
    q, s = kops.quantize_int8(jnp.asarray(padded), block=BLOCK)
    return np.asarray(q).tobytes(), np.asarray(s).tobytes(), pad


def serialize_tree(
    tree,
    mode: str = "full",
    base: Optional[Any] = None,
) -> CheckpointPayload:
    assert mode in ("full", "int8", "delta-int8"), mode
    if mode == "delta-int8" and base is None:
        raise ValueError("delta-int8 needs a base checkpoint tree")
    entries: List[Dict[str, Any]] = []
    buf = io.BytesIO()
    base_leaves = dict(_flatten_with_paths(base)) if base is not None else {}
    for path, arr in _flatten_with_paths(tree):
        entry: Dict[str, Any] = {
            "path": path,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "offset": buf.tell(),
        }
        if mode == "full" or not jnp.issubdtype(arr.dtype, jnp.floating):
            raw = arr.tobytes()
            entry["enc"] = "raw"
            buf.write(raw)
        else:
            flat = arr.astype(np.float32).reshape(-1)
            if mode == "delta-int8":
                b = base_leaves.get(path)
                if b is not None and b.shape == arr.shape:
                    flat = flat - b.astype(np.float32).reshape(-1)
                    entry["delta"] = True
            qb, sb, pad = _quant_flat(flat)
            # entropy-code the int8 payload: near-zero deltas collapse
            # (the paper's §VIII 'compressed model deltas', implemented)
            qz = zlib.compress(qb, level=1)
            sz = zlib.compress(sb, level=1)
            entry["enc"] = "int8"
            entry["pad"] = pad
            entry["qlen"] = len(qz)
            entry["q_raw"] = len(qb)
            entry["s_raw"] = len(sb)
            buf.write(qz)
            buf.write(sz)
        entry["nbytes"] = buf.tell() - entry["offset"]
        entries.append(entry)
    manifest = {"mode": mode, "block": BLOCK, "entries": entries}
    return CheckpointPayload(manifest, buf.getvalue())


def deserialize_tree(
    payload: CheckpointPayload,
    like,
    base: Optional[Any] = None,
):
    """Rebuild a pytree with the structure/dtypes of `like` (params template
    or ShapeDtypeStructs). delta-int8 payloads need the same base tree."""
    entries = {e["path"]: e for e in payload.manifest["entries"]}
    base_leaves = dict(_flatten_with_paths(base)) if base is not None else {}
    data = payload.data

    def rebuild(path, leaf):
        p = _path_str(path)
        e = entries[p]
        raw = data[e["offset"]: e["offset"] + e["nbytes"]]
        shape = tuple(e["shape"])
        dtype = np.dtype(e["dtype"])
        if e["enc"] == "raw":
            arr = np.frombuffer(raw, dtype=dtype).reshape(shape)
        else:
            q = np.frombuffer(zlib.decompress(raw[: e["qlen"]]), dtype=np.int8)
            s = np.frombuffer(zlib.decompress(raw[e["qlen"]:]), dtype=np.float32)
            flat = np.asarray(
                kops.dequantize_int8(jnp.asarray(q), jnp.asarray(s), block=payload.manifest["block"])
            )
            if e["pad"]:
                flat = flat[: -e["pad"]] if e["pad"] else flat
            if e.get("delta") and p in base_leaves:
                flat = flat + base_leaves[p].astype(np.float32).reshape(-1)
            arr = flat.reshape(shape).astype(dtype)
        return arr

    return jax.tree_util.tree_map_with_path(rebuild, like)


def to_bytes(payload: CheckpointPayload) -> bytes:
    mjson = json.dumps(payload.manifest).encode()
    head = MAGIC + len(mjson).to_bytes(8, "little")
    return head + mjson + payload.data


def from_bytes(raw: bytes) -> CheckpointPayload:
    assert raw[:8] == MAGIC, "not a GreenFlow checkpoint"
    mlen = int.from_bytes(raw[8:16], "little")
    manifest = json.loads(raw[16: 16 + mlen].decode())
    return CheckpointPayload(manifest, raw[16 + mlen:])
