"""Checkpoint manager: periodic/async saves, retention, restore with
resharding onto a (possibly different) mesh — the migration engine's
storage layer and the source of truth for the feasibility model's S_j.

Layout: <root>/<job>/step_<N>/ checkpoint.bin  (manifest embedded).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import serializer as ser


@dataclass
class CheckpointInfo:
    job: str
    step: int
    path: str
    nbytes: int
    mode: str
    wall_time_s: float


class CheckpointManager:
    def __init__(
        self,
        root: str,
        job: str = "job0",
        *,
        mode: str = "full",
        keep: int = 3,
        async_save: bool = False,
    ):
        self.root = root
        self.job = job
        self.mode = mode
        self.keep = keep
        self.async_save = async_save
        self._history: List[CheckpointInfo] = []
        self._base_cache: Optional[Any] = None  # last full state (delta base)
        self._pending: Optional[threading.Thread] = None
        os.makedirs(self._job_dir(), exist_ok=True)
        self._scan_existing()

    # -- paths ---------------------------------------------------------------
    def _job_dir(self) -> str:
        return os.path.join(self.root, self.job)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self._job_dir(), f"step_{step:08d}")

    def _scan_existing(self):
        for name in sorted(os.listdir(self._job_dir())):
            if name.startswith("step_"):
                p = os.path.join(self._job_dir(), name, "checkpoint.bin")
                if os.path.exists(p):
                    step = int(name.split("_")[1])
                    self._history.append(
                        CheckpointInfo(self.job, step, p, os.path.getsize(p), "?", 0.0)
                    )

    # -- API ------------------------------------------------------------------
    @property
    def latest(self) -> Optional[CheckpointInfo]:
        return self._history[-1] if self._history else None

    @property
    def latest_bytes(self) -> int:
        """S_j for the feasibility model — measured, not estimated."""
        self.wait()
        return self.latest.nbytes if self.latest else 0

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, state, *, mode: Optional[str] = None) -> CheckpointInfo:
        """Serialize + persist `state` (any pytree: params or full train
        state). delta-int8 uses the previous save as base."""
        mode = mode or self.mode
        t0 = time.time()
        host_state = jax.tree.map(np.asarray, state)  # device->host (gather)
        base = self._base_cache if mode == "delta-int8" else None
        if mode == "delta-int8" and base is None:
            mode = "int8"  # first checkpoint has no base

        def _write() -> CheckpointInfo:
            payload = ser.serialize_tree(host_state, mode=mode, base=base)
            d = self._step_dir(step)
            os.makedirs(d, exist_ok=True)
            path = os.path.join(d, "checkpoint.bin")
            with open(path, "wb") as f:
                f.write(ser.to_bytes(payload))
            info = CheckpointInfo(self.job, step, path, os.path.getsize(path), mode, time.time() - t0)
            return info

        self.wait()
        if self.async_save:
            # host_state is already gathered: the device-side training loop
            # can proceed while serialization+IO happen off-thread.
            info = CheckpointInfo(self.job, step, "", 0, mode, 0.0)

            def run():
                done = _write()
                info.path, info.nbytes, info.wall_time_s = done.path, done.nbytes, done.wall_time_s

            self._pending = threading.Thread(target=run, daemon=True)
            self._pending.start()
        else:
            info = _write()
        self._base_cache = host_state
        self._history.append(info)
        self._gc()
        return info

    def restore(
        self,
        like,
        *,
        step: Optional[int] = None,
        shardings=None,
        base: Optional[Any] = None,
    ):
        """Load a checkpoint into the structure of `like`. If `shardings`
        (pytree of NamedSharding) is given, leaves are placed onto the new
        mesh — this is how a migrated job resumes on a *different* slice
        (elastic restore)."""
        self.wait()
        infos = [i for i in self._history if step is None or i.step == step]
        if not infos:
            raise FileNotFoundError(f"no checkpoint for {self.job} step={step}")
        info = infos[-1]
        with open(info.path, "rb") as f:
            payload = ser.from_bytes(f.read())
        if payload.manifest["mode"] == "delta-int8" and base is None:
            base = self._base_cache
        tree = ser.deserialize_tree(payload, like, base=base)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return tree, info

    def _gc(self):
        while len(self._history) > self.keep:
            old = self._history.pop(0)
            shutil.rmtree(os.path.dirname(old.path), ignore_errors=True)

    # -- migration support -----------------------------------------------------
    def export_bytes(self, step: Optional[int] = None) -> bytes:
        self.wait()
        infos = [i for i in self._history if step is None or i.step == step]
        with open(infos[-1].path, "rb") as f:
            return f.read()

    @staticmethod
    def import_bytes(root: str, job: str, step: int, raw: bytes) -> "CheckpointManager":
        mgr = CheckpointManager(root, job)
        d = mgr._step_dir(step)
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, "checkpoint.bin")
        with open(path, "wb") as f:
            f.write(raw)
        mgr._history.append(CheckpointInfo(job, step, path, len(raw), "?", 0.0))
        return mgr
