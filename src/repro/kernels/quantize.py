"""Pallas TPU per-block symmetric int8 quantize / dequantize.

Used by (a) WAN-aware checkpoint compression — the paper's §VIII feasible-
envelope expansion — and (b) cross-pod int8 gradient all-reduce. The op is
bandwidth-bound, so the kernel is a straight VMEM-tiled elementwise pass:
each grid step loads a (ROWS, BLOCK) tile, computes the per-row absmax scale
on the VPU, and writes int8 + scales without re-reading HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256  # quantization group (lane-aligned: 2x128)
ROWS = 64  # rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (ROWS, BLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, None]


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...]  # s is (ROWS, 1), broadcasts over lanes


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_int8_pallas(x: jax.Array, *, block: int = BLOCK, interpret: bool = False):
    """x: flat (n,) with n % (ROWS*block) == 0 -> (q int8 (n,), scales (n/block,))."""
    n = x.shape[0]
    rows = n // block
    grid_rows = min(ROWS, rows)
    assert rows % grid_rows == 0, (rows, grid_rows)
    x2 = x.reshape(rows, block)
    q2, s = pl.pallas_call(
        _quant_kernel,
        grid=(rows // grid_rows,),
        in_specs=[pl.BlockSpec((grid_rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((grid_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((grid_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, block), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x2)
    return q2.reshape(n), s.reshape(rows)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequantize_int8_pallas(q: jax.Array, scale: jax.Array, *, block: int = BLOCK, interpret: bool = False):
    n = q.shape[0]
    rows = n // block
    grid_rows = min(ROWS, rows)
    assert rows % grid_rows == 0, (rows, grid_rows)
    x2 = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // grid_rows,),
        in_specs=[
            pl.BlockSpec((grid_rows, block), lambda i: (i, 0)),
            pl.BlockSpec((grid_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((grid_rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, block), jnp.float32),
        interpret=interpret,
    )(q.reshape(rows, block), scale.reshape(rows, 1))
    return x2.reshape(n)
