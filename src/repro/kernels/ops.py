"""jit'd dispatch wrappers for the Pallas kernels.

On TPU backends the Pallas fast path is selected; on CPU (this container,
incl. every dry-run lowering) the jnp reference executes — identical math,
so tests/smoke runs and the roofline lowering are faithful. Override with
REPRO_ATTN_IMPL / REPRO_QUANT_IMPL in {'pallas','ref','interpret'}.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_lib


def _impl(env: str) -> str:
    forced = os.environ.get(env, "").lower()
    if forced in ("pallas", "ref", "interpret"):
        return forced
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(
    q, k, v, *, mask_kind="causal", window=0, attn_softcap=0.0,
    qpos=None, kpos=None, impl=None,
):
    """GQA attention. qpos/kpos accepted for API parity with the decode path;
    the kernel assumes dense left-aligned sequences (qpos==kpos==arange),
    which is what train/prefill use."""
    impl = impl or _impl("REPRO_ATTN_IMPL")
    if impl == "ref":
        return ref_lib.flash_attention_ref(
            q, k, v, mask_kind=mask_kind, window=window, attn_softcap=attn_softcap
        )
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(
        q, k, v, mask_kind=mask_kind, window=window, attn_softcap=attn_softcap,
        interpret=(impl == "interpret"),
    )


def quantize_int8(x: jax.Array, *, block: int = 256, impl=None) -> Tuple[jax.Array, jax.Array]:
    impl = impl or _impl("REPRO_QUANT_IMPL")
    if impl == "ref":
        return ref_lib.quantize_int8_ref(x, block=block)
    from repro.kernels.quantize import quantize_int8_pallas

    return quantize_int8_pallas(x, block=block, interpret=(impl == "interpret"))


def dequantize_int8(q: jax.Array, scale: jax.Array, *, block: int = 256, impl=None) -> jax.Array:
    impl = impl or _impl("REPRO_QUANT_IMPL")
    if impl == "ref":
        return ref_lib.dequantize_int8_ref(q, scale, block=block)
    from repro.kernels.quantize import dequantize_int8_pallas

    return dequantize_int8_pallas(q, scale, interpret=(impl == "interpret"), block=block)
