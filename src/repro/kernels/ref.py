"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the allclose sweeps in
tests/test_kernels.py, and double as the XLA execution path on non-TPU
backends (CPU container, dry-run lowering).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(
    q: jax.Array,  # (b, s, nh, hd)
    k: jax.Array,  # (b, t, nkv, hd)
    v: jax.Array,  # (b, t, nkv, hd)
    *,
    mask_kind: str = "causal",  # 'causal' | 'window' | 'full'
    window: int = 0,
    attn_softcap: float = 0.0,
) -> jax.Array:
    """Quadratic GQA attention oracle, f32 softmax, dense left-aligned
    positions (qpos/kpos = arange)."""
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    qg = q.reshape(b, s, nkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * (hd ** -0.5)
    if attn_softcap:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    if mask_kind != "full":
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(t)[None, :]
        ok = kpos <= qpos
        if mask_kind == "window" and window > 0:
            ok &= (qpos - kpos) < window
        scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, nh, hd)


def quantize_int8_ref(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization of a flat f32/bf16 array.
    Returns (q int8 (n,), scales f32 (n_blocks,)). n must divide by block
    (callers pad)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    xb = x.astype(jnp.float32).reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    qv = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return qv.reshape(n), scale


def dequantize_int8_ref(q: jax.Array, scale: jax.Array, block: int = 256) -> jax.Array:
    n = q.shape[0]
    qb = q.reshape(n // block, block).astype(jnp.float32)
    return (qb * scale[:, None]).reshape(n)
