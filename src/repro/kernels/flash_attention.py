"""Pallas TPU flash attention (forward), VMEM-tiled online softmax.

TPU-native adaptation (DESIGN.md §8): q tiles of BLOCK_Q=256 rows stream
through VMEM while the kv reduction runs along the innermost grid axis;
(m, l, acc) online-softmax carries live in VMEM scratch across kv steps.
All matmul tile dims are multiples of the 128-lane MXU systolic width.
Supports causal masking, sliding windows (gemma2 local layers), GQA head
grouping via BlockSpec index maps, and tanh soft-capping — fused, so the
masked QK^T logits never round-trip to HBM.

Validated against kernels/ref.py in interpret mode (CPU) by
tests/test_kernels.py; selected automatically on TPU by kernels/ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
NEG_INF = -2.0e38


def _attn_kernel(
    q_ref,  # (1, bq, 1, hd)
    k_ref,  # (1, bk, 1, hd)
    v_ref,  # (1, bk, 1, hd)
    o_ref,  # (1, bq, 1, hd)
    m_scr,  # (bq,) f32  running max
    l_scr,  # (bq,) f32  running denom
    acc_scr,  # (bq, hd) f32  running numerator
    *,
    mask_kind: str,
    window: int,
    attn_softcap: float,
    block_q: int,
    block_k: int,
    n_k_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]  # (bq, hd)
    k = k_ref[0, :, 0, :]  # (bk, hd)
    v = v_ref[0, :, 0, :]
    hd = q.shape[-1]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (hd ** -0.5)  # (bq, bk)
    if attn_softcap:
        s = attn_softcap * jnp.tanh(s / attn_softcap)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    if mask_kind != "full":
        ok = kpos <= qpos
        if mask_kind == "window" and window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])  # (bq, bk)
    l_cur = l_scr[...] * alpha + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mask_kind", "window", "attn_softcap", "block_q", "block_k", "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (b, s, nh, hd)
    k: jax.Array,  # (b, t, nkv, hd)
    v: jax.Array,
    *,
    mask_kind: str = "causal",
    window: int = 0,
    attn_softcap: float = 0.0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, s, nh, hd = q.shape
    t, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    n_q = s // block_q
    n_k = t // block_k

    grid = (b, nh, n_q, n_k)
    kernel = functools.partial(
        _attn_kernel,
        mask_kind=mask_kind, window=window, attn_softcap=attn_softcap,
        block_q=block_q, block_k=block_k, n_k_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, hd), q.dtype),
        scratch_shapes=[
            # (bq,) m, (bq,) l, (bq, hd) acc — f32 online-softmax VMEM carries
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
