"""Pallas TPU kernels for the compute hot-spots (flash attention, int8
quantize) with jnp reference oracles. See ops.py for backend dispatch."""
from repro.kernels.ops import flash_attention, quantize_int8, dequantize_int8  # noqa: F401
