"""Logical-axis sharding layer.

Models annotate activations/params with *logical* axis names; a rule table
maps logical names to mesh axes.  Changing the parallelism strategy (the
hillclimb lever) means swapping the rule table — zero model-code changes.

Baseline strategy (see DESIGN.md §5):
  * activations: batch -> ('pod', 'data'); sequence -> 'model'
    (2-D token sharding: every chip owns a (batch/16 x seq/16) token tile)
  * weights + optimizer state: fully sharded (ZeRO-3/FSDP) over
    ('data', 'model') on the two largest dims, replicated over 'pod'
  * MoE experts: expert dim on 'model' (EP), falls back to FSDP inside
  * KV caches: batch -> 'data', cache sequence -> 'model'
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Optional[object]  # mesh axis name, tuple of names, or None


@dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axis (or tuple, or None=replicated)."""

    rules: Dict[str, Axis] = field(default_factory=dict)

    def get(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        return self.rules.get(name, None)

    def spec(self, *names: Optional[str]) -> P:
        return P(*(self.get(n) for n in names))

    def with_overrides(self, **kw: Axis) -> "AxisRules":
        d = dict(self.rules)
        d.update(kw)
        return AxisRules(d)


# Baseline rule table -------------------------------------------------------
DEFAULT_RULES = AxisRules(
    {
        # activations
        "batch": ("pod", "data"),
        "dp_batch": "data",  # batch sharding that must not touch 'pod'
        "seq": "model",
        "embed_act": None,  # activation feature dim
        "heads_act": None,
        "kv_seq": "model",  # KV-cache sequence dim (decode)
        "kv_long": ("data", "model"),  # long-context cache sequence (batch=1)
        "expert_act": "model",  # dispatched MoE token buffers
        "vocab_act": None,
        # params (FSDP: both biggest dims sharded; ZeRO-3 gathers per layer)
        "embed": "data",
        "vocab": "model",
        "heads": "model",
        "kv_heads": None,
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "conv": None,
        "state": None,
        "layers": None,  # stacked scan dim — never sharded
    }
)

_tls = threading.local()


def set_rules(rules: AxisRules) -> None:
    _tls.rules = rules


def get_rules() -> AxisRules:
    return getattr(_tls, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def use_rules(rules: AxisRules):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def _mesh_axis_names() -> Tuple[str, ...]:
    # 1) explicitly-installed mesh (our own context, survives exotic tracing)
    forced = getattr(_tls, "mesh_axes", None)
    if forced:
        return forced
    # 2) `with mesh:` context (works under jit tracing too)
    from jax.interpreters import pxla

    env_mesh = pxla.thread_resources.env.physical_mesh
    if not env_mesh.empty:
        return tuple(env_mesh.axis_names)
    # 3) abstract mesh (explicit-axis-type meshes; version-gated in
    # repro.parallel.compat — the API is absent at the jax pin)
    from repro.parallel.compat import abstract_mesh_axis_names

    return abstract_mesh_axis_names()


@contextlib.contextmanager
def force_mesh_axes(names: Tuple[str, ...]):
    """Declare the mesh axes in effect (for code paths where the physical
    mesh context is not visible, e.g. AOT lowering helpers)."""
    prev = getattr(_tls, "mesh_axes", None)
    _tls.mesh_axes = tuple(names)
    try:
        yield
    finally:
        _tls.mesh_axes = prev


def _prune(axis: Axis, present: Tuple[str, ...]) -> Axis:
    """Drop mesh axes that don't exist in the active mesh (e.g. 'pod' on the
    single-pod mesh) so rule tables are mesh-shape agnostic."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in present)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in present else None


def logical_spec(*names: Optional[str]) -> P:
    """PartitionSpec for the given logical axis names under current rules,
    pruned to the axes present in the currently-entered mesh. The sentinel
    '*' maps to PartitionSpec.UNCONSTRAINED (partial constraints)."""
    rules = get_rules()
    present = _mesh_axis_names()

    def one(n):
        if n == "*":
            return P.UNCONSTRAINED
        return _prune(rules.get(n), present)

    axes = [one(n) for n in names]
    # a mesh axis may appear at most once: keep the first occurrence
    seen = set()
    out = []
    for a in axes:
        flat = a if isinstance(a, tuple) else (a,) if (a is not None and a is not P.UNCONSTRAINED) else ()
        if any(f in seen for f in flat):
            out.append(None)
            continue
        seen.update(flat)
        out.append(a)
    return P(*out)


def _mesh_axis_sizes() -> Dict[str, int]:
    from jax.interpreters import pxla

    env_mesh = pxla.thread_resources.env.physical_mesh
    if not env_mesh.empty:
        return dict(zip(env_mesh.axis_names, env_mesh.devices.shape))
    return {}


def shd(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names. Shape-aware: axes
    whose mesh size does not divide the dim (e.g. a size-1 decode seq dim)
    are dropped BEFORE duplicate resolution, so later logical names (like
    'mlp_act') can claim the mesh axis. No-op outside a mesh."""
    present = _mesh_axis_names()
    if not present:
        return x
    rules = get_rules()
    sizes = _mesh_axis_sizes()
    axes = []
    for n, dim in zip(names, x.shape):
        if n == "*":
            axes.append(P.UNCONSTRAINED)
            continue
        a = _prune(rules.get(n), present)
        if sizes:
            a = _divisible(a, dim, sizes)
        axes.append(a)
    axes += [None] * (len(x.shape) - len(axes))
    seen = set()
    out = []
    for a in axes:
        flat = a if isinstance(a, tuple) else (a,) if (a is not None and a is not P.UNCONSTRAINED) else ()
        if any(f in seen for f in flat):
            out.append(None)
            continue
        seen.update(flat)
        out.append(a)
    return jax.lax.with_sharding_constraint(x, P(*out))


def batch_axes() -> P:
    return logical_spec("batch")


# ---------------------------------------------------------------------------
# Parameter partition rules (by pytree path)
# ---------------------------------------------------------------------------
# Params are nested dicts.  Rules are (regex over '/'-joined path) ->
# logical axis names per dimension.  First match wins.  Scanned stacks have a
# leading 'layers' dim which is handled automatically (rank mismatch pads
# 'layers' at dim 0).

PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/table$", ("vocab", "embed")),
    (r"unembed/table$", ("embed", "vocab")),
    (r"pos_embed/table$", (None, "embed")),
    # attention
    (r"(attn|cross_attn)/wq$", ("embed", "heads", "head_dim")),
    (r"(attn|cross_attn)/wk$", ("embed", "kv_heads", "head_dim")),
    (r"(attn|cross_attn)/wv$", ("embed", "kv_heads", "head_dim")),
    (r"(attn|cross_attn)/wo$", ("heads", "head_dim", "embed")),
    (r"(attn|cross_attn)/bq$", ("heads", "head_dim")),
    (r"(attn|cross_attn)/b[kv]$", ("kv_heads", "head_dim")),
    (r"(attn|cross_attn)/(q_norm|k_norm)$", ("head_dim",)),
    # dense mlp
    (r"mlp/w(i|g)$", ("embed", "mlp")),
    (r"mlp/wo$", ("mlp", "embed")),
    # moe
    (r"moe/router$", ("embed", "expert")),
    (r"moe/w(i|g)$", ("expert", "embed", None)),
    (r"moe/wo$", ("expert", None, "embed")),
    # mamba
    (r"mamba/in_proj$", ("embed", "mlp")),
    (r"mamba/conv_w$", ("conv", "mlp")),
    (r"mamba/conv_b$", ("mlp",)),
    (r"mamba/x_proj$", ("mlp", None)),
    (r"mamba/dt_proj$", (None, "mlp")),
    (r"mamba/dt_bias$", ("mlp",)),
    (r"mamba/A_log$", ("mlp", "state")),
    (r"mamba/D$", ("mlp",)),
    (r"mamba/out_proj$", ("mlp", "embed")),
    # xlstm (mLSTM inner dim d_in uses 'mlp'; heads are few — unsharded)
    (r"mlstm/w_up$", ("embed", "mlp")),
    (r"mlstm/w(q|k|v)$", (None, "embed2", None)),
    (r"mlstm/w(i|f|o)$", ("mlp", None)),
    (r"mlstm/b(i|f|o)$", (None,)),
    (r"mlstm/skip$", ("mlp",)),
    (r"mlstm/w_down$", ("mlp", "embed")),
    (r"slstm/w(i|f|z|o)$", ("embed", "embed2")),
    (r"slstm/r(i|f|z|o)$", ("heads", "head_dim", "head_dim")),
    (r"slstm/b(i|f|z|o)$", ("embed2",)),
    (r"slstm/ffn_w(i|g)$", ("embed", "mlp")),
    (r"slstm/ffn_wo$", ("mlp", "embed")),
    # norms / scalars
    (r"(norm|norm1|norm2|norm3|final_norm|ln)/(scale|bias)$", ("embed",)),
    (r".*", ()),  # default: replicated
)

# 'embed2' logical axis: second d_model-sized dim of square sLSTM weights —
# shard over 'model' to spread the 4x d^2 matrices.
DEFAULT_RULES = DEFAULT_RULES.with_overrides(embed2="model")


def _axis_sizes(mesh: Optional[Mesh]):
    if mesh is not None:
        return dict(zip(mesh.axis_names, mesh.devices.shape))
    return {}


def _divisible(axis: Axis, dim: int, sizes) -> Axis:
    """Drop a sharding axis whose size does not divide the dim — pjit
    argument shardings must be even (e.g. vocab 49155 over 16)."""
    if axis is None or not sizes:
        return axis
    axes = axis if isinstance(axis, tuple) else (axis,)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    if n and dim % n == 0:
        return axis
    # try the leading sub-tuple
    if isinstance(axis, tuple) and len(axis) > 1:
        return _divisible(axis[:-1], dim, sizes)
    return None


def _spec_for_path(path: str, shape, rules: AxisRules, present, sizes) -> P:
    ndim = len(shape)
    for pattern, names in PARAM_RULES:
        if re.search(pattern, path):
            names_l = list(names)
            if len(names_l) < ndim:  # leading stacked 'layers'/group dims
                names_l = [None] * (ndim - len(names_l)) + names_l
            elif len(names_l) > ndim:
                names_l = names_l[-ndim:] if ndim else []
            axes = [_prune(rules.get(n), present) for n in names_l]
            axes = [_divisible(a, d, sizes) for a, d in zip(axes, shape)]
            # a mesh axis may appear at most once per spec
            seen = set()
            out = []
            for a in axes:
                flat = a if isinstance(a, tuple) else (a,) if a else ()
                if any(f in seen for f in flat):
                    out.append(None)
                    continue
                seen.update(flat)
                out.append(a)
            return P(*out)
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(params, rules: Optional[AxisRules] = None, mesh: Optional[Mesh] = None):
    """Build a PartitionSpec pytree for a param pytree (leaves may be arrays
    or ShapeDtypeStructs)."""
    rules = rules or get_rules()
    if mesh is not None:
        present = tuple(mesh.axis_names)
    else:
        present = _mesh_axis_names() or ("data", "model")

    sizes = _axis_sizes(mesh)

    def f(path, leaf):
        return _spec_for_path(_path_str(path), tuple(leaf.shape), rules, present, sizes)

    return jax.tree_util.tree_map_with_path(f, params)


def named_shardings(tree_pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
