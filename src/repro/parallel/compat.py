"""jax version-compatibility shims, consolidated in one place.

The repo pins jax 0.4.37, which predates two `jax.sharding` APIs newer
code paths want — both verified absent at the pin:

* ``jax.sharding.get_abstract_mesh`` (explicit-axis-type mesh contexts),
* ``jax.sharding.AxisType`` (the ``axis_types=`` argument of
  ``jax.make_mesh``).

Each shim probes once at import and degrades to the pinned-version
behaviour.  Callers (``parallel/sharding.py``, ``launch/mesh.py``) use
these helpers instead of scattering ``getattr`` gates; when the pin
moves past both APIs, this module is the single file to delete.
"""
from __future__ import annotations

from typing import Tuple

import jax

#: ``jax.sharding.get_abstract_mesh`` or None at the 0.4.x pin.
_GET_ABSTRACT_MESH = getattr(jax.sharding, "get_abstract_mesh", None)

#: ``jax.sharding.AxisType`` or None at the 0.4.x pin.
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def abstract_mesh_axis_names() -> Tuple[str, ...]:
    """Axis names of the active abstract mesh (explicit-axis-type mesh
    contexts), or ``()`` when there is none — including on jax versions
    that predate ``get_abstract_mesh`` entirely."""
    if _GET_ABSTRACT_MESH is None:
        return ()
    am = _GET_ABSTRACT_MESH()
    if am is not None and am.shape_tuple:
        return tuple(name for name, _ in am.shape_tuple)
    return ()


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the jax version
    supports them (``jax.sharding.AxisType`` is absent at the pin)."""
    if _AXIS_TYPE is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(_AXIS_TYPE.Auto,) * len(axes))


__all__ = ["abstract_mesh_axis_names", "make_mesh"]
