from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    set_rules,
    get_rules,
    shd,
    logical_spec,
    param_pspecs,
    batch_axes,
    named_shardings,
    force_mesh_axes,
    use_rules,
)
