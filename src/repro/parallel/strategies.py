"""Named sharding strategies — the §Perf hillclimb levers.

Each strategy is a complete AxisRules table; swap with --rules in
launch/dryrun.py (zero model-code changes, see parallel/sharding.py).

  baseline     2-D token sharding (batch x seq) + full ZeRO-3 FSDP over
               (data x model); K/V gathered over 'model' per attention.
  tp-ffn       Megatron-style: sequence replicated inside the block, FFN
               activations sharded on 'model' (d_ff), attention heads on
               'model' where divisible; weights FSDP only over 'data'.
               Trades the per-layer weight all-gather over 256 chips for
               activation all-reduces over 16.
  small-repl   baseline, but small recurrent weights (sLSTM/mLSTM inner
               maps, norms) replicated instead of sharded — kills the
               per-timestep re-gather inside sequential scans.
  seq-data     long-context: residual sequence sharded over ('data','model')
               jointly (batch=1 decode / prefill where batch < data axis).
"""
from __future__ import annotations

from typing import Dict

from repro.parallel.sharding import AxisRules, DEFAULT_RULES

STRATEGIES: Dict[str, AxisRules] = {}

STRATEGIES["baseline"] = DEFAULT_RULES

STRATEGIES["tp-ffn"] = DEFAULT_RULES.with_overrides(
    seq=None,  # sequence replicated inside blocks
    mlp_act="model",  # FFN hidden sharded (Megatron column-parallel)
    heads_act="model",  # attention heads sharded where divisible
    # weights: TP dims live on 'model' persistently; FSDP only over 'data'
    mlp="model",
    heads="model",
    embed="data",
)

STRATEGIES["small-repl"] = DEFAULT_RULES.with_overrides(
    embed2=None,  # sLSTM square maps replicated
    conv=None,
    state=None,
)

# Decode/serving: weights stay resident TP-sharded — FFN on d_ff, attention
# on head_dim (128/16 always divides, unlike head counts), unembed on vocab —
# with matching activation constraints so GSPMD never all-gathers a weight:
# only KB-scale activation all-reduces move per token. KV cache stays
# (batch@data, seq@model) with partial softmax.
STRATEGIES["decode-tp"] = DEFAULT_RULES.with_overrides(
    embed=None,          # weight d_model dims replicated (activations tiny)
    mlp="model",         # FFN column-parallel
    mlp_act="model",
    head_dim="model",    # attention sliced on head_dim
    heads=None,
    vocab="model",
    embed2="model",
)

# MoE with small per-expert FFNs (granite: 50M params/layer total): keep
# expert weights replicated and dispatch block-locally — zero MoE
# collectives, top_k·cf× (not E×) activation buffers. Pair with
# REPRO_MOE_IMPL=capacity.
STRATEGIES["moe-blocked"] = DEFAULT_RULES.with_overrides(
    expert=None,
    expert_act=None,
)

STRATEGIES["seq-data"] = DEFAULT_RULES.with_overrides(
    seq=("data", "model"),
    batch=None,
    dp_batch=None,
)


def get_strategy(name: str) -> AxisRules:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; have {sorted(STRATEGIES)}")
    return STRATEGIES[name]
