"""AdamW with mixed precision (bf16 compute params / f32 master+moments),
global-norm clipping and weight decay.

Pure pytree functions. ZeRO-1/3 comes for free: optimizer-state leaves
mirror param structure, so `parallel.sharding.param_pspecs` shards master,
m and v exactly like the params (fully sharded over data x model); XLA
inserts the per-layer gathers inside the scan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def _is_float(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def init_opt_state(params) -> Dict[str, Any]:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": master,
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params,
    grads,
    state: Dict[str, Any],
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params (compute dtype), new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = cfg.lr * lr_scale
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, mp):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * mp
        mp2 = mp - lr * delta
        return m2, v2, mp2

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), new_master, params
    )
    new_state = {"step": step, "master": new_master, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
