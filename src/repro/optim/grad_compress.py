"""Gradient compression for the cross-pod (DCN) data-parallel reduction —
the paper's §VIII 'periodic synchronization of compressed model deltas'
applied to the gradient path.

Two entry points:

  compress_roundtrip(grads)
      int8 quantize->dequantize round trip (per-256 block, kernels/quantize).
      Numerically models the compression loss anywhere (pjit path); the
      beyond-paper dry-run variant uses it inside shard_map so the DCN
      all-reduce moves int8+scales instead of bf16 (4-8x fewer bytes).

  crosspod_allgather_mean_int8(grads, axis_name='pod')
      Inside shard_map over the pod axis: quantize local grads, all_gather
      the int8 payload + scales across pods, dequantize and average.
      DCN bytes per pod = (P-1)/P · size/4 of the bf16 ring all-reduce.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

BLOCK = 256


def _quant_leaf(g: jax.Array):
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    q, s = kops.quantize_int8(flat, block=BLOCK)
    return q, s, n


def _dequant_leaf(q, s, n, shape, dtype):
    flat = kops.dequantize_int8(q, s, block=BLOCK)
    return flat[:n].reshape(shape).astype(dtype)


def compress_roundtrip(grads: Any) -> Any:
    """Quantize->dequantize every floating leaf (models int8 DCN traffic)."""

    def f(g):
        if not jnp.issubdtype(g.dtype, jnp.floating) or g.size < BLOCK:
            return g
        q, s, n = _quant_leaf(g)
        return _dequant_leaf(q, s, n, g.shape, g.dtype)

    return jax.tree.map(f, grads)


def crosspod_allgather_mean_int8(grads: Any, axis_name: str = "pod") -> Any:
    """Per-pod int8 all-gather + local dequant/average. Call inside
    shard_map(..., mesh axis `axis_name`)."""
    npods = jax.lax.axis_size(axis_name)

    def f(g):
        if not jnp.issubdtype(g.dtype, jnp.floating) or g.size < BLOCK:
            return jax.lax.pmean(g, axis_name)
        q, s, n = _quant_leaf(g)
        qs = jax.lax.all_gather(q, axis_name)  # (npods, n_padded) int8 on DCN
        ss = jax.lax.all_gather(s, axis_name)
        acc = jnp.zeros(g.size + (-g.size) % BLOCK, jnp.float32)
        for p in range(npods):
            acc = acc + kops.dequantize_int8(qs[p], ss[p], block=BLOCK)
        return (acc[: g.size] / npods).reshape(g.shape).astype(g.dtype)

    return jax.tree.map(f, grads)
