from repro.optim.adamw import AdamWConfig, init_opt_state, apply_updates, global_norm  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
from repro.optim.grad_compress import compress_roundtrip, crosspod_allgather_mean_int8  # noqa: F401
