"""Preemption-aware trainer with checkpoint/restart and migration hooks.

This is the single-job execution engine that the paper's orchestrator
manages: it trains until (a) step budget, (b) a preemption signal (renewable
window closing / node failure), or (c) a migration order, checkpointing at
a bounded interval so at most `save_every` steps are ever lost — the
fault-tolerance contract for 1000+-node deployments.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMDataset
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainStepConfig, make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 200
    save_every: int = 50
    ckpt_mode: str = "full"  # 'full' | 'int8' | 'delta-int8'
    log_every: int = 25
    seed: int = 0
    step_cfg: TrainStepConfig = field(default_factory=TrainStepConfig)


class Trainer:
    def __init__(
        self,
        model: Model,
        dataset: SyntheticLMDataset,
        ckpt: CheckpointManager,
        cfg: TrainerConfig,
        *,
        preempt_signal: Optional[Callable[[int], bool]] = None,
    ):
        self.model = model
        self.dataset = dataset
        self.ckpt = ckpt
        self.cfg = cfg
        self.preempt_signal = preempt_signal or (lambda step: False)
        self.train_step = jax.jit(make_train_step(model, cfg.step_cfg))
        self.params = None
        self.opt_state = None
        self.step = 0
        self.history: List[Dict[str, float]] = []

    # -- state ---------------------------------------------------------------
    def init_state(self):
        key = jax.random.PRNGKey(self.cfg.seed)
        self.params = self.model.init(key)
        self.opt_state = init_opt_state(self.params)
        self.step = 0

    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": np.int32(self.step)}

    def restore(self, shardings=None):
        """Resume from the newest checkpoint (crash restart or migration
        arrival). Returns the restored step."""
        if self.params is None:
            self.init_state()
        like = self.state_tree()
        tree, info = self.ckpt.restore(like, shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = int(tree["step"])
        return self.step

    def save(self):
        self.ckpt.save(self.step, self.state_tree(), mode=self.cfg.ckpt_mode)

    # -- loop ----------------------------------------------------------------
    def run(self, max_steps: Optional[int] = None) -> Dict[str, Any]:
        """Train until budget or preemption. Returns a status dict."""
        if self.params is None:
            self.init_state()
        budget = min(
            self.cfg.total_steps,
            self.step + (max_steps if max_steps is not None else self.cfg.total_steps),
        )
        status = "done"
        t0 = time.time()
        while self.step < budget:
            if self.preempt_signal(self.step):
                self.save()
                status = "preempted"
                break
            batch = self.dataset.batch(self.step)
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            self.step += 1
            if self.step % self.cfg.log_every == 0 or self.step == budget:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = self.step
                self.history.append(row)
            if self.step % self.cfg.save_every == 0:
                self.save()
        if status == "done" and self.step >= self.cfg.total_steps:
            self.save()
        return {
            "status": status,
            "step": self.step,
            "elapsed_s": time.time() - t0,
            "loss": self.history[-1]["loss"] if self.history else float("nan"),
            "ckpt_bytes": self.ckpt.latest_bytes,
        }
