"""jit-able train/eval steps: loss -> grads -> (optional int8 DCN
compression) -> AdamW. Pure functions of (params, opt_state, batch)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, apply_updates
from repro.optim.grad_compress import compress_roundtrip
from repro.optim.schedule import cosine_schedule


@dataclass(frozen=True)
class TrainStepConfig:
    opt: AdamWConfig = AdamWConfig()
    remat_policy: str = "full"
    grad_compress: bool = False  # int8 round-trip on grads (cross-pod DCN model)
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatch: int = 0  # >0: gradient accumulation over seq-of-microbatches


def make_train_step(model: Model, cfg: TrainStepConfig) -> Callable:
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat_policy=cfg.remat_policy)
        return loss, metrics

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch):
        if cfg.microbatch and cfg.microbatch > 1:
            n = cfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                (l, m), g = grads_of(params, mb)
                gsum, lsum = carry
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), m

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), ms = jax.lax.scan(acc_fn, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
            metrics = jax.tree.map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = grads_of(params, batch)
        if cfg.grad_compress:
            grads = compress_roundtrip(grads)
        lr_scale = cosine_schedule(
            opt_state["step"] + 1, warmup=cfg.warmup_steps, total=cfg.total_steps
        )
        params, opt_state, om = apply_updates(params, grads, opt_state, cfg.opt, lr_scale)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_eval_step(model: Model, remat_policy: str = "none") -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, remat_policy=remat_policy)
        return {"loss": loss, **metrics}

    return eval_step
