from repro.train.train_step import make_train_step, make_eval_step, TrainStepConfig  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig  # noqa: F401
