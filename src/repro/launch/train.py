"""Training launcher.

Two modes:
  * real execution (CPU demo / TPU): builds the model, synthetic data
    pipeline, checkpoint manager and preemption-aware trainer, and runs
    `--steps` steps. Reduced configs (`--smoke`) run anywhere.
  * AOT lowering of the production config against the production mesh is
    handled by dryrun.py — this launcher is the *runtime* path.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch micro-lm --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax

from repro.configs import SHAPES, get_config
from repro.core.traces import generate_trace
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMDataset
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro-lm")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--ckpt-mode", default="full", choices=["full", "int8", "delta-int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--preempt-with-trace", action="store_true",
                    help="preempt when the site's renewable window closes")
    ap.add_argument("--scenario", default=None,
                    help="drive the preemption trace from a registered "
                         "scenario (see repro.core.scenarios)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    data = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)
    root = args.ckpt_dir or tempfile.mkdtemp(prefix="greenflow_ckpt_")
    ckpt = CheckpointManager(root, job=cfg.name, mode=args.ckpt_mode)

    preempt = None
    if args.scenario:
        from repro.core.scenarios import get_scenario

        scn = get_scenario(args.scenario)
        trace = scn.build_traces()[0]
        print(f"[train] scenario {scn.name!r}: {scn.description}")
        # 1 training step ~ 1 simulated minute, clocked from the site's
        # first surplus window so the demo trains until it closes
        t0 = trace.windows[0].start_s if trace.windows else 0.0
        preempt = lambda step: not trace.active(t0 + step * 60.0)
    elif args.preempt_with_trace:
        trace = generate_trace(1, days=1, seed=0)[0]
        preempt = lambda step: not trace.active(step * 60.0)

    trainer = Trainer(
        model, data, ckpt,
        TrainerConfig(
            total_steps=args.steps,
            save_every=args.save_every,
            ckpt_mode=args.ckpt_mode,
            step_cfg=TrainStepConfig(
                opt=AdamWConfig(lr=args.lr),
                grad_compress=args.grad_compress,
                total_steps=max(args.steps, 1),
                warmup_steps=max(args.steps // 10, 1),
            ),
        ),
        preempt_signal=preempt,
    )
    if args.resume:
        try:
            step = trainer.restore()
            print(f"[train] resumed from step {step}")
        except FileNotFoundError:
            trainer.init_state()
    status = trainer.run()
    print("[train] history:")
    for row in trainer.history:
        print("  ", json.dumps(row))
    print("[train] status:", json.dumps(status))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
