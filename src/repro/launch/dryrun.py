import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_UNROLL_SCAN", "1")  # full-cost accounting (see
# models/transformer.scan_or_unroll): XLA counts While bodies once.
"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN item 3) plus the
orchestration plan preview.

For every (architecture × assigned shape × mesh) cell:
  jax.jit(step).lower(**ShapeDtypeStructs).compile()
must succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh.
We record memory_analysis() (fits-in-HBM proof), cost_analysis() (FLOPs /
bytes for §Roofline) and the collective bytes parsed from the compiled HLO
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
into a JSON artifact per cell that benchmarks/roofline.py consumes.

``--plan`` is the *orchestration* dry-run: it materializes a registered
scenario at a chosen sim-time, builds the same ClusterState snapshot the
simulator hands to policies (one shared constructor,
``repro.core.state.ClusterState.build``) and prints the typed actions a
policy would emit — a what-would-happen preview without running the sim.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --plan --scenario flaky-wan \
      --policy feasibility-aware --at-hour 36
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.parallel.sharding import (
    AxisRules, DEFAULT_RULES, force_mesh_axes, logical_spec, param_pspecs, use_rules,
)
from repro.train.train_step import TrainStepConfig, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts")

# TPU v5e constants (assignment §ROOFLINE)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s/link

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes of every collective in the compiled HLO, keyed by op
    kind (output-shape bytes — bytes received per device)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_types, single_type, kind = m.group(1), m.group(2), m.group(3)
        type_str = tuple_types if tuple_types is not None else single_type
        # skip the -done ops (shapes already counted at -start)
        pre = hlo_text[max(0, m.start() - 160): m.start()]
        if "-done" in hlo_text[m.start(): m.end()]:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(type_str or "")
    return out


def _peak_bytes(mem) -> Optional[int]:
    """Per-device peak HBM: the runtime stat when jaxlib exposes it, else
    the conservative sum of live buffer classes (args + outputs + temps +
    code, minus donated aliases)."""
    peak = getattr(mem, "peak_memory_in_bytes", None)
    if peak is not None:
        return peak
    parts = [getattr(mem, a, 0) or 0 for a in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes")]
    if not any(parts):
        return None
    return sum(parts) - (getattr(mem, "alias_size_in_bytes", 0) or 0)


def _pspec_tree(logical_tree, mesh):
    """Convert a logical-axis-name pspec tree to PartitionSpecs."""
    def is_leaf(x):
        return isinstance(x, tuple) and (not x or not isinstance(x[0], (tuple, dict)))

    def conv(names):
        return logical_spec(*names)

    with force_mesh_axes(tuple(mesh.axis_names)):
        return jax.tree.map(conv, logical_tree, is_leaf=is_leaf)


def _shardings(tree_pspecs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _compile_once(
    cfg,
    shape_name: str,
    mesh,
    rules: AxisRules,
    *,
    remat_policy: str,
    grad_compress: bool,
    unroll: bool,
):
    """Lower+compile one step function for `cfg` on `mesh`; returns
    (flops, bytes, collectives dict, mem, compiled)."""
    model = build_model(cfg)
    shape = SHAPES[shape_name]
    prev = os.environ.get("REPRO_UNROLL_SCAN")
    os.environ["REPRO_UNROLL_SCAN"] = "1" if unroll else "0"
    try:
        with use_rules(rules), force_mesh_axes(tuple(mesh.axis_names)):
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            p_shard = _shardings(param_pspecs(params_sds, rules, mesh), mesh)
            batch_sds, batch_logical = model.input_specs(shape_name)
            b_shard = _shardings(_pspec_tree(batch_logical, mesh), mesh)

            if shape.kind == "train":
                opt_sds = jax.eval_shape(init_opt_state, params_sds)
                o_shard = _shardings(param_pspecs(opt_sds, rules, mesh), mesh)
                step_cfg = TrainStepConfig(
                    remat_policy=remat_policy, grad_compress=grad_compress
                )
                fn = make_train_step(model, step_cfg)
                jfn = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                              donate_argnums=(0, 1))
                args = (params_sds, opt_sds, batch_sds)
            elif shape.kind == "prefill":
                def fwd(params, batch):
                    logits, aux = model.forward(params, batch, remat_policy=remat_policy)
                    return logits

                jfn = jax.jit(fwd, in_shardings=(p_shard, b_shard))
                args = (params_sds, batch_sds)
            else:  # decode
                long = shape_name == "long_500k"
                cache_sds = batch_sds.pop("cache")
                cache_shard = b_shard.pop("cache")

                def decode(params, cache, rest):
                    return model.decode_step(params, cache, dict(rest), long_context=long)

                jfn = jax.jit(decode, in_shardings=(p_shard, cache_shard, b_shard),
                              donate_argnums=(1,))
                args = (params_sds, cache_sds, batch_sds)

            with mesh:
                lowered = jfn.lower(*args)
                compiled = lowered.compile()
                mem = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):  # older jax: per-program list
                    cost = cost[0] if cost else {}
    finally:
        if prev is None:
            os.environ.pop("REPRO_UNROLL_SCAN", None)
        else:
            os.environ["REPRO_UNROLL_SCAN"] = prev
    coll = collective_bytes(compiled.as_text())
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    return flops, bytes_accessed, coll, mem, compiled


def _reduced_depth(cfg, k: int):
    """Same arch with k layer-groups (pattern preserved)."""
    import dataclasses as _dc

    kw = {"num_layers": len(cfg.block_pattern) * k}
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return _dc.replace(cfg, name=f"{cfg.name}@g{k}", **kw)


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules: Optional[AxisRules] = None,
    remat_policy: str = "full",
    grad_compress: bool = False,
    save_artifact: bool = True,
    artifact_dir: Optional[str] = None,
    tag: str = "baseline",
) -> Dict[str, Any]:
    """Lower + compile one (arch × shape × mesh) cell; return the record."""
    cfg = get_config(arch)
    if shape_name not in cfg.shapes():
        return {
            "arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
            "status": "SKIP",
            "reason": "long_500k requires sub-quadratic attention (DESIGN.md §7)",
        }
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or DEFAULT_RULES
    t0 = time.time()
    ck = dict(remat_policy=remat_policy, grad_compress=grad_compress)

    # 1) REQUIRED compile proof + memory analysis: the full production model
    #    (scanned layer stack — memory-faithful).
    _, _, _, mem, compiled = _compile_once(cfg, shape_name, mesh, rules, unroll=False, **ck)
    # 2) Exact cost extrapolation from two reduced-depth unrolled compiles:
    #    cost(G) = fixed + G*body  (see module docstring).
    G = cfg.num_groups
    f1, b1, c1, _, _ = _compile_once(_reduced_depth(cfg, 1), shape_name, mesh, rules, unroll=True, **ck)
    f2, b2, c2, _, _ = _compile_once(_reduced_depth(cfg, 2), shape_name, mesh, rules, unroll=True, **ck)
    flops = f1 + (f2 - f1) * (G - 1)
    bytes_accessed = b1 + (b2 - b1) * (G - 1)
    coll: Dict[str, float] = {}
    for kind in set(c1) | set(c2):
        v1, v2 = c1.get(kind, 0), c2.get(kind, 0)
        coll[kind] = float(v1 + (v2 - v1) * (G - 1))
    n_chips = mesh.size
    coll_total = float(sum(coll.values()))

    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "tag": tag,
        "status": "OK",
        "n_chips": n_chips,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_compile_s": round(time.time() - t0, 1),
        "num_groups": cfg.num_groups,
        # cost_analysis is per-device under SPMD; extrapolated over depth
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": _peak_bytes(mem),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        # roofline terms (seconds, per §ROOFLINE — per-chip quantities)
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": bytes_accessed / HBM_BW,
        "t_collective_s": coll_total / ICI_BW,
    }
    terms = {
        "compute": record["t_compute_s"],
        "memory": record["t_memory_s"],
        "collective": record["t_collective_s"],
    }
    record["bottleneck"] = max(terms, key=terms.get)
    if save_artifact:
        d = artifact_dir or os.path.abspath(ARTIFACT_DIR)
        os.makedirs(d, exist_ok=True)
        fname = f"{tag}_{record['mesh']}_{arch.replace('/', '_')}_{shape_name}.json"
        with open(os.path.join(d, fname), "w") as f:
            json.dump(record, f, indent=1)
    return record


def plan_orchestration(
    scenario: str = "paper-table6",
    policy: str = "feasibility-aware",
    at_hour: float = 36.0,
    fill: float = 0.5,
    transfers: Tuple[Tuple[int, int], ...] = (),
):
    """Orchestration dry-run: scenario state at sim-time ``at_hour`` ->
    ClusterState (via the shared constructor) -> the policy's typed actions.

    Placement is synthetic but scenario-faithful: the earliest-arrived jobs
    run at their home sites, up to ``fill`` of each site's slots;
    ``transfers`` injects synthetic in-flight ``(src, dst)`` migrations so
    the preview can be taken under WAN load.  Every ``Migrate`` the policy
    proposes is re-checked at the **post-admission** ``(flows+1)`` rate —
    the advertised matrix is the current grant, systematically optimistic
    for a transfer the plan itself would add — and moves that are
    infeasible at the diluted rate are dropped from the plan.  Returns
    (state, actions)."""
    from repro.core import feasibility as fz
    from repro.core.actions import Migrate
    from repro.core.orchestrator import make_policy
    from repro.core.scenarios import get_scenario
    from repro.core.simulator import generate_jobs
    from repro.core.state import ClusterState, JobView, site_views_from_traces

    scn = get_scenario(scenario)
    cfg = scn.sim_config()
    traces = scn.build_traces()
    t = at_hour * 3600.0
    cap = max(1, int(round(cfg.slots_per_site * fill)))
    per_site = [0] * cfg.n_sites
    views = []
    for j in generate_jobs(cfg):
        if j.arrival_s > t or per_site[j.home_site] >= cap:
            continue
        views.append(JobView(j.jid, j.home_site, j.ckpt_bytes, j.compute_s))
        per_site[j.home_site] += 1
    sites = site_views_from_traces(traces, t, slots=cfg.slots_per_site,
                                   busy=per_site)
    # the same WanTopology the simulator materializes for this scenario
    # (per-link caps, asymmetric NICs, brownout calendar at sim-time t),
    # plus the forecast horizon (σ=0: the planner reads the calendar as-is)
    state = ClusterState.build(t, views, sites, wan=scn.build_wan(),
                               transfers=transfers, traces=traces,
                               signals=scn.build_signals(),
                               battery=cfg.battery)
    jobs_by_id = {j.jid: j for j in state.jobs}
    flows = list(transfers)
    actions = []
    for a in make_policy(policy).decide(state):
        if isinstance(a, Migrate):
            j = jobs_by_id[a.jid]
            rate = state.post_admission_bps(j.site, a.dest, flows)
            v = fz.evaluate(j.ckpt_bytes, rate,
                            state.site(a.dest).window_remaining_s,
                            t_load_s=j.t_load_s)
            if not bool(v.feasible):
                continue  # optimistic under load: drop from the plan
            flows.append((j.site, a.dest))
        actions.append(a)
    return state, actions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--rules", default="baseline",
                    help="sharding strategy (parallel/strategies.py)")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--plan", action="store_true",
                    help="orchestration plan preview instead of HLO lowering")
    ap.add_argument("--scenario", default="paper-table6")
    ap.add_argument("--policy", default="feasibility-aware")
    ap.add_argument("--at-hour", type=float, default=36.0)
    ap.add_argument("--transfers", default="",
                    help="synthetic in-flight migrations for --plan as "
                         "src:dst pairs, e.g. '0:2,0:3' — proposed moves "
                         "are admission-checked at the diluted "
                         "post-admission rate")
    args = ap.parse_args()

    if args.plan:
        transfers = tuple(
            (int(s), int(d)) for s, d in
            (pair.split(":") for pair in args.transfers.split(",") if pair))
        state, actions = plan_orchestration(args.scenario, args.policy,
                                            args.at_hour, transfers=transfers)
        print(f"[plan] scenario={args.scenario} policy={args.policy} "
              f"t={args.at_hour:.1f}h jobs={len(state.jobs)}")
        if state.battery is not None:
            b = state.battery
            sell = (f" sellback={b.sellback_kw:.1f}kW"
                    f"@floor=${b.sellback_price_floor:.2f}/kWh"
                    if b.sellback_kw > 0.0 else "")
            print(f"[plan] battery: {b.capacity_kwh:.0f} kWh/site, "
                  f"charge<={b.max_charge_kw:.1f}kW "
                  f"discharge<={b.max_discharge_kw:.1f}kW "
                  f"rte={b.round_trip_efficiency:.2f} "
                  f"dark-discharge>={b.discharge_threshold_g:.0f}g/kWh"
                  f"{sell}")
        for s in state.sites:
            print(f"[plan]   site{s.sid}: busy={s.busy} "
                  f"{'GREEN' if s.renewable_active else 'grid '} "
                  f"window={s.window_remaining_s / 3600:.2f}h")
        if not actions:
            print("[plan] no actions")
        for a in actions:
            print(f"[plan]   {a}")
        return 0

    archs = [args.arch] if args.arch else list(ASSIGNED)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for shape_name in shapes:
            for mp in meshes:
                try:
                    from repro.parallel.strategies import get_strategy

                    rec = lower_cell(
                        arch, shape_name, multi_pod=mp, remat_policy=args.remat,
                        grad_compress=args.grad_compress, tag=args.tag,
                        artifact_dir=args.out, rules=get_strategy(args.rules),
                    )
                except Exception as e:  # a failure here is a sharding bug
                    rec = {
                        "arch": arch, "shape": shape_name,
                        "mesh": "multi" if mp else "single",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                    }
                rows.append(rec)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    extra = (
                        f" t_comp={rec['t_compute_s']:.3f}s t_mem={rec['t_memory_s']:.3f}s"
                        f" t_coll={rec['t_collective_s']:.3f}s bound={rec['bottleneck']}"
                        f" peak={_fmt_bytes(rec['memory']['peak_bytes'])}"
                        f" ({rec['lower_compile_s']}s)"
                    )
                print(f"[dryrun] {arch:24s} {shape_name:12s} {rec['mesh']:6s} {status}{extra}", flush=True)
    n_ok = sum(1 for r in rows if r["status"] == "OK")
    n_skip = sum(1 for r in rows if r["status"] == "SKIP")
    n_fail = len(rows) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    return 1 if n_fail else 0


def _fmt_bytes(b) -> str:
    if b is None:
        return "?"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


if __name__ == "__main__":
    raise SystemExit(main())
