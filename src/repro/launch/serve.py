"""Serving launcher: batched greedy decode with a KV cache / SSM state.

  PYTHONPATH=src python -m repro.launch.serve --arch micro-lm --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model


def greedy_decode(model, params, prompt_tokens, max_new: int, cache_len: int):
    B, P = prompt_tokens.shape
    cache = model.init_cache(B, cache_len)
    step_fn = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b), donate_argnums=(1,)
    )
    tok = prompt_tokens[:, 0]
    out = [tok]
    for i in range(P + max_new - 1):
        logits, cache = step_fn(params, cache, {"token": tok, "index": jnp.int32(i)})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = prompt_tokens[:, i + 1] if i + 1 < P else nxt
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro-lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_encdec or cfg.input_mode == "embeddings":
        raise SystemExit("serve demo targets token-input decoder-only archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    seqs = greedy_decode(model, params, prompt, args.tokens, args.prompt_len + args.tokens)
    dt = time.time() - t0
    n_new = args.batch * args.tokens
    print(f"[serve] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched)")
    print("[serve] sample:", seqs[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
