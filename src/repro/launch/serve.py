"""Serving launcher: batched greedy decode with a KV cache / SSM state,
plus a green request router over the shared ClusterState snapshot.

The router is the serving-side analogue of the training orchestrator (cf.
Heron's renewable-aware routing in *AI Greenferencing*): inference batches
are steered toward sites inside renewable windows, load-balanced across
free slots, using the same ``ClusterState.build`` constructor the simulator
and the dry-run planner use.

  PYTHONPATH=src python -m repro.launch.serve --arch micro-lm --tokens 32
  PYTHONPATH=src python -m repro.launch.serve --green-route 64 \
      --scenario solar-heavy --at-hour 12
"""
from __future__ import annotations

import argparse
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model


def build_serving_state(scenario: str = "paper-table6", at_hour: float = 12.0,
                        busy: Tuple[int, ...] = (),
                        transfers: Tuple[Tuple[int, int], ...] = ()):
    """Snapshot of the serving fleet at sim-time ``at_hour`` for a
    registered scenario, through the shared ClusterState constructor.
    ``transfers`` injects in-flight ``(src, dst)`` WAN flows so the router
    sees a loaded fabric."""
    from repro.core.scenarios import get_scenario
    from repro.core.state import ClusterState, site_views_from_traces

    scn = get_scenario(scenario)
    cfg = scn.sim_config()
    traces = scn.build_traces()
    t = at_hour * 3600.0
    busy_full = [busy[s] if s < len(busy) else 0 for s in range(cfg.n_sites)]
    sites = site_views_from_traces(traces, t, slots=cfg.slots_per_site,
                                   busy=busy_full)
    # the scenario's materialized WanTopology — identical to what the
    # simulator's transfer loop and the dry-run planner consume — plus the
    # forecast horizon (windows + outage calendar + grid signals) for
    # lookahead / carbon-aware routing
    return ClusterState.build(t, [], sites, wan=scn.build_wan(),
                              transfers=transfers, traces=traces,
                              signals=scn.build_signals())


def green_route(state, n_requests: int, *, origin: int = None,
                min_gbps: float = 0.0, lookahead_s: float = 0.0) -> List[int]:
    """Assign each request to the greenest feasible site: renewable sites
    with free slots first (longest remaining window wins), then spill by
    least relative load once renewable capacity is exhausted.

    With ``lookahead_s`` > 0 the router consumes ``state.forecast``
    instead of only the current snapshot: once current-green capacity is
    exhausted, free-slot sites whose forecast window *starts within the
    lookahead* take the next tier (soonest start wins — the request rides
    the window that is about to open), and the final grid spill breaks
    load ties by the current carbon signal (cleanest grid first; zeros
    when the run carries no signals, reducing to the reactive order).

    With ``origin`` set, each request must ship its batch/KV state from
    ``origin`` to the chosen site, and a remote site is only admissible if
    the **post-admission** ``(flows+1)`` rate on (origin, site) — counting
    both the snapshot's in-flight transfers and the requests this call
    already routed — stays at or above ``min_gbps``.  The advertised
    matrix is the pre-admission grant and is systematically optimistic
    for exactly this check: a saturated uplink that still advertises its
    current share flips the verdict once the request's own dilution is
    counted."""
    load = {s.sid: s.busy for s in state.sites}
    flows = list(state.transfers)
    fc = state.forecast if lookahead_s > 0.0 else None
    next_start = (
        {s.sid: fc.next_window_start_s(s.sid, state.t) for s in state.sites}
        if fc is not None else {})
    carbon = state.site_carbon if lookahead_s > 0.0 else None

    def admissible(s) -> bool:
        if origin is None or s.sid == origin or min_gbps <= 0.0:
            return True
        return state.post_admission_bps(origin, s.sid, flows) >= min_gbps * 1e9

    out: List[int] = []
    for _ in range(n_requests):
        free_green = [s for s in state.sites
                      if s.renewable_active and load[s.sid] < s.slots
                      and admissible(s)]
        if free_green:
            best = max(free_green,
                       key=lambda s: (s.window_remaining_s, -load[s.sid], -s.sid))
        else:
            best = None
            if fc is not None:
                # upcoming-window tier: a site about to turn green beats a
                # grid spill — the request runs mostly inside the window
                soon = [s for s in state.sites
                        if load[s.sid] < s.slots and admissible(s)
                        and state.t < next_start[s.sid]
                        <= state.t + lookahead_s]
                if soon:
                    best = min(soon, key=lambda s: (
                        next_start[s.sid], load[s.sid] / max(s.slots, 1),
                        s.sid))
            if best is None:
                # non-empty: the origin site (or, with no origin, every
                # site) is always admissible
                spill = [s for s in state.sites if admissible(s)]
                best = min(spill, key=lambda s: (
                    load[s.sid] / max(s.slots, 1),
                    not s.renewable_active,
                    float(carbon[s.sid]) if carbon is not None else 0.0,
                    s.sid))
        load[best.sid] += 1
        if origin is not None and best.sid != origin:
            flows.append((origin, best.sid))
        out.append(best.sid)
    return out


def greedy_decode(model, params, prompt_tokens, max_new: int, cache_len: int):
    B, P = prompt_tokens.shape
    cache = model.init_cache(B, cache_len)
    step_fn = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b), donate_argnums=(1,)
    )
    tok = prompt_tokens[:, 0]
    out = [tok]
    for i in range(P + max_new - 1):
        logits, cache = step_fn(params, cache, {"token": tok, "index": jnp.int32(i)})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = prompt_tokens[:, i + 1] if i + 1 < P else nxt
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro-lm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--green-route", type=int, default=0, metavar="N",
                    help="route N inference requests across the scenario's "
                         "sites and exit")
    ap.add_argument("--scenario", default="paper-table6")
    ap.add_argument("--at-hour", type=float, default=12.0)
    ap.add_argument("--origin", type=int, default=None,
                    help="site requests originate from; remote routing then "
                         "requires post-admission bandwidth >= --min-gbps")
    ap.add_argument("--min-gbps", type=float, default=0.0)
    ap.add_argument("--lookahead-h", type=float, default=2.0,
                    help="route by *upcoming* forecast windows within this "
                         "many hours (and break grid-spill ties by the "
                         "carbon signal); 0 = reactive snapshot only")
    ap.add_argument("--router", default="green-first",
                    help="serving-plane router for the simulated horizon "
                         "(see repro.core.serving.available_routers)")
    args = ap.parse_args(argv)

    if args.green_route > 0:
        # t=0 view: the snapshot router over one shared ClusterState —
        # same output as before the serving plane existed
        state = build_serving_state(args.scenario, args.at_hour)
        routes = green_route(state, args.green_route, origin=args.origin,
                             min_gbps=args.min_gbps,
                             lookahead_s=args.lookahead_h * 3600.0)
        counts = {s.sid: routes.count(s.sid) for s in state.sites}
        carbon = state.site_carbon
        print(f"[serve] green routing {args.green_route} requests "
              f"({args.scenario} @ t={args.at_hour:.1f}h, "
              f"lookahead={args.lookahead_h:.1f}h):")
        for s in state.sites:
            tag = "GREEN" if s.renewable_active else "grid "
            nxt = (state.forecast.next_window_start_s(s.sid, state.t)
                   if state.forecast is not None else float("inf"))
            nxt_h = ((nxt - state.t) / 3600.0) if nxt < float("inf") else -1.0
            print(f"[serve]   site{s.sid} {tag} "
                  f"window={s.window_remaining_s / 3600:.2f}h "
                  f"next_window_in={nxt_h:+.2f}h "
                  f"carbon={carbon[s.sid]:.0f}g/kWh "
                  f"-> {counts[s.sid]} requests")
        # then play the same burst through the event-driven serving plane:
        # replica queues, batch formation, WAN transfer of remote batches,
        # SLO accounting — over a short simulated horizon
        import math

        from repro.core.scenarios import get_scenario
        from repro.core.serving import ServingProfile
        from repro.core.simulator import ClusterSimulator

        n_sites = len(state.sites)
        t0 = args.at_hour * 3600.0
        trace = tuple(
            (t0 + 1e-3 * i,
             args.origin if args.origin is not None else i % n_sites)
            for i in range(args.green_route))
        prof = ServingProfile(arrival_trace=trace)
        # keep the scenario's own horizon so the simulator's traces are
        # the exact ones the t=0 view above was built from
        days = max(get_scenario(args.scenario).days,
                   math.ceil(args.at_hour / 24.0 + 0.5))
        sim = ClusterSimulator.from_scenario(
            args.scenario, "static",
            overrides=dict(n_jobs=0, engine="event", days=days,
                           serving=prof, serving_router=args.router))
        res = sim.run()
        plane = sim.serving
        p50, p95, _ = plane.latency_percentiles()
        print(f"[serve] simulated horizon (router={args.router}): "
              f"served={res.requests_served}/{res.requests_arrived} "
              f"dropped={res.requests_dropped} "
              f"slo_violations={res.slo_violations} "
              f"p50={p50:.2f}s p95={p95:.2f}s "
              f"request_gco2={res.request_gco2:.1f}g")
        for sid in range(n_sites):
            print(f"[serve]   site{sid} routed={plane.site_routed[sid]} "
                  f"served={plane.site_served[sid]} "
                  f"gco2={plane.site_request_gco2[sid]:.1f}g")
        return 0

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if cfg.is_encdec or cfg.input_mode == "embeddings":
        raise SystemExit("serve demo targets token-input decoder-only archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    seqs = greedy_decode(model, params, prompt, args.tokens, args.prompt_len + args.tokens)
    dt = time.time() - t0
    n_new = args.batch * args.tokens
    print(f"[serve] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched)")
    print("[serve] sample:", seqs[0].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
