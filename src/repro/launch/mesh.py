"""Production mesh construction (assignment §MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version supports
    them (jax.sharding.AxisType is absent in older releases)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU demos/tests)."""
    return make_mesh((1, 1), ("data", "model"))
