"""Production mesh construction (assignment §MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh():
    """1-device mesh with the production axis names (CPU demos/tests)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
