"""Production mesh construction (assignment §MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module constant: importing this module never touches jax
device state."""
from __future__ import annotations

# the version-gated jax.make_mesh wrapper (AxisType is absent at the jax
# pin); re-exported here because launch-layer callers import it from this
# module
from repro.parallel.compat import make_mesh

__all__ = ["make_local_mesh", "make_mesh", "make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the production axis names (CPU demos/tests)."""
    return make_mesh((1, 1), ("data", "model"))
