"""Paper Table IV: workload classification by migration feasibility
(size bands + time-threshold classes at 1 and 10 Gbps)."""
from __future__ import annotations

import numpy as np

from repro.core import feasibility as fz

from benchmarks.common import GB, emit, table, timed


def run():
    hold = {}
    with timed(hold):
        rows = []
        for label, size_gb, chars in [
            ("A: Suitable", 5, "Small (<10 GB)"),
            ("B: Conditional", 40, "Medium (10-100 GB)"),
            ("C: Infeasible", 280, "Large LLMs (>100 GB)"),
        ]:
            s = size_gb * GB
            t10 = float(fz.transfer_time_s(s, 10e9))
            t1 = float(fz.transfer_time_s(s, 1e9))
            rows.append([
                label, chars, f"{size_gb} GB",
                "ABC"[int(fz.classify_by_size(s))],
                f"{t1:.0f}s -> " + "ABC"[int(fz.classify(s, 1e9))],
                f"{t10:.0f}s -> " + "ABC"[int(fz.classify(s, 10e9))],
            ])
        tbl = table(rows, ["Class", "Characteristics", "Size", "size-band",
                           "T@1Gbps->cls", "T@10Gbps->cls"])
    print(tbl)
    print("| note: the paper's Table IV size bands coincide with the §VI.D time")
    print("| thresholds at ~1 Gbps effective bandwidth (60s≈7.5GB, 300s≈37.5GB).")
    emit("table4_classes", hold["us"],
         "size bands == time thresholds @ ~1Gbps; A<10GB B10-100GB C>100GB")


if __name__ == "__main__":
    run()
