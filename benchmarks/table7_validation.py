"""Paper Table VII: feasibility-domain validation — one migration per
representative workload inside a 2.5 h renewable window; measured JCT
overhead vs the analytic eq.(1) prediction, and the resulting
FEASIBLE/INFEASIBLE status under the formal model."""
from __future__ import annotations

from repro.core import feasibility as fz

from benchmarks.common import GB, emit, table, timed

WORKLOADS = [
    ("ResNet-50", 1.0, "A", "FEASIBLE"),
    ("GPT-2 Small", 6.0, "A", "FEASIBLE"),
    ("GPT-2 Medium", 40.0, "B", "INFEASIBLE (Energy)"),
    ("LLaMA-70B", 280.0, "C", "INFEASIBLE (Both)"),
]
PAPER_OVH = {"ResNet-50": "1.3%", "GPT-2 Small": "5.4%",
             "GPT-2 Medium": "25.9%", "LLaMA-70B": "187%"}
WINDOW_S = 2.5 * 3600
JCT_BASE_S = 3600.0  # 1 h compute segment between checkpoints


def verdict_str(v) -> str:
    if bool(v.feasible):
        return "FEASIBLE"
    why = []
    if not bool(v.time_ok) or int(v.workload_class) == 2:
        why.append("Time")
    if not bool(v.energy_ok):
        why.append("Energy")
    return f"INFEASIBLE ({'+'.join(why) or 'Class'})"


def run():
    hold = {}
    with timed(hold):
        rows = []
        agree = 0
        for name, gb, paper_cls, paper_status in WORKLOADS:
            s = gb * GB
            for bw_name, bw in [("10G", 10e9), ("1G", 1e9)]:
                v = fz.evaluate(s, bw, WINDOW_S)
                ovh = float(v.t_cost_s) / JCT_BASE_S
                status = verdict_str(v)
                if bw_name == "1G":
                    # the paper's statuses correspond to ~1 Gbps effective bw
                    agree += (status.startswith("FEASIBLE")
                              == paper_status.startswith("FEASIBLE"))
                rows.append([
                    name, f"{gb:.0f} GB", bw_name,
                    "ABC"[int(v.workload_class)],
                    f"{float(v.t_transfer_s):.1f}s", f"{ovh:.1%}", status,
                    f"{paper_cls}/{PAPER_OVH[name]}/{paper_status}" if bw_name == "1G" else "",
                ])
        tbl = table(rows, ["Workload", "Size", "bw", "class", "T_transfer",
                           "JCT-ovh(1h seg)", "status(formal model)", "paper@(their sim)"])
    print(tbl)
    print("| note: at the nominal 10 Gbps the formal model admits GPT-2-M (42.7s")
    print("| cost < 900s budget); the paper's INFEASIBLE statuses for B/C reproduce")
    print("| at ~1 Gbps effective bandwidth. The paper's '(Energy)' tag for GPT-2-M")
    print("| contradicts its own §IV.D finding (T_BE is minutes) — see EXPERIMENTS.md.")
    emit("table7_validation", hold["us"],
         f"status agreement @1Gbps effective: {agree}/4 (A feasible; B/C infeasible)")


if __name__ == "__main__":
    run()
