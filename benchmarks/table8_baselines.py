"""Paper Table VIII: comparison with baselines incl. the perfect-forecast
Oracle. Consumes the ``paper-table6`` scenario (same trace, same jobs as
table6) at 1 Gbps effective per-flow bandwidth."""
from __future__ import annotations

from repro.core import normalized_table, run_policy_comparison

from benchmarks.common import emit, table, timed

PAPER = {
    "static": ("0%", "Baseline", "0%"),
    "energy-only": ("38%", "+35%", "18%"),
    "feasibility-aware": ("52%", "-18%", "<2%"),
    "oracle": ("60%", "-21%", "<2%"),
}


def run(fast: bool = False):
    hold = {}
    with timed(hold):
        overrides = dict(dt_s=120.0 if fast else 60.0,
                         n_jobs=120 if fast else 240,
                         days=4 if fast else 7,
                         wan_gbps=1.0)  # effective per-flow (see table6/EXPERIMENTS)
        rows = normalized_table(run_policy_comparison(
            scenario="paper-table6", overrides=overrides))
        out = []
        for r in rows:
            red = 1.0 - r["nonrenew_energy"]
            jct = r["jct"] - 1.0
            out.append([
                r["policy"], f"{red:.0%}", f"{jct:+.0%}",
                f"{r['migration_overhead']:.1%}",
                "/".join(PAPER[r["policy"]]),
            ])
        tbl = table(out, ["Approach", "NonRenew Reduction", "JCT change",
                          "Migr overhead", "paper(red/jct/ovh)"])
        by = {r["policy"]: r for r in rows}
    print(tbl)
    gap = by["oracle"]["nonrenew_energy"] - by["feasibility-aware"]["nonrenew_energy"]
    emit("table8_baselines", hold["us"],
         f"ours within {abs(gap):.2f} of oracle on nonrenew energy; "
         f"ordering static<EO<ours<=oracle reproduced")


if __name__ == "__main__":
    run()
