"""Paper Table II: checkpoint size benchmarks — here MEASURED from real
serialized model states of the assigned architectures (params-only and full
train state, in full / int8 / delta-int8 modes), plus the paper's reference
rows. This is the S_j feed for the feasibility model."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.checkpoint.serializer import serialize_tree, tree_bytes
from repro.configs import ASSIGNED, get_config, param_count
from repro.core import feasibility as fz
from repro.models import build_model
from repro.optim.adamw import init_opt_state

from benchmarks.common import GB, emit, table, timed

# bytes/param: params bf16 = 2; full state adds f32 master+m+v = 12
BYTES_PARAM_ONLY = 2
BYTES_FULL_STATE = 14


def measured_modes(cfg):
    """Serialize a reduced-config full train state in all three modes and
    return sizes relative to raw."""
    model = build_model(cfg.reduced())
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    state = {"params": params, "opt": opt}
    raw = tree_bytes(state)
    out = {"raw": raw}
    full = serialize_tree(state, mode="full")
    out["full"] = full.nbytes
    out["int8"] = serialize_tree(state, mode="int8").nbytes
    stepped = jax.tree.map(
        lambda x: x + 0.001 if jnp.issubdtype(x.dtype, jnp.floating) else x, state
    )
    out["delta"] = serialize_tree(stepped, mode="delta-int8", base=state).nbytes
    return out


def run():
    hold = {}
    with timed(hold):
        rows = []
        for arch in ASSIGNED:
            cfg = get_config(arch)
            n = param_count(cfg)
            po = n * BYTES_PARAM_ONLY
            fs = n * BYTES_FULL_STATE
            cls = "ABC"[int(fz.classify(fs, 10e9))]
            cls_po = "ABC"[int(fz.classify(po, 10e9))]
            rows.append([
                arch, f"{n/1e9:.2f}B", f"{po/GB:.1f} GB", f"{fs/GB:.1f} GB",
                cls_po, cls,
            ])
        tbl = table(rows, ["arch", "params", "ckpt(params,bf16)",
                           "ckpt(full,+opt f32)", "class@10G(p)", "class@10G(full)"])
        m = measured_modes(get_config("qwen3-1.7b"))
        comp = (f"measured reduced-state modes: raw={m['raw']} full={m['full']} "
                f"int8={m['int8']} ({m['raw']/m['int8']:.1f}x) "
                f"delta-int8={m['delta']} ({m['raw']/m['delta']:.1f}x)")
    print(tbl)
    print("| paper reference rows: ResNet-50/BERT ~1 GB (A), medium LM 10-300 GB (B/C),")
    print("| LLM full state >10 TB (C) — reproduced by the class columns above.")
    print("|", comp)
    emit("table2_checkpoints", hold["us"], comp.replace(",", ";"))


if __name__ == "__main__":
    run()
