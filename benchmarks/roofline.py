"""§Roofline: per (arch × shape × mesh) three-term roofline from the
dry-run artifacts (benchmarks/artifacts/*.json written by launch/dryrun.py).

  compute    = HLO_FLOPs / peak_FLOP/s          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw               (819 GB/s)
  collective = collective_bytes / link_bw       (~50 GB/s ICI)

(cost_analysis is per-device under SPMD, so the chip-count division is
already applied.) Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, active_param_count, get_config, param_count

from benchmarks.common import ARTIFACTS, emit, table, timed

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    """6·N·D for train (fwd+bwd), 2·N·D for prefill, 2·N per token decode."""
    cfg = get_config(arch)
    n = active_param_count(cfg) if cfg.moe else param_count(cfg)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return 6.0 * n * shape.tokens / n_chips
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens / n_chips
    return 2.0 * n * shape.global_batch / n_chips  # one decode token


def load_records(tag: str = "baseline", artifact_dir: Optional[str] = None) -> List[dict]:
    d = artifact_dir or ARTIFACTS
    recs = []
    for path in sorted(glob.glob(os.path.join(d, f"{tag}_*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyse(rec: dict) -> dict:
    terms = {
        "compute": rec["t_compute_s"],
        "memory": rec["t_memory_s"],
        "collective": rec["t_collective_s"],
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], rec["n_chips"])
    hf = rec["hlo_flops_per_device"] or 1.0
    t_model = mf / PEAK_FLOPS  # ideal compute time for useful FLOPs
    t_bound = max(terms.values())
    return {
        **rec,
        "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_ratio": mf / hf,
        # roofline fraction: ideal useful-compute time / achievable step time
        # (the §Perf score — how close the bound is to pure useful compute)
        "roofline_frac": t_model / t_bound if t_bound > 0 else 0.0,
    }


def run(tag: str = "baseline"):
    hold = {}
    with timed(hold):
        recs = [analyse(r) for r in load_records(tag) if r.get("status") == "OK"]
        rows = []
        for r in sorted(recs, key=lambda x: (x["mesh"], x["arch"], x["shape"])):
            rows.append([
                r["mesh"], r["arch"], r["shape"],
                f"{r['t_compute_s']:.3f}", f"{r['t_memory_s']:.3f}",
                f"{r['t_collective_s']:.3f}", r["dominant"],
                f"{r['useful_ratio']:.2f}", f"{r['roofline_frac']:.3f}",
                f"{(r['memory']['peak_bytes'] or 0)/2**30:.2f}G",
            ])
        tbl = table(rows, ["mesh", "arch", "shape", "t_comp", "t_mem",
                           "t_coll", "bound", "useful", "roofline", "peak"])
    print(tbl)
    if recs:
        worst = min(recs, key=lambda r: r["roofline_frac"])
        coll = max(recs, key=lambda r: r["t_collective_s"])
        emit("roofline", hold["us"],
             f"{len(recs)} cells; worst roofline_frac={worst['roofline_frac']:.3f} "
             f"({worst['arch']}/{worst['shape']}/{worst['mesh']}); most collective-bound "
             f"{coll['arch']}/{coll['shape']} t_coll={coll['t_collective_s']:.2f}s")
    else:
        emit("roofline", hold["us"], "no artifacts yet (run launch/dryrun.py --all)")
    return recs


if __name__ == "__main__":
    run()
