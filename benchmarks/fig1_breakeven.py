"""Paper Fig. 1: energy breakeven curves for 1-100 GB checkpoints —
breakeven always within minutes => time, not energy, limits feasibility."""
from __future__ import annotations

import numpy as np

from repro.core import feasibility as fz

from benchmarks.common import GB, emit, table, timed


def run():
    hold = {}
    with timed(hold):
        sizes = np.array([1, 5, 10, 20, 40, 60, 80, 100], float)
        bws = [("1 Gbps", 1e9), ("10 Gbps", 10e9), ("100 Gbps", 100e9)]
        rows = []
        for s in sizes:
            row = [f"{s:.0f} GB"]
            for _, b in bws:
                row.append(f"{float(fz.breakeven_time_s(s * GB, b)) / 60:.2f} min")
            rows.append(row)
        tbl = table(rows, ["ckpt"] + [f"T_BE @ {n}" for n, _ in bws])
        worst = float(fz.breakeven_time_s(100 * GB, 1e9)) / 60
    print(tbl)
    print("| paper Critical Finding reproduced: all breakeven points are minutes,")
    print(f"| worst case (100 GB @ 1 Gbps) = {worst:.1f} min << 2.5 h windows.")
    emit("fig1_breakeven", hold["us"],
         f"worst T_BE(100GB@1Gbps)={worst:.1f}min << 150min window; ratio P_sys/P_node={fz.P_SYS_KW/fz.P_NODE_KW}")


if __name__ == "__main__":
    run()
