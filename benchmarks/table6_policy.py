"""Paper Table VI: policy comparison over the 7-day CAISO-calibrated trace,
normalized to the Static baseline. Consumes the ``paper-table6`` scenario
from the registry and runs it at the nominal 10 Gbps NIC and at 1 Gbps
effective per-flow bandwidth (shared inter-region WAN — the regime where
the paper's ordering is sharpest; see EXPERIMENTS.md), plus a stochastic
feasibility (§VI.H) variant wired through ``policy_configs`` — per-policy
knobs now reach the comparison path."""
from __future__ import annotations

from repro.core import FeasibilityConfig, normalized_table, run_policy_comparison

from benchmarks.common import emit, table, timed

PAPER = {
    "static": (1.00, 1.00, "0%"),
    "energy-only": (0.62, 1.35, "18%"),
    "feasibility-aware": (0.48, 0.82, "<2%"),
    "oracle": (0.40, 0.79, "<2%"),
}

# the paper's Table VI plus the beyond-paper plan-ahead and the
# signal-aware receding-horizon rows (no published reference numbers)
POLICIES = ("static", "energy-only", "feasibility-aware", "oracle",
            "plan-ahead", "receding-horizon")


def one(rows, label):
    # dr_comp (fraction of requested curtail span-watts actually shed)
    # only appears when the scenario issued DR requests — the
    # normalized_table emits the key conditionally
    has_dr = any("dr_compliance" in r for r in rows)
    out = []
    for r in rows:
        pe, pj, po = PAPER.get(r["policy"], ("-", "-", "-"))
        row = [
            r["policy"], r["nonrenew_energy"], r["grid_gco2"],
            r["grid_cost"], r["jct"],
            f"{r['migration_overhead']:.1%}", f"{r['stall_overhead']:.1%}",
            f"{r['renewable_frac']:.1%}", r["rejected_actions"],
        ]
        if has_dr:
            row.append(f"{r.get('dr_compliance', 1.0):.1%}")
        row += [
            f"{r['ticks_per_sec']:.0f}", f"{r['decide_s']:.3f}",
            f"{pe}/{pj}/{po}",
        ]
        out.append(row)
    print(f"--- {label} ---")
    # 'rej' (rejected actions) makes action-validity regressions visible in
    # the table; 'ticks/s' tracks engine throughput and 'decide_s' the
    # cumulative policy overhead; 'gCO2'/'cost' are the grid-signal
    # accounting normalized to static (grid kWh are not interchangeable —
    # a dirty-peak kWh is not a curtailed-noon kWh)
    hdr = ["policy", "nonrenew", "gCO2", "cost", "JCT",
           "migr-ovh", "stalls", "renew%", "rej"]
    if has_dr:
        hdr.append("dr_comp")
    hdr += ["ticks/s", "decide_s", "paper(e/jct/ovh)"]
    print(table(out, hdr))
    return {r["policy"]: r for r in rows}


def sweep_summary(fast: bool = False) -> str:
    """The Monte-Carlo view of the same comparison: mean ± 95% CI per
    (scenario, policy) through ``SweepResult.table()`` — the single-seed
    table above cannot say whether an ordering is noise."""
    from repro.core.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        scenarios=("paper-table6", "carbon-peaks"),
        policies=("feasibility-aware", "plan-ahead", "receding-horizon"),
        seeds=tuple(range(2 if fast else 4)),
        overrides=dict(days=2 if fast else 4, n_jobs=60 if fast else 120))
    sw = run_sweep(spec, keep_results=False)
    return sw.table()


def run(fast: bool = False):
    hold = {}
    with timed(hold):
        overrides = dict(dt_s=120.0 if fast else 60.0,
                         n_jobs=120 if fast else 240,
                         days=4 if fast else 7)
        r10 = one(normalized_table(run_policy_comparison(
            scenario="paper-table6", overrides=overrides,
            policies=POLICIES)),
            "WAN 10 Gbps NIC (Table V nominal)")
        r1 = one(normalized_table(run_policy_comparison(
            scenario="paper-table6", overrides={**overrides, "wan_gbps": 1.0},
            policies=POLICIES)),
            "WAN 1 Gbps effective per-flow")
        # §VI.H: stochastic feasibility gate under noisy forecasts, passed
        # per-policy via a structured PolicyConfig
        rs = one(normalized_table(run_policy_comparison(
            scenario="paper-table6",
            overrides={**overrides, "wan_gbps": 1.0},
            policies=("static", "feasibility-aware"),
            policy_configs={"feasibility-aware": FeasibilityConfig(
                eps=0.05, forecast_sigma_s=900.0)})),
            "WAN 1 Gbps + stochastic feasibility (eps=0.05)")
        print("--- Monte-Carlo sweep (mean ± 95% CI over seeds) ---")
        print(sweep_summary(fast))
    fa10, fa1 = r10["feasibility-aware"], r1["feasibility-aware"]
    eo1, fs1 = r1["energy-only"], rs["feasibility-aware"]
    emit(
        "table6_policy", hold["us"],
        f"feas@10G e={fa10['nonrenew_energy']} jct={fa10['jct']} "
        f"ovh={fa10['migration_overhead']:.3f} | feas@1G e={fa1['nonrenew_energy']} "
        f"jct={fa1['jct']} | EO@1G e={eo1['nonrenew_energy']} jct={eo1['jct']} "
        f"| stoch@1G e={fs1['nonrenew_energy']} "
        f"(paper: 0.48/0.82/<2% and EO 0.62/1.35/18%)",
    )
    return r10, r1


if __name__ == "__main__":
    run()
