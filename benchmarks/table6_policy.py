"""Paper Table VI: policy comparison over the 7-day CAISO-calibrated trace,
normalized to the Static baseline. Run at the nominal 10 Gbps NIC and at
1 Gbps effective per-flow bandwidth (shared inter-region WAN — the regime
where the paper's ordering is sharpest; see EXPERIMENTS.md)."""
from __future__ import annotations

import dataclasses

from repro.core import SimConfig, normalized_table, run_policy_comparison

from benchmarks.common import emit, table, timed

PAPER = {
    "static": (1.00, 1.00, "0%"),
    "energy-only": (0.62, 1.35, "18%"),
    "feasibility-aware": (0.48, 0.82, "<2%"),
    "oracle": (0.40, 0.79, "<2%"),
}


def one(cfg, label):
    rows = normalized_table(run_policy_comparison(cfg))
    out = []
    for r in rows:
        pe, pj, po = PAPER[r["policy"]]
        out.append([
            r["policy"], r["nonrenew_energy"], r["jct"],
            f"{r['migration_overhead']:.1%}", f"{r['stall_overhead']:.1%}",
            f"{r['renewable_frac']:.1%}", f"{pe}/{pj}/{po}",
        ])
    print(f"--- {label} ---")
    print(table(out, ["policy", "nonrenew", "JCT", "migr-ovh", "stalls",
                      "renew%", "paper(e/jct/ovh)"]))
    return {r["policy"]: r for r in rows}


def run(fast: bool = False):
    hold = {}
    with timed(hold):
        cfg = SimConfig(dt_s=120.0 if fast else 60.0,
                        n_jobs=120 if fast else 240,
                        days=4 if fast else 7)
        r10 = one(cfg, "WAN 10 Gbps NIC (Table V nominal)")
        r1 = one(dataclasses.replace(cfg, wan_gbps=1.0),
                 "WAN 1 Gbps effective per-flow")
    fa10, fa1 = r10["feasibility-aware"], r1["feasibility-aware"]
    eo1 = r1["energy-only"]
    emit(
        "table6_policy", hold["us"],
        f"feas@10G e={fa10['nonrenew_energy']} jct={fa10['jct']} "
        f"ovh={fa10['migration_overhead']:.3f} | feas@1G e={fa1['nonrenew_energy']} "
        f"jct={fa1['jct']} | EO@1G e={eo1['nonrenew_energy']} jct={eo1['jct']} "
        f"(paper: 0.48/0.82/<2% and EO 0.62/1.35/18%)",
    )
    return r10, r1


if __name__ == "__main__":
    run()
