"""Paper Fig. 2: feasibility phase diagram (checkpoint size x WAN bandwidth)
with the four representative workloads placed at 10 and 1 Gbps, plus the
beyond-paper COMPRESSED phase diagram (int8+delta shrinks S by ~4-7x and
moves workloads across class boundaries — §VIII envelope expansion,
implemented)."""
from __future__ import annotations

import numpy as np

from repro.core import feasibility as fz

from benchmarks.common import GB, emit, timed

SIZES_GB = np.logspace(0, 3, 25)  # 1 GB .. 1 TB
BWS_GBPS = np.logspace(-1, 2, 13)  # 0.1 .. 100 Gbps
GLYPH = {0: ".", 1: "o", 2: "#"}  # A, B, C
WORKLOADS = [("ResNet-50", 1.0), ("GPT-2-S", 6.0), ("GPT-2-M", 40.0), ("LLaMA-70B", 280.0)]


def ascii_phase(compress: float = 1.0):
    d = fz.phase_diagram(SIZES_GB / compress, BWS_GBPS, window_s=2.5 * 3600)
    lines = []
    for i, s in enumerate(SIZES_GB):
        row = "".join(GLYPH[int(c)] for c in d["class"][i])
        lines.append(f"{s:8.1f} GB |{row}|")
    lines.append(" " * 12 + " " + "".join("^" if abs(b - 1) < 0.05 or abs(b - 10) < 0.5 else " "
                                          for b in BWS_GBPS))
    lines.append(" " * 12 + f" bw: {BWS_GBPS[0]:.1f} .. {BWS_GBPS[-1]:.0f} Gbps (log)   . =A  o=B  #=C")
    return "\n".join(lines), d


def run():
    hold = {}
    with timed(hold):
        diagram, d = ascii_phase()
        diagram_c, _ = ascii_phase(compress=5.0)
        placements = []
        for name, s in WORKLOADS:
            c10 = "ABC"[int(fz.classify(s * GB, 10e9))]
            c1 = "ABC"[int(fz.classify(s * GB, 1e9))]
            placements.append(f"{name}({s:.0f}GB): {c10}@10G/{c1}@1G")
    print("Feasibility phase diagram (uncompressed):")
    print(diagram)
    print("dual placement:", "; ".join(placements))
    print("\nWith int8+delta checkpoint compression (~5x, measured in table2):")
    print(diagram_c)
    # Key Insight check: sub-20 GB fully class A at 10 Gbps
    i10 = int(np.argmin(np.abs(BWS_GBPS - 10)))
    i20 = int(np.searchsorted(SIZES_GB, 20.0))
    a_below_20 = (d["class"][:i20, i10] == 0).all()
    emit("fig2_phase", hold["us"],
         f"sub-20GB all class A @10Gbps: {bool(a_below_20)}; "
         f"LLaMA-70B C@1Gbps B@10Gbps; compression(5x) shifts boundary ~5x up")


if __name__ == "__main__":
    run()
