"""Latency-vs-carbon Pareto sweep for the serving plane.

Runs the ``inference-heavy`` scenario through all three request routers
at escalating per-site arrival rates (the chunked fast path makes the
grid affordable: ~1M-request weeks at a few seconds per cell) and emits
one CSV row per (router, rate) cell with the latency percentiles,
request-carbon and SLO digits — the frontier the paper's serving section
argues about: latency-greedy routing (``nearest``) anchors the latency
axis, window-chasing (``green-first``) the carbon axis, and the SLO-aware
compromise (``carbon-slo``) should sit between them at every load level.

Cells fan out through :func:`repro.core.sweep.run_cells` (the same
process-pool engine the Monte-Carlo sweeps use), so the grid
parallelizes on multi-core runners and stays deterministic in merge
order.

  PYTHONPATH=src python -m benchmarks.pareto_serving [--days 3]
  PYTHONPATH=src python -m benchmarks.gen_report --section pareto
"""
from __future__ import annotations

import argparse
import csv
import os
import sys
from typing import Optional, Sequence, Tuple

ROUTERS: Tuple[str, ...] = ("nearest", "green-first", "carbon-slo")
RATES: Tuple[float, ...] = (0.1, 0.3, 0.6, 1.0)
OUT_CSV = os.path.join(os.path.dirname(__file__), "PARETO_serving.csv")

FIELDS = (
    "router", "req_per_s_per_site", "requests_arrived", "requests_served",
    "requests_dropped", "requests_shed", "slo_violations", "slo_attainment",
    "latency_p50_s", "latency_p95_s", "latency_p99_s", "request_gco2",
    "serve_grid_kwh",
)


def build_cells(days: int, rates: Sequence[float] = RATES, seed: int = 0):
    """One prepared sweep cell per (router, rate) — the cell label packs
    the grid coordinates so the merged records key themselves."""
    from repro.core.scenarios import ServingProfile, get_scenario

    s = get_scenario("inference-heavy")
    cells = []
    for router in ROUTERS:
        for rate in rates:
            cfg = s.sim_config(
                days=days, seed=seed, serving_router=router,
                serving=ServingProfile(req_per_s_per_site=rate))
            pconf = {k: dict(v) for k, v in s.policy_configs.items()}
            cells.append((cfg, f"{router}@{rate:g}", seed, ("static",),
                          pconf, False, seed))
    return cells


def run(days: int = 3, rates: Sequence[float] = RATES,
        workers: Optional[int] = None, out_csv: str = OUT_CSV) -> list:
    from repro.core.sweep import run_cells

    res = run_cells(build_cells(days, rates), workers=workers,
                    keep_results=False)
    rows = []
    for rec in res.runs:
        router, rate = rec.scenario.rsplit("@", 1)
        s = rec.summary
        served = s["requests_served"]
        att = 1.0 - s["slo_violations"] / served if served else 1.0
        rows.append({
            "router": router,
            "req_per_s_per_site": float(rate),
            "requests_arrived": s["requests_arrived"],
            "requests_served": served,
            "requests_dropped": s["requests_dropped"],
            "requests_shed": s["requests_shed"],
            "slo_violations": s["slo_violations"],
            "slo_attainment": round(att, 5),
            "latency_p50_s": s["latency_p50_s"],
            "latency_p95_s": s["latency_p95_s"],
            "latency_p99_s": s["latency_p99_s"],
            "request_gco2": s["request_gco2"],
            "serve_grid_kwh": s["serve_grid_kwh"],
        })
    with open(out_csv, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=FIELDS)
        w.writeheader()
        w.writerows(rows)
    print(f"[pareto] {len(rows)} cells ({len(ROUTERS)} routers x "
          f"{len(rates)} rates, {days}-day runs, {res.workers} workers, "
          f"{res.wall_s:.1f}s) -> {out_csv}")
    for r in rows:
        print(f"[pareto] {r['router']:>11} @ {r['req_per_s_per_site']:.2f} "
              f"req/s/site: p95={r['latency_p95_s']:.2f}s "
              f"p99={r['latency_p99_s']:.2f}s slo={r['slo_attainment']:.4f} "
              f"gco2={r['request_gco2']:.1f} dropped={r['requests_dropped']} "
              f"shed={r['requests_shed']}")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=3,
                    help="simulated days per cell (default 3)")
    ap.add_argument("--rates", type=float, nargs="+", default=list(RATES),
                    help="per-site request rates to sweep")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool size (default: min(cells, cpus))")
    ap.add_argument("--out", default=OUT_CSV)
    args = ap.parse_args()
    run(days=args.days, rates=tuple(args.rates), workers=args.workers,
        out_csv=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
