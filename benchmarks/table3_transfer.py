"""Paper Table III: checkpoint transfer time vs WAN speeds."""
from __future__ import annotations

import numpy as np

from repro.core import feasibility as fz

from benchmarks.common import GB, emit, table, timed


def fmt(seconds: float) -> str:
    if seconds < 60:
        return f"{seconds:.2f}s" if seconds < 10 else f"{seconds:.1f}s"
    m, s = divmod(seconds, 60)
    return f"{int(m)}m{s:02.0f}s"


def run():
    hold = {}
    with timed(hold):
        sizes = [1, 16, 40, 100]
        bws = [("100 Mbps", 100e6), ("1 Gbps", 1e9), ("10 Gbps", 10e9), ("100 Gbps", 100e9)]
        rows = []
        for s in sizes:
            row = [f"{s} GB"]
            for _, b in bws:
                row.append(fmt(float(fz.transfer_time_s(s * GB, b))))
            rows.append(row)
        tbl = table(rows, ["Size"] + [n for n, _ in bws])
        t40 = float(fz.transfer_time_s(40 * GB, 10e9))
    print(tbl)
    emit("table3_transfer", hold["us"],
         f"40GB@10Gbps={t40:.0f}s (paper: 34s incl. overheads); grid matches 8S/B")


if __name__ == "__main__":
    run()
