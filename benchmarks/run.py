"""Benchmark runner: one function per paper table/figure.
Each prints its table then a ``name,us_per_call,derived`` CSV line.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # smaller sims
  PYTHONPATH=src python -m benchmarks.run --only table6_policy
  PYTHONPATH=src python -m benchmarks.run --quick    # CI perf smoke:
      full 7-day/240-job paper-table6 sim, prints wall time + ticks/sec
"""
from __future__ import annotations

import argparse
import sys
import traceback


def quick_smoke() -> int:
    """Perf gate for the orchestration hot loop: the headline 7-day/240-job
    run under the ``paper-table6`` scenario, end to end, with ticks/sec."""
    from repro.core import ClusterSimulator

    print("name,us_per_call,derived")
    ok = True
    for policy in ("feasibility-aware", "energy-only"):
        sim = ClusterSimulator.from_scenario("paper-table6", policy)
        r = sim.run()
        print(f"[quick] {policy}: {r.wall_time_s:.2f}s wall for {r.ticks} ticks "
              f"({r.ticks_per_sec:.0f} ticks/sec) | grid={r.grid_kwh:.1f} kWh "
              f"renew_frac={r.renewable_fraction:.2f} migrations={r.migrations} "
              f"completed={r.completed}")
        print(f"quick_{policy},{r.wall_time_s * 1e6:.0f},"
              f"{r.ticks_per_sec:.0f} ticks/sec")
        ok &= r.completed == len(r.jobs)
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller trace-driven sims")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="perf smoke only: 7-day/240-job sim + ticks/sec")
    args = ap.parse_args()

    if args.quick:
        sys.exit(quick_smoke())

    from benchmarks import (
        fig1_breakeven, fig2_phase, roofline, table1_hardware,
        table2_checkpoints, table3_transfer, table4_classes, table6_policy,
        table7_validation, table8_baselines,
    )

    benches = [
        ("table1_hardware", table1_hardware.run, {}),
        ("table2_checkpoints", table2_checkpoints.run, {}),
        ("table3_transfer", table3_transfer.run, {}),
        ("table4_classes", table4_classes.run, {}),
        ("fig1_breakeven", fig1_breakeven.run, {}),
        ("fig2_phase", fig2_phase.run, {}),
        ("table6_policy", table6_policy.run, {"fast": args.fast}),
        ("table7_validation", table7_validation.run, {}),
        ("table8_baselines", table8_baselines.run, {"fast": args.fast}),
        ("roofline", roofline.run, {}),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, kw in benches:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===")
        try:
            fn(**kw)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
