"""Benchmark runner: one function per paper table/figure.
Each prints its table then a ``name,us_per_call,derived`` CSV line.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # smaller sims
  PYTHONPATH=src python -m benchmarks.run --only table6_policy
  PYTHONPATH=src python -m benchmarks.run --quick    # CI perf smoke:
      full 7-day/240-job paper-table6 sim; prints wall time + ticks/sec
      and writes BENCH_quick.latest.json next to the committed
      BENCH_quick.json baseline (see benchmarks/check_quick.py for the
      CI regression gate)
"""
from __future__ import annotations

import argparse
import heapq
import json
import os
import sys
import time
import traceback

QUICK_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_quick.json")
QUICK_LATEST = os.path.join(os.path.dirname(__file__), "BENCH_quick.latest.json")


def calibrate() -> float:
    """Wall seconds for a fixed python+numpy workload shaped like the sim
    hot loop (heap churn + small-array numpy).  Stored alongside ticks/sec
    so check_quick.py can normalize away machine-speed differences between
    the committed baseline and the CI runner.  Best-of-3, matching the
    best-of-N treatment the sim runs themselves get."""
    import numpy as np

    def once() -> float:
        t0 = time.perf_counter()
        rng = np.random.default_rng(0)
        x = rng.random(512)
        acc = 0.0
        for _ in range(400):
            acc += float(np.minimum(x, 0.5).sum())
            h: list = []
            for i in range(512):
                heapq.heappush(h, (float(x[i]) + i, i))
            while h:
                heapq.heappop(h)
        assert acc > 0
        return time.perf_counter() - t0

    return min(once() for _ in range(3))


#: 25-site fleet variant of forecastable-brownouts: the scale where the
#: vectorized decide path pulls away from the scalar oracle (~4x on the
#: decide wall; at 5 sites numpy dispatch ~= python-loop cost).
FLEET_OVERRIDES = dict(n_sites=25, n_jobs=1200, arrival_skew=(1.0,) * 25)

#: 100-site x 10k-job variant: the O(100) sites x O(10^4..10^5) jobs regime
#: the compiled decide path targets.  One 7-day run ticks ~8000x faster
#: than real time; decide wall is ~5x below the pre-batched (PR 4)
#: reservation-loop path.
FLEET_COMPILED_OVERRIDES = dict(n_sites=100, n_jobs=10000,
                                arrival_skew=(1.0,) * 100)

#: 1000-cell mini-sweep (2 scenarios x 1 policy x 500 seeds of tiny
#: 1-day cells): the many-small-cells regime where the cross-cell batched
#: runner amortizes per-cell python/numpy dispatch into one fused kernel
#: pass per tick round.
SWEEP_BATCHED_SPEC = dict(
    scenarios=("paper-table6", "forecastable-brownouts"),
    policies=("feasibility-aware",), seeds=tuple(range(500)),
    overrides=dict(n_jobs=6, days=1, orch_dt_s=1800.0))


def quick_smoke(json_path: str = QUICK_LATEST) -> int:
    """Perf gate for the orchestration hot loop: full 7-day runs — the
    headline ``paper-table6`` scenario, the forecast-driven ``plan-ahead``
    policy on ``forecastable-brownouts`` (per-link outage calendar +
    ForecastHorizon grids every tick) at the paper's 5 sites and at the
    25-site fleet scale, the signal-aware ``receding-horizon`` planner on
    ``carbon-peaks`` (multi-window plan search + carbon accounting every
    span) and on ``price-spread`` (scenario-scoped non-zero price
    weight), the serving plane on ``train-plus-serve`` (carbon-slo
    router: request events + replica queues interleaved with training
    migrations), the fault-injection subsystem on ``chaos-monkey`` (all
    five fault classes mildly on; the fault-blind ``energy-only`` policy
    exercises the watchdog-abort -> retry -> reroute ladder and must
    still land every job), plus a mini Monte-Carlo sweep (2 scenarios x
    2 policies x 2 seeds through the process-pool engine).  Ticks/sec = processed events
    per second under the next-event engine; ``decide_s`` = cumulative
    wall time inside ``Policy.decide``."""
    from repro.core import ClusterSimulator
    from repro.core.sweep import SweepSpec, run_sweep

    print("name,us_per_call,derived")
    ok = True
    record = {"engine": None, "calib_s": round(calibrate(), 4), "policies": {}}
    for label, scenario, policy, overrides in (
        ("feasibility-aware", "paper-table6", "feasibility-aware", None),
        ("energy-only", "paper-table6", "energy-only", None),
        ("plan-ahead", "forecastable-brownouts", "plan-ahead", None),
        ("plan-ahead-fleet", "forecastable-brownouts", "plan-ahead",
         FLEET_OVERRIDES),
        ("receding-horizon", "carbon-peaks", "receding-horizon", None),
        ("receding-horizon-price", "price-spread", "receding-horizon", None),
        ("receding-horizon-battery", "battery-bridging", "receding-horizon",
         None),
        ("carbon-slo", "train-plus-serve", "feasibility-aware", None),
        ("chaos-monkey", "chaos-monkey", "energy-only", None),
        ("fleet-compiled", "forecastable-brownouts", "feasibility-aware",
         FLEET_COMPILED_OVERRIDES),
    ):
        best = None
        for _ in range(2):  # best-of-2: shave scheduler noise off the gate
            sim = ClusterSimulator.from_scenario(scenario, policy,
                                                 overrides=overrides)
            r = sim.run()
            if best is None or r.wall_time_s < best.wall_time_s:
                best = r
        r = best
        span_s = sim.cfg.days * 86400.0
        record["engine"] = r.engine
        print(f"[quick] {label}@{scenario}: {r.wall_time_s:.2f}s wall for "
              f"{r.ticks} ticks ({r.ticks_per_sec:.0f} ticks/sec, "
              f"decide {r.decide_s:.2f}s) | grid={r.grid_kwh:.1f} kWh "
              f"gco2={r.grid_gco2:.0f} g cost=${r.grid_cost:.2f} "
              f"renew_frac={r.renewable_fraction:.2f} migrations={r.migrations} "
              f"completed={r.completed} rejected={r.rejected_actions}")
        print(f"quick_{label},{r.wall_time_s * 1e6:.0f},"
              f"{r.ticks_per_sec:.0f} ticks/sec")
        record["policies"][label] = {
            "scenario": scenario,
            "wall_s": round(r.wall_time_s, 4),
            "ticks": r.ticks,
            "ticks_per_sec": round(r.ticks_per_sec, 1),
            "decide_s": round(r.decide_s, 4),
            "decide_first_s": round(r.decide_first_s, 4),
            "grid_kwh": round(r.grid_kwh, 1),
            "renewable_kwh": round(r.renewable_kwh, 1),
            "grid_gco2": round(r.grid_gco2, 1),
            "grid_cost": round(r.grid_cost, 2),
            "migrations": r.migrations,
            "completed": r.completed,
            "rejected_actions": r.rejected_actions,
        }
        if label == "fleet-compiled":
            # the acceptance regime: a 100-site fleet week must tick far
            # faster than real time, with XLA compile (first decide tick)
            # reported apart from the steady-state decide wall
            rt = span_s / max(r.wall_time_s, 1e-9)
            print(f"[quick]   fleet: {rt:.0f}x real time "
                  f"(decide {r.decide_s:.2f}s steady + "
                  f"{r.decide_first_s:.2f}s first-tick)")
            record["policies"][label]["realtime_factor"] = round(rt, 1)
        if r.battery_charge_kwh > 0.0 or r.sellback_kwh > 0.0:
            # the prosumer microgrid row: storage cycling + export revenue
            # from the PowerLedger, alongside the usual carbon digits
            print(f"[quick]   battery: charge={r.battery_charge_kwh:.1f} kWh "
                  f"discharge={r.battery_discharge_kwh:.1f} kWh "
                  f"cycles={r.battery_cycles:.2f} "
                  f"sellback={r.sellback_kwh:.1f} kWh "
                  f"(${r.sellback_usd:.2f}) "
                  f"dr_compliance={r.dr_compliance:.3f}")
            record["policies"][label].update({
                "battery_charge_kwh": round(r.battery_charge_kwh, 1),
                "battery_discharge_kwh": round(r.battery_discharge_kwh, 1),
                "battery_cycles": round(r.battery_cycles, 3),
                "sellback_kwh": round(r.sellback_kwh, 1),
                "sellback_usd": round(r.sellback_usd, 2),
                "dr_compliance": round(r.dr_compliance, 4),
            })
        if r.requests_arrived > 0:
            print(f"[quick]   serving: served={r.requests_served}"
                  f"/{r.requests_arrived} dropped={r.requests_dropped} "
                  f"slo_violations={r.slo_violations} "
                  f"p95={r.latency_p95_s:.2f}s "
                  f"request_gco2={r.request_gco2:.1f} g")
            record["policies"][label].update({
                "requests_arrived": r.requests_arrived,
                "requests_served": r.requests_served,
                "requests_dropped": r.requests_dropped,
                "slo_violations": r.slo_violations,
                "request_gco2": round(r.request_gco2, 1),
                "latency_p95_s": round(r.latency_p95_s, 3),
            })
            ok &= r.requests_served > 0
        if r.site_outages > 0 or r.watchdog_aborts > 0:
            # the fault-injection row: recovery-ladder telemetry (the
            # fault-blind policy walks watchdog aborts -> retries ->
            # reroutes yet still lands every job)
            print(f"[quick]   faults: outages={r.site_outages} "
                  f"mttr={r.mttr_s:.1f}s retries={r.retries} "
                  f"reroutes={r.reroutes} "
                  f"watchdog_aborts={r.watchdog_aborts} "
                  f"failed_migrations={r.failed_migrations}")
            record["policies"][label].update({
                "site_outages": r.site_outages,
                "mttr_s": round(r.mttr_s, 1),
                "retries": r.retries,
                "reroutes": r.reroutes,
                "watchdog_aborts": r.watchdog_aborts,
                "failed_migrations": r.failed_migrations,
            })
        ok &= r.completed == len(r.jobs)
    # serving fast path: the chunked engine against its per-event parity
    # oracle on the dedicated ~1.1M-request serving week.  Interleaved
    # best-of-2 per engine on the same machine — the gated quantity is
    # the requests/sec RATIO, so machine speed cancels out of the floor;
    # summaries minus timing must agree exactly (the fast path's
    # determinism contract).
    from repro.core.sweep import TIMING_KEYS

    ch_w = ev_w = None
    ch_r = ev_r = None
    for _ in range(2):
        for eng in ("chunked", "event"):
            sim = ClusterSimulator.from_scenario(
                "inference-heavy", "static",
                overrides=dict(serving_engine=eng))
            r = sim.run()
            if eng == "chunked":
                if ch_w is None or r.wall_time_s < ch_w:
                    ch_w, ch_r = r.wall_time_s, r
            elif ev_w is None or r.wall_time_s < ev_w:
                ev_w, ev_r = r.wall_time_s, r

    def _strip(d):
        # json round-trip so NaN columns (mean_jct_h on a zero-job
        # scenario) compare equal instead of poisoning dict equality
        return json.dumps({k: v for k, v in d.items()
                           if k not in TIMING_KEYS}, sort_keys=True)

    same_serving = _strip(ch_r.summary()) == _strip(ev_r.summary())
    req_s = ch_r.requests_arrived / max(ch_w, 1e-9)
    sp = ev_w / max(ch_w, 1e-9)
    print(f"[quick] inference-heavy: chunked {ch_w:.2f}s vs per-event "
          f"{ev_w:.2f}s for {ch_r.requests_arrived} requests "
          f"({req_s:,.0f} req/s, {sp:.2f}x), identical={same_serving} | "
          f"served={ch_r.requests_served} dropped={ch_r.requests_dropped} "
          f"slo_violations={ch_r.slo_violations} "
          f"p95={ch_r.latency_p95_s:.2f}s")
    print(f"quick_inference_heavy,{ch_w * 1e6:.0f},{sp:.2f}x")
    record["serving_fastpath"] = {
        "scenario": "inference-heavy",
        "requests_arrived": ch_r.requests_arrived,
        "requests_served": ch_r.requests_served,
        "requests_dropped": ch_r.requests_dropped,
        "slo_violations": ch_r.slo_violations,
        "latency_p95_s": round(ch_r.latency_p95_s, 3),
        "request_gco2": round(ch_r.request_gco2, 1),
        "chunked_wall_s": round(ch_w, 4),
        "event_wall_s": round(ev_w, 4),
        "req_per_s": round(req_s, 1),
        "speedup": round(sp, 2),
        "identical": same_serving,
    }
    ok &= same_serving and ch_r.requests_served > 0
    # mini-sweep: exercises the process-pool fan-out end to end in CI
    spec = SweepSpec(
        scenarios=("paper-table6", "forecastable-brownouts"),
        policies=("feasibility-aware", "plan-ahead"), seeds=(0, 1),
        overrides=dict(days=3, n_jobs=80))
    sw = run_sweep(spec, workers=2, keep_results=False)
    completed = sum(r.summary["completed"] for r in sw.runs)
    # the gated quantity is the summed in-simulator wall, not the pool
    # wall: process spawn/import overhead tracks runner provisioning, not
    # the code under test
    sim_wall = sum(r.summary["wall_s"] for r in sw.runs)
    print(f"[quick] mini-sweep: {len(sw.runs)} runs "
          f"(2 scen x 2 pol x 2 seeds) in {sw.wall_s:.2f}s pool wall "
          f"({sw.workers} workers, {sim_wall:.2f}s summed sim wall), "
          f"completed={completed}")
    print(f"quick_sweep,{sw.wall_s * 1e6:.0f},{len(sw.runs)} runs")
    record["sweep"] = {
        "runs": len(sw.runs), "workers": sw.workers,
        "wall_s": round(sw.wall_s, 4), "sim_wall_s": round(sim_wall, 4),
        "completed": completed,
    }
    ok &= completed == 2 * 2 * 2 * 80
    # 1000-cell batched-vs-pool sweep: the cross-cell fused decide path
    # against the process-pool engine on identical cells.  The gated
    # quantity is the summed in-simulator decide wall (steady + first
    # tick) — pool spawn/IPC overhead tracks runner provisioning, not
    # the kernels under test.  Summaries minus TIMING_KEYS must agree
    # exactly (the batched runner's determinism contract).
    from repro.core.sweep import run_cells, run_cells_batched

    bspec = SweepSpec(**SWEEP_BATCHED_SPEC)
    dec = lambda sw: sum(  # noqa: E731
        r.summary["decide_s"] + r.summary["decide_first_s"]
        for r in sw.runs)
    pool_dec = batch_dec = pool = batched = None
    for _ in range(2):  # best-of-2 per engine, like the policy rows
        p = run_cells(bspec.cells(keep_results=False), workers=2,
                      keep_results=False)
        b = run_cells_batched(bspec.cells(keep_results=False),
                              keep_results=False)
        if pool_dec is None or dec(p) < pool_dec:
            pool, pool_dec = p, dec(p)
        if batch_dec is None or dec(b) < batch_dec:
            batched, batch_dec = b, dec(b)
    ratio = pool_dec / max(batch_dec, 1e-9)
    same = (pool.deterministic_summaries()
            == batched.deterministic_summaries())
    bdone = sum(r.summary["completed"] for r in batched.runs)
    print(f"[quick] sweep-batched: {len(batched.runs)} runs, decide "
          f"{pool_dec:.2f}s pool vs {batch_dec:.2f}s batched "
          f"({ratio:.2f}x), deterministic={same}, completed={bdone}")
    print(f"quick_sweep_batched,{batch_dec * 1e6:.0f},{ratio:.2f}x")
    record["sweep_batched"] = {
        "runs": len(batched.runs),
        "pool_decide_s": round(pool_dec, 4),
        "batched_decide_s": round(batch_dec, 4),
        "speedup": round(ratio, 2),
        "deterministic": same,
        "completed": bdone,
    }
    ok &= same
    with open(json_path, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    print(f"[quick] wrote {json_path} (calib {record['calib_s']}s)")
    return 0 if ok else 1


def sweep_table(workers=None) -> None:
    """``--sweep``: the Monte-Carlo evaluation the single-seed tables
    cannot give — 5 scenarios x 3 policies x 8 seeds, full 7-day runs,
    fanned out over the process pool; prints mean +/- 95% CI per
    metric."""
    from repro.core.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        scenarios=("paper-table6", "flaky-wan", "solar-heavy",
                   "hub-spoke-wan", "forecastable-brownouts"),
        policies=("energy-only", "feasibility-aware", "plan-ahead"),
        seeds=tuple(range(8)))
    sw = run_sweep(spec, workers=workers, keep_results=False)
    print(sw.table())
    print(f"[sweep] {len(sw.runs)} runs ({sw.workers} workers) "
          f"in {sw.wall_s:.1f}s")
    print(f"sweep,{sw.wall_s * 1e6:.0f},{len(sw.runs)} runs")


def profile_run(scenario: str, policy: str, out_csv: str) -> None:
    """``--profile``: cProfile one full run and emit the top-15
    cumulative-time rows as CSV — so the next perf PR starts from data,
    not guesses."""
    import cProfile
    import pstats

    from repro.core import ClusterSimulator

    sim = ClusterSimulator.from_scenario(scenario, policy)
    srv_tm = (sim.serving.enable_timing()
              if sim.serving is not None else None)
    pr = cProfile.Profile()
    pr.enable()
    r = sim.run()
    pr.disable()
    print(f"[profile] {policy}@{scenario}: {r.wall_time_s:.2f}s wall "
          f"(decide {r.decide_s:.2f}s steady + {r.decide_first_s:.2f}s "
          f"first-tick — XLA compile lands in the first tick; profile "
          f"steady-state perf against decide_s), {r.ticks} ticks")
    if srv_tm is not None:
        # per-event-class serving breakdown (both planes accumulate the
        # same keys; the chunked engine books merged spans to chunk_s)
        total = sum(srv_tm.values())
        parts = " ".join(f"{k[:-2]}={v:.2f}s" for k, v in srv_tm.items())
        print(f"[profile] serving breakdown ({total:.2f}s booked): "
              f"{parts}")
    stats = pstats.Stats(pr)
    stats.sort_stats("cumulative")
    rows = []
    for func in stats.fcn_list:  # already cumulative-sorted
        cc, nc, tt, ct, _ = stats.stats[func]
        file, line, name = func
        rows.append((f"{file}:{line}({name})", nc, tt, ct))
        if len(rows) >= 15:
            break
    with open(out_csv, "w") as f:
        f.write("function,ncalls,tottime_s,cumtime_s\n")
        for fn, nc, tt, ct in rows:
            f.write(f"\"{fn}\",{nc},{tt:.4f},{ct:.4f}\n")
    print(f"[profile] top-15 cumulative rows -> {out_csv}")
    for fn, nc, tt, ct in rows:
        print(f"  {ct:8.4f}s cum  {tt:8.4f}s tot  {nc:>8}x  {fn}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller trace-driven sims")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="perf smoke only: 7-day/240-job sim + ticks/sec")
    ap.add_argument("--quick-json", default=QUICK_LATEST,
                    help="where --quick writes its JSON record")
    ap.add_argument("--sweep", action="store_true",
                    help="Monte-Carlo sweep: 5 scenarios x 3 policies x "
                         "8 seeds over the process pool, mean±CI table")
    ap.add_argument("--sweep-workers", type=int, default=None,
                    help="process-pool size for --sweep (default: cpus)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile one run, top-15 cumulative-time CSV")
    ap.add_argument("--profile-scenario", default="forecastable-brownouts")
    ap.add_argument("--profile-policy", default="plan-ahead")
    ap.add_argument("--profile-out",
                    default=os.path.join(os.path.dirname(__file__),
                                         "PROFILE_top15.csv"))
    args = ap.parse_args()

    if args.quick:
        sys.exit(quick_smoke(args.quick_json))
    if args.sweep:
        sweep_table(args.sweep_workers)
        return
    if args.profile:
        profile_run(args.profile_scenario, args.profile_policy,
                    args.profile_out)
        return

    from benchmarks import (
        fig1_breakeven, fig2_phase, roofline, table1_hardware,
        table2_checkpoints, table3_transfer, table4_classes, table6_policy,
        table7_validation, table8_baselines,
    )

    benches = [
        ("table1_hardware", table1_hardware.run, {}),
        ("table2_checkpoints", table2_checkpoints.run, {}),
        ("table3_transfer", table3_transfer.run, {}),
        ("table4_classes", table4_classes.run, {}),
        ("fig1_breakeven", fig1_breakeven.run, {}),
        ("fig2_phase", fig2_phase.run, {}),
        ("table6_policy", table6_policy.run, {"fast": args.fast}),
        ("table7_validation", table7_validation.run, {}),
        ("table8_baselines", table8_baselines.run, {"fast": args.fast}),
        ("roofline", roofline.run, {}),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, kw in benches:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===")
        try:
            fn(**kw)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
