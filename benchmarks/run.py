"""Benchmark runner: one function per paper table/figure.
Each prints its table then a ``name,us_per_call,derived`` CSV line.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --fast     # smaller sims
  PYTHONPATH=src python -m benchmarks.run --only table6_policy
  PYTHONPATH=src python -m benchmarks.run --quick    # CI perf smoke:
      full 7-day/240-job paper-table6 sim; prints wall time + ticks/sec
      and writes BENCH_quick.latest.json next to the committed
      BENCH_quick.json baseline (see benchmarks/check_quick.py for the
      CI regression gate)
"""
from __future__ import annotations

import argparse
import heapq
import json
import os
import sys
import time
import traceback

QUICK_BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_quick.json")
QUICK_LATEST = os.path.join(os.path.dirname(__file__), "BENCH_quick.latest.json")


def calibrate() -> float:
    """Wall seconds for a fixed python+numpy workload shaped like the sim
    hot loop (heap churn + small-array numpy).  Stored alongside ticks/sec
    so check_quick.py can normalize away machine-speed differences between
    the committed baseline and the CI runner.  Best-of-3, matching the
    best-of-N treatment the sim runs themselves get."""
    import numpy as np

    def once() -> float:
        t0 = time.perf_counter()
        rng = np.random.default_rng(0)
        x = rng.random(512)
        acc = 0.0
        for _ in range(400):
            acc += float(np.minimum(x, 0.5).sum())
            h: list = []
            for i in range(512):
                heapq.heappush(h, (float(x[i]) + i, i))
            while h:
                heapq.heappop(h)
        assert acc > 0
        return time.perf_counter() - t0

    return min(once() for _ in range(3))


def quick_smoke(json_path: str = QUICK_LATEST) -> int:
    """Perf gate for the orchestration hot loop: full 7-day/240-job runs —
    the headline ``paper-table6`` scenario plus the forecast-driven
    ``plan-ahead`` policy on ``forecastable-brownouts`` (per-link outage
    calendar + ForecastHorizon queries every tick), end to end, with
    ticks/sec (one tick = one processed event under the next-event
    engine)."""
    from repro.core import ClusterSimulator

    print("name,us_per_call,derived")
    ok = True
    record = {"engine": None, "calib_s": round(calibrate(), 4), "policies": {}}
    for scenario, policy in (
        ("paper-table6", "feasibility-aware"),
        ("paper-table6", "energy-only"),
        ("forecastable-brownouts", "plan-ahead"),
    ):
        best = None
        for _ in range(2):  # best-of-2: shave scheduler noise off the gate
            sim = ClusterSimulator.from_scenario(scenario, policy)
            r = sim.run()
            if best is None or r.wall_time_s < best.wall_time_s:
                best = r
        r = best
        record["engine"] = r.engine
        print(f"[quick] {policy}@{scenario}: {r.wall_time_s:.2f}s wall for "
              f"{r.ticks} ticks ({r.ticks_per_sec:.0f} ticks/sec) | "
              f"grid={r.grid_kwh:.1f} kWh "
              f"renew_frac={r.renewable_fraction:.2f} migrations={r.migrations} "
              f"completed={r.completed} rejected={r.rejected_actions}")
        print(f"quick_{policy},{r.wall_time_s * 1e6:.0f},"
              f"{r.ticks_per_sec:.0f} ticks/sec")
        record["policies"][policy] = {
            "scenario": scenario,
            "wall_s": round(r.wall_time_s, 4),
            "ticks": r.ticks,
            "ticks_per_sec": round(r.ticks_per_sec, 1),
            "grid_kwh": round(r.grid_kwh, 1),
            "renewable_kwh": round(r.renewable_kwh, 1),
            "migrations": r.migrations,
            "completed": r.completed,
            "rejected_actions": r.rejected_actions,
        }
        ok &= r.completed == len(r.jobs)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    print(f"[quick] wrote {json_path} (calib {record['calib_s']}s)")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller trace-driven sims")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="perf smoke only: 7-day/240-job sim + ticks/sec")
    ap.add_argument("--quick-json", default=QUICK_LATEST,
                    help="where --quick writes its JSON record")
    args = ap.parse_args()

    if args.quick:
        sys.exit(quick_smoke(args.quick_json))

    from benchmarks import (
        fig1_breakeven, fig2_phase, roofline, table1_hardware,
        table2_checkpoints, table3_transfer, table4_classes, table6_policy,
        table7_validation, table8_baselines,
    )

    benches = [
        ("table1_hardware", table1_hardware.run, {}),
        ("table2_checkpoints", table2_checkpoints.run, {}),
        ("table3_transfer", table3_transfer.run, {}),
        ("table4_classes", table4_classes.run, {}),
        ("fig1_breakeven", fig1_breakeven.run, {}),
        ("fig2_phase", fig2_phase.run, {}),
        ("table6_policy", table6_policy.run, {"fast": args.fast}),
        ("table7_validation", table7_validation.run, {}),
        ("table8_baselines", table8_baselines.run, {"fast": args.fast}),
        ("roofline", roofline.run, {}),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, fn, kw in benches:
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===")
        try:
            fn(**kw)
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
