"""§Perf hillclimb harness: re-lower chosen cells under candidate changes
(sharding strategy, remat policy, grad compression) and diff the roofline
terms against the baseline artifact.

  PYTHONPATH=src python -m benchmarks.perf_variants \
      --arch xlstm-1.3b --shape train_4k --mesh single \
      --variant small-repl --variant tp-ffn --remat dots
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import ARTIFACTS, table


def main():
    # the 512-device override must precede jax init (dryrun does it on import)
    from repro.launch.dryrun import lower_cell
    from repro.parallel.strategies import get_strategy

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", action="append", default=[],
                    help="strategy name, or strategy:remat, or +gradcompress")
    ap.add_argument("--baseline-tag", default="baseline")
    args = ap.parse_args()

    mp = args.mesh == "multi"
    base_path = os.path.join(
        ARTIFACTS, f"{args.baseline_tag}_{args.mesh}_{args.arch}_{args.shape}.json"
    )
    rows = []

    def add(rec, label):
        rows.append([
            label, f"{rec['t_compute_s']:.3f}", f"{rec['t_memory_s']:.3f}",
            f"{rec['t_collective_s']:.3f}",
            f"{(rec['memory']['peak_bytes'] or 0)/2**30:.2f}G",
            rec.get("lower_compile_s", "-"),
        ])

    if os.path.exists(base_path):
        with open(base_path) as f:
            add(json.load(f), "baseline(artifact)")

    for v in args.variant:
        gc = v.endswith("+gradcompress")
        v2 = v.replace("+gradcompress", "")
        strat, _, remat = v2.partition(":")
        strat = strat or "baseline"
        remat = remat or "full"
        rec = lower_cell(
            args.arch, args.shape, multi_pod=mp,
            rules=get_strategy(strat), remat_policy=remat, grad_compress=gc,
            tag=f"perf-{v.replace(':', '-').replace('+', '-')}",
        )
        if rec["status"] != "OK":
            print(f"[perf] {v}: {rec['status']}")
            continue
        add(rec, v)

    print(table(rows, ["variant", "t_comp", "t_mem", "t_coll", "peak", "compile_s"]))


if __name__ == "__main__":
    main()
