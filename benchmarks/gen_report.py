"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts, plus the §Sweep Monte-Carlo aggregate
(``SweepResult.table()``: mean ± 95% CI per (scenario, policy) —
the statistical view the single-seed tables cannot give).

  PYTHONPATH=src python -m benchmarks.gen_report [--tag baseline] > tables.md
  PYTHONPATH=src python -m benchmarks.gen_report --section sweep
"""
from __future__ import annotations

import argparse
import json

from repro.configs import ASSIGNED, SHAPES, get_config

from benchmarks.roofline import analyse, load_records


def human(n):
    if n is None:
        return "?"
    for u in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.2f}TB"


def dryrun_table(recs):
    by = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    lines = [
        "| arch | shape | mesh | status | peak/chip | HLO GFLOP/chip | HLO GB/chip | coll GB/chip | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = by.get((arch, shape, mesh))
                if shape not in cfg.shapes():
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | SKIP (full attention; DESIGN.md §7) | | | | | |"
                    )
                    continue
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | |")
                    continue
                lines.append(
                    f"| {arch} | {shape} | {mesh} | {r['status']} | "
                    f"{human(r['memory']['peak_bytes'])} | "
                    f"{r['hlo_flops_per_device']/1e9:,.0f} | "
                    f"{r['hlo_bytes_per_device']/1e9:.1f} | "
                    f"{r['collective_bytes_per_device']/1e9:.2f} | "
                    f"{r['lower_compile_s']} |"
                )
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | mesh | t_compute s | t_memory s | t_collective s | bound | MODEL_FLOPS/chip | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        a = analyse(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_collective_s']:.3f} | **{a['dominant']}** | "
            f"{a['model_flops_per_device']/1e9:,.0f}G | {a['useful_ratio']:.2f} | "
            f"{a['roofline_frac']:.3f} |"
        )
    return "\n".join(lines)


def sweep_section(fast: bool = True) -> str:
    """The Monte-Carlo aggregate table (run live — sweeps are seconds,
    not artifacts): SweepResult.table() over the policy-comparison grid,
    fenced for markdown embedding."""
    from benchmarks.table6_policy import sweep_summary

    return "```\n" + sweep_summary(fast=fast) + "\n```"


def pareto_table(csv_path=None) -> str:
    """The serving latency-vs-carbon frontier from the
    ``benchmarks.pareto_serving`` CSV artifact: one row per
    (router, rate) cell, latency axis next to the carbon axis."""
    import csv
    import os

    from benchmarks.pareto_serving import OUT_CSV

    path = csv_path or OUT_CSV
    if not os.path.exists(path):
        return (f"(no {os.path.basename(path)} — run `PYTHONPATH=src "
                f"python -m benchmarks.pareto_serving` first)")
    with open(path) as f:
        rows = list(csv.DictReader(f))
    out = ["| router | req/s/site | served | dropped | shed | p95 s "
           "| p99 s | SLO att. | req gCO2 | grid kWh |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['router']} | {float(r['req_per_s_per_site']):.2f} "
            f"| {r['requests_served']} | {r['requests_dropped']} "
            f"| {r['requests_shed']} | {float(r['latency_p95_s']):.2f} "
            f"| {float(r['latency_p99_s']):.2f} "
            f"| {float(r['slo_attainment']):.4f} "
            f"| {float(r['request_gco2']):.1f} "
            f"| {float(r['serve_grid_kwh']):.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--section", default="both",
                    choices=["dryrun", "roofline", "both", "sweep",
                             "pareto", "all"])
    ap.add_argument("--full-sweep", action="store_true",
                    help="sweep section at full (4-seed, 4-day) size")
    args = ap.parse_args()
    if args.section == "sweep":
        print("### Monte-Carlo sweep (mean ± 95% CI)\n")
        print(sweep_section(fast=not args.full_sweep))
        return
    if args.section == "pareto":
        print("### Serving latency-vs-carbon Pareto sweep\n")
        print(pareto_table())
        return
    recs = [r for r in load_records(args.tag) if r.get("status") == "OK"]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.section in ("dryrun", "both", "all"):
        print("### Dry-run matrix\n")
        print(dryrun_table(recs))
    if args.section in ("roofline", "both", "all"):
        print("\n### Roofline table\n")
        print(roofline_table(recs))
    if args.section == "all":
        print("\n### Monte-Carlo sweep (mean ± 95% CI)\n")
        print(sweep_section(fast=not args.full_sweep))
        print("\n### Serving latency-vs-carbon Pareto sweep\n")
        print(pareto_table())


if __name__ == "__main__":
    main()
