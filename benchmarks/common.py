"""Shared benchmark helpers: CSV emission + wall-clock accounting."""
from __future__ import annotations

import os
import sys
import time
from contextlib import contextmanager

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")
GB = 1e9


def emit(name: str, us_per_call: float, derived: str):
    """Benchmark contract: ``name,us_per_call,derived`` CSV on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed(holder: dict):
    t0 = time.time()
    yield
    holder["us"] = (time.time() - t0) * 1e6


def table(rows, header):
    widths = [max(len(str(r[i])) for r in [header] + rows) for i in range(len(header))]
    fmt = "  ".join("{:<%d}" % w for w in widths)
    lines = [fmt.format(*header), fmt.format(*["-" * w for w in widths])]
    lines += [fmt.format(*[str(c) for c in r]) for r in rows]
    return "\n".join("| " + l for l in lines)
