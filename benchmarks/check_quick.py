"""CI regression gate for the orchestration hot loop.

Compares the ``BENCH_quick.latest.json`` written by ``benchmarks/run.py
--quick`` against the committed ``BENCH_quick.json`` baseline and fails
(exit 1) if any policy's ticks/sec regressed more than ``--threshold``
(default 30%).

Raw ticks/sec is machine-dependent, so both records carry ``calib_s`` —
wall time of a fixed python+numpy workload (``benchmarks.run.calibrate``)
— and the comparison normalizes by relative machine speed:

    normalized_tps = latest_tps * (latest_calib_s / baseline_calib_s)

i.e. a runner that executes the calibration loop 2x slower is forgiven a
2x lower raw ticks/sec before the threshold applies.

  PYTHONPATH=src python -m benchmarks.check_quick
"""
from __future__ import annotations

import argparse
import json
import sys

from benchmarks.run import QUICK_BASELINE, QUICK_LATEST


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=QUICK_BASELINE)
    ap.add_argument("--latest", default=QUICK_LATEST)
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional ticks/sec regression")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.latest) as f:
        latest = json.load(f)

    speed = latest["calib_s"] / base["calib_s"]  # >1: this machine is slower
    print(f"[check_quick] machine-speed factor {speed:.2f} "
          f"(baseline calib {base['calib_s']}s, here {latest['calib_s']}s)")
    failed = False
    for policy, b in base["policies"].items():
        cur = latest["policies"].get(policy)
        if cur is None:
            print(f"[check_quick] FAIL {policy}: missing from latest record")
            failed = True
            continue
        norm_tps = cur["ticks_per_sec"] * speed
        floor = b["ticks_per_sec"] * (1.0 - args.threshold)
        # escape hatch: ticks count *events*, so a change that legitimately
        # removes events lowers ticks/sec without being a regression — let
        # machine-normalized wall time (what the gate actually protects)
        # override the verdict when it did not get worse
        norm_wall = cur["wall_s"] / speed
        wall_ok = norm_wall <= b["wall_s"] * (1.0 + args.threshold)
        ok = norm_tps >= floor or wall_ok
        verdict = "ok" if ok else "FAIL"
        print(f"[check_quick] {verdict} {policy}: {cur['ticks_per_sec']:.0f} "
              f"ticks/sec raw, {norm_tps:.0f} normalized vs baseline "
              f"{b['ticks_per_sec']:.0f} (floor {floor:.0f}); wall "
              f"{cur['wall_s']:.2f}s raw, {norm_wall:.2f}s normalized vs "
              f"baseline {b['wall_s']:.2f}s")
        if not ok:
            failed = True
        if cur["completed"] != b["completed"]:
            print(f"[check_quick] FAIL {policy}: completed "
                  f"{cur['completed']} != baseline {b['completed']}")
            failed = True
        # grid-signal accounting is seed-deterministic, but the trace
        # generator goes through libm (exp) and numpy Gaussian draws, so
        # cross-machine float drift at the last digits is possible — gate
        # at a 0.1% band: accounting regressions move these numbers by
        # percents, platform noise by parts per million
        if "grid_gco2" in b:
            got = cur.get("grid_gco2")
            if got is None or abs(got - b["grid_gco2"]) > max(
                    1e-3 * abs(b["grid_gco2"]), 0.2):
                print(f"[check_quick] FAIL {policy}: grid_gco2 "
                      f"{got} != baseline {b['grid_gco2']} (0.1% band)")
                failed = True
        # serving-plane rows: request accounting is seed-deterministic —
        # served/dropped counts are exact integers; SLO violations get a
        # tiny band (service jitter sits right at deadline boundaries on
        # some platforms) and request carbon the same 0.1% band as above
        if "requests_served" in b:
            for k in ("requests_arrived", "requests_served",
                      "requests_dropped"):
                if cur.get(k) != b[k]:
                    print(f"[check_quick] FAIL {policy}: {k} "
                          f"{cur.get(k)} != baseline {b[k]}")
                    failed = True
            viol_band = max(1, round(0.005 * b["requests_served"]))
            got_v = cur.get("slo_violations")
            if got_v is None or abs(got_v - b["slo_violations"]) > viol_band:
                print(f"[check_quick] FAIL {policy}: slo_violations "
                      f"{got_v} != baseline {b['slo_violations']} "
                      f"(band {viol_band})")
                failed = True
            got_g = cur.get("request_gco2")
            if got_g is None or abs(got_g - b["request_gco2"]) > max(
                    1e-3 * abs(b["request_gco2"]), 0.2):
                print(f"[check_quick] FAIL {policy}: request_gco2 "
                      f"{got_g} != baseline {b['request_gco2']} (0.1% band)")
                failed = True
        # fault-injection rows: the FaultPlan spans and the recovery
        # ladder are seed-deterministic — outage/retry/reroute/abort
        # counts are exact integers; mean time-to-repair is a pure span
        # average so it gets the same 0.1% platform-noise band
        if "retries" in b:
            for k in ("site_outages", "retries", "reroutes",
                      "watchdog_aborts", "failed_migrations"):
                if cur.get(k) != b[k]:
                    print(f"[check_quick] FAIL {policy}: {k} "
                          f"{cur.get(k)} != baseline {b[k]}")
                    failed = True
            got_m = cur.get("mttr_s")
            if got_m is None or abs(got_m - b["mttr_s"]) > max(
                    1e-3 * abs(b["mttr_s"]), 0.2):
                print(f"[check_quick] FAIL {policy}: mttr_s "
                      f"{got_m} != baseline {b['mttr_s']} (0.1% band)")
                failed = True
        # prosumer-microgrid rows: battery cycling, sell-back revenue and
        # DR compliance come out of the PowerLedger's deterministic span
        # accounting — same 0.1% platform-noise band as grid_gco2
        if "battery_cycles" in b:
            for k, floor_abs in (("battery_cycles", 0.01),
                                 ("sellback_usd", 0.01),
                                 ("dr_compliance", 0.001)):
                got_b = cur.get(k)
                if got_b is None or abs(got_b - b[k]) > max(
                        1e-3 * abs(b[k]), floor_abs):
                    print(f"[check_quick] FAIL {policy}: {k} "
                          f"{got_b} != baseline {b[k]} (0.1% band)")
                    failed = True
    # mini-sweep row: regression gate on the *summed in-simulator wall*
    # (machine-normalized; the pool wall is spawn/import-dominated and
    # tracks runner provisioning, not the code) plus exact determinism of
    # the completed-jobs total
    b_sw, c_sw = base.get("sweep"), latest.get("sweep")
    if b_sw is not None:
        if c_sw is None:
            print("[check_quick] FAIL sweep: missing from latest record")
            failed = True
        else:
            norm_wall = c_sw["sim_wall_s"] / speed
            wall_ok = norm_wall <= b_sw["sim_wall_s"] * (1.0 + args.threshold)
            det_ok = c_sw["completed"] == b_sw["completed"]
            verdict = "ok" if (wall_ok and det_ok) else "FAIL"
            print(f"[check_quick] {verdict} sweep: sim wall "
                  f"{c_sw['sim_wall_s']:.2f}s raw, {norm_wall:.2f}s "
                  f"normalized vs baseline {b_sw['sim_wall_s']:.2f}s "
                  f"(pool wall {c_sw['wall_s']:.2f}s); completed "
                  f"{c_sw['completed']} vs {b_sw['completed']}")
            if not (wall_ok and det_ok):
                failed = True
    # batched-sweep row: the cross-cell fused decide path must keep
    # beating the process-pool engine on summed decide wall.  The gate is
    # a same-machine *ratio* (pool and batched run back to back in one
    # process), so no calibration normalization applies; the floor sits
    # well under the standalone ~3x — a warm, loaded CI process measures
    # lower (observed 2.0-2.3x) and the gate must only catch the batched
    # path collapsing back to per-cell dispatch, not scheduler noise.  The
    # determinism bit (summaries minus timing identical across engines)
    # and the completed total are exact.
    b_sb, c_sb = base.get("sweep_batched"), latest.get("sweep_batched")
    if b_sb is not None:
        if c_sb is None:
            print("[check_quick] FAIL sweep_batched: missing from latest "
                  "record")
            failed = True
        else:
            ratio_ok = c_sb["speedup"] >= 1.5
            det_ok = bool(c_sb["deterministic"])
            done_ok = c_sb["completed"] == b_sb["completed"]
            verdict = "ok" if (ratio_ok and det_ok and done_ok) else "FAIL"
            print(f"[check_quick] {verdict} sweep_batched: "
                  f"{c_sb['speedup']:.2f}x batched-vs-pool decide "
                  f"({c_sb['pool_decide_s']:.2f}s vs "
                  f"{c_sb['batched_decide_s']:.2f}s; floor 1.5x), "
                  f"deterministic={c_sb['deterministic']}, completed "
                  f"{c_sb['completed']} vs {b_sb['completed']}")
            if not (ratio_ok and det_ok and done_ok):
                failed = True
    # serving-fast-path row: the chunked engine must keep >=10x the
    # per-event oracle's requests/sec on the inference-heavy week.  Both
    # engines run back to back (interleaved best-of-2) in one process,
    # so the floor is a same-machine ratio and no calibration
    # normalization applies.  Request accounting is exact, the SLO tally
    # gets the same tiny band as the other serving rows, and the
    # cross-engine determinism bit (summaries minus timing identical)
    # must hold.
    b_fp = base.get("serving_fastpath")
    c_fp = latest.get("serving_fastpath")
    if b_fp is not None:
        if c_fp is None:
            print("[check_quick] FAIL serving_fastpath: missing from "
                  "latest record")
            failed = True
        else:
            ratio_ok = c_fp["speedup"] >= 10.0
            det_ok = bool(c_fp["identical"])
            exact_ok = True
            for k in ("requests_arrived", "requests_served",
                      "requests_dropped"):
                if c_fp.get(k) != b_fp[k]:
                    print(f"[check_quick] FAIL serving_fastpath: {k} "
                          f"{c_fp.get(k)} != baseline {b_fp[k]}")
                    exact_ok = False
            viol_band = max(1, round(0.005 * b_fp["requests_served"]))
            got_v = c_fp.get("slo_violations")
            slo_ok = (got_v is not None
                      and abs(got_v - b_fp["slo_violations"]) <= viol_band)
            if not slo_ok:
                print(f"[check_quick] FAIL serving_fastpath: "
                      f"slo_violations {got_v} != baseline "
                      f"{b_fp['slo_violations']} (band {viol_band})")
            row_ok = ratio_ok and det_ok and exact_ok and slo_ok
            verdict = "ok" if row_ok else "FAIL"
            print(f"[check_quick] {verdict} serving_fastpath: "
                  f"{c_fp['speedup']:.2f}x chunked-vs-event "
                  f"({c_fp['req_per_s']:,.0f} req/s chunked, "
                  f"{c_fp['chunked_wall_s']:.2f}s vs "
                  f"{c_fp['event_wall_s']:.2f}s; floor 10x), "
                  f"identical={c_fp['identical']}, served "
                  f"{c_fp['requests_served']} vs {b_fp['requests_served']}")
            if not row_ok:
                failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
