"""Paper Table I: hardware configuration comparison (system-level power,
perf/W, $/TFLOP) + §II.C energy-per-sample reference points + the TPU v5e
row this framework targets."""
from __future__ import annotations

from repro.core.energy import ENERGY_PER_SAMPLE_MJ, TABLE_I, joules_per_sample

from benchmarks.common import emit, table, timed


def run():
    hold = {}
    with timed(hold):
        rows = []
        for key, hw in TABLE_I.items():
            p = (f"{hw.power_kw[0]:.2f} kW" if hw.power_kw[0] == hw.power_kw[1]
                 else f"{hw.power_kw[0]:.1f}-{hw.power_kw[1]:.1f} kW")
            pw = (f"{hw.perf_per_watt[0]:.2f}" if hw.perf_per_watt[0] == hw.perf_per_watt[1]
                  else f"{hw.perf_per_watt[0]:.2f}-{hw.perf_per_watt[1]:.2f}")
            rows.append([hw.name, p, pw, f"~${hw.usd_per_tflop:.0f}"])
        tbl = table(rows, ["Configuration", "Power (typ.)", "Perf/W (sys.)", "$/TFLOP"])
        # §II.C: mini-PC vs single-active-GPU A100 node J/sample ratio
        ratio = ENERGY_PER_SAMPLE_MJ["4xa100-node"] / ENERGY_PER_SAMPLE_MJ["rtx4090-mini-pc"]
    print(tbl)
    emit(
        "table1_hardware", hold["us"],
        f"vit_b32 mJ/sample mini-pc={ENERGY_PER_SAMPLE_MJ['rtx4090-mini-pc']} "
        f"a100-node={ENERGY_PER_SAMPLE_MJ['4xa100-node']} ratio={ratio:.1f}x "
        f"(paper: 2.7 vs 6-7)",
    )


if __name__ == "__main__":
    run()
