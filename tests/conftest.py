import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests run on the single real CPU device (the 512-device override is
# dryrun.py-only, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
