"""Feasibility-domain model: unit values from the paper + hypothesis
property tests (the property section is skipped when hypothesis is not
installed; the deterministic tests always run)."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: keep the deterministic tests
    HAS_HYPOTHESIS = False

from repro.core import feasibility as fz

GB = 1e9


# ---------------------------------------------------------------------------
# Paper-anchored unit values
# ---------------------------------------------------------------------------


def test_transfer_time_table_iii():
    # Table III: checkpoint transfer times vs WAN speeds
    cases = [
        (1 * GB, 100e6, 80.0),  # 1m20s (paper rounds to 1m25s w/ overheads)
        (1 * GB, 1e9, 8.0),  # 8.6 s in paper (8 S/B = 8.0 exact)
        (1 * GB, 10e9, 0.8),
        (16 * GB, 10e9, 12.8),  # paper: 13.8 s
        (40 * GB, 10e9, 32.0),  # paper: 34 s
        (100 * GB, 10e9, 80.0),  # paper: 86 s
        (100 * GB, 100e9, 8.0),  # paper: 8.6 s
    ]
    for size, bw, want in cases:
        got = float(fz.transfer_time_s(size, bw))
        assert got == pytest.approx(want, rel=0.01)


def test_breakeven_example_section_iv_d():
    # §IV.D: 40 GB @ 10 Gbps -> E_cost = 0.016 kWh, T_BE ≈ 1.3 min
    e = float(fz.migration_energy_kwh(40 * GB, 10e9))
    assert e == pytest.approx(1.8 * (8 * 40 / 10) / 3600, rel=1e-6)
    assert e == pytest.approx(0.016, rel=0.01)
    t_be = float(fz.breakeven_time_s(40 * GB, 10e9))
    assert t_be == pytest.approx(0.016 / 0.75 * 3600, rel=0.01)
    assert 60 < t_be < 120  # "≈ 1.3 minutes"


def test_classification_thresholds():
    # §VI.D: A < 60 s, B < 300 s, C otherwise
    assert int(fz.classify(1 * GB, 10e9)) == 0
    assert int(fz.classify(70 * GB, 10e9)) == 0  # 56 s
    assert int(fz.classify(80 * GB, 10e9)) == 1  # 64 s
    assert int(fz.classify(300 * GB, 10e9)) == 1  # 240 s
    assert int(fz.classify(400 * GB, 10e9)) == 2  # 320 s
    # Table IV size bands (~1 Gbps equivalence)
    assert int(fz.classify_by_size(5 * GB)) == 0
    assert int(fz.classify_by_size(40 * GB)) == 1
    assert int(fz.classify_by_size(200 * GB)) == 2


def test_energy_always_feasible_within_caiso_windows():
    """Critical Finding (§IV.D): breakeven ≪ even the shortest curtailment
    window (2.5 h) for checkpoints up to 1 TB at 10 Gbps."""
    sizes = np.array([1, 10, 40, 100, 300, 1000]) * GB
    t_be = np.asarray(fz.breakeven_time_s(sizes, 10e9))
    assert (t_be < 2.5 * 3600).all()
    # and within minutes for the Fig. 1 range (1-100 GB)
    assert (t_be[:4] < 5 * 60).all()


def test_evaluate_paper_boundary_case():
    # 40 GB, 10 Gbps, 2.5 h window: t_cost = 32+10.3+0.4 = 42.7 s < 900 s => ok
    v = fz.evaluate(40 * GB, 10e9, 2.5 * 3600)
    assert bool(v.feasible)
    # same at 1 Gbps: T_transfer = 320 s -> class C -> never migrated
    v = fz.evaluate(40 * GB, 1e9, 2.5 * 3600)
    assert not bool(v.feasible)
    assert int(v.workload_class) == 2


def test_phase_diagram_shape_and_monotonicity():
    sizes = np.logspace(0, 3, 13)  # 1 GB .. 1 TB
    bws = np.array([0.1, 1.0, 10.0, 100.0])
    d = fz.phase_diagram(sizes, bws)
    assert d["class"].shape == (13, 4)
    # class is monotone nondecreasing in size, nonincreasing in bandwidth
    assert (np.diff(d["class"], axis=0) >= 0).all()
    assert (np.diff(d["class"], axis=1) <= 0).all()
    # Key Insight: sub-20 GB migrates efficiently at 10 Gbps
    i20 = np.searchsorted(sizes, 20.0)
    assert (d["class"][:i20, 2] == 0).all()


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis only)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    sizes_st = st.floats(min_value=1e6, max_value=1e13)  # 1 MB .. 10 TB
    bw_st = st.floats(min_value=1e6, max_value=1e12)  # 1 Mbps .. 1 Tbps
    win_st = st.floats(min_value=60.0, max_value=24 * 3600.0)

    @settings(max_examples=200, deadline=None)
    @given(sizes_st, bw_st, win_st, sizes_st)
    def test_feasibility_monotone_in_size(size, bw, window, size2):
        """A larger checkpoint is never *more* feasible (all else equal)."""
        lo, hi = sorted([size, size2])
        v_lo = fz.evaluate(lo, bw, window)
        v_hi = fz.evaluate(hi, bw, window)
        assert bool(v_hi.feasible) <= bool(v_lo.feasible)
        assert int(v_hi.workload_class) >= int(v_lo.workload_class)

    @settings(max_examples=200, deadline=None)
    @given(sizes_st, bw_st, bw_st, win_st)
    def test_feasibility_monotone_in_bandwidth(size, bw, bw2, window):
        lo, hi = sorted([bw, bw2])
        v_lo = fz.evaluate(size, lo, window)
        v_hi = fz.evaluate(size, hi, window)
        assert bool(v_lo.feasible) <= bool(v_hi.feasible)

    @settings(max_examples=200, deadline=None)
    @given(sizes_st, bw_st, win_st)
    def test_feasible_implies_all_constraints(size, bw, window):
        v = fz.evaluate(size, bw, window)
        if bool(v.feasible):
            assert float(v.t_cost_s) < fz.ALPHA * window
            assert float(v.t_breakeven_s) < window
            assert int(v.workload_class) != 2
            # eq.(1) decomposition holds
            assert float(v.t_cost_s) == pytest.approx(
                float(v.t_transfer_s) + fz.T_LOAD_S + fz.T_DOWNTIME_S, rel=1e-6
            )

    @settings(max_examples=100, deadline=None)
    @given(sizes_st, bw_st, win_st, st.floats(min_value=1.0, max_value=3600.0))
    def test_stochastic_tighter_than_deterministic(size, bw, window, sigma):
        """ε-feasibility with ε<0.5 is strictly more conservative than the
        deterministic check at the forecast mean (§VI.H)."""
        stoch = bool(fz.stochastic_feasible(size, bw, window, sigma, eps=0.05))
        det = float(fz.migration_cost_s(size, bw)) < fz.ALPHA * window
        assert stoch <= det

    @settings(max_examples=100, deadline=None)
    @given(sizes_st, bw_st)
    def test_breakeven_ratio_is_power_ratio(size, bw):
        """T_BE / T_transfer == P_sys / P_node exactly (§VI.B)."""
        r = float(fz.breakeven_time_s(size, bw)) / float(fz.transfer_time_s(size, bw))
        assert r == pytest.approx(fz.P_SYS_KW / fz.P_NODE_KW, rel=1e-6)
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests inactive")
    def test_property_based_invariants():
        pass
