"""Monte-Carlo sweep engine: process-pool determinism, aggregation, and
the rerouted ``run_policy_comparison`` guarantees."""
import numpy as np
import pytest

from repro.core import SimConfig, run_policy_comparison
from repro.core.sweep import (
    TIMING_KEYS, SweepSpec, run_cells, run_sweep,
)

SMALL = dict(days=2, n_jobs=30, slots_per_site=2)


def small_spec():
    return SweepSpec(
        scenarios=("paper-table6", "forecastable-brownouts"),
        policies=("energy-only", "plan-ahead"),
        seeds=(0, 1),
        overrides=SMALL,
    )


def test_sweep_parallel_matches_sequential():
    """The acceptance guarantee: a process-parallel sweep produces
    identical per-run summaries (timing keys aside) and identical merge
    order to the same spec run inline with workers=1."""
    spec = small_spec()
    seq = run_sweep(spec, workers=1)
    par = run_sweep(spec, workers=2)
    assert seq.workers == 1 and par.workers == 2
    assert seq.deterministic_summaries() == par.deterministic_summaries()
    assert [(r.scenario, r.policy, r.seed) for r in seq.runs] == \
           [(r.scenario, r.policy, r.seed) for r in par.runs]


def test_sweep_cells_order_and_count():
    spec = small_spec()
    cells = spec.cells()
    assert len(cells) == 4  # 2 scenarios x 2 seeds
    assert [(c[1], c[2]) for c in cells] == [
        ("paper-table6", 0), ("paper-table6", 1),
        ("forecastable-brownouts", 0), ("forecastable-brownouts", 1)]
    # seeds reach the SimConfig (different seeds => different traces/jobs)
    assert cells[0][0].seed == 0 and cells[1][0].seed == 1


def test_sweep_aggregate_mean_std_ci():
    spec = small_spec()
    res = run_sweep(spec, workers=1)
    agg = res.aggregate()
    key = ("paper-table6", "energy-only")
    assert key in agg
    m = agg[key]["grid_kwh"]
    vals = [r.summary["grid_kwh"] for r in res.runs
            if (r.scenario, r.policy) == key]
    assert m["n"] == 2
    assert m["mean"] == pytest.approx(np.mean(vals))
    assert m["std"] == pytest.approx(np.std(vals, ddof=1))
    assert m["ci95"] == pytest.approx(1.96 * m["std"] / np.sqrt(2))
    # the table renders without error and mentions every policy
    tbl = res.table()
    assert "energy-only" in tbl and "plan-ahead" in tbl


def test_run_policy_comparison_routes_through_sweep():
    """Rerouted comparison: same-trace-same-jobs preserved (static is a
    strict superset of every other policy's grid burn ordering is not
    guaranteed, but determinism and full completion are), and calling it
    twice is bit-identical."""
    a = run_policy_comparison(
        SimConfig(**SMALL), policies=("static", "energy-only", "plan-ahead"))
    b = run_policy_comparison(
        SimConfig(**SMALL), policies=("static", "energy-only", "plan-ahead"))
    assert list(a) == ["static", "energy-only", "plan-ahead"]  # order kept
    for name in a:
        sa, sb = a[name].summary(), b[name].summary()
        for k in TIMING_KEYS:
            sa.pop(k), sb.pop(k)
        assert sa == sb, name
    # same jobs across policies: identical arrival/compute workload
    tot = {n: round(sum(j.compute_s for j in r.jobs), 6)
           for n, r in a.items()}
    assert len(set(tot.values())) == 1


def test_run_policy_comparison_scenario_and_overrides_still_work():
    res = run_policy_comparison(
        scenario="paper-table6", overrides=SMALL,
        policies=("static", "feasibility-aware"),
        policy_configs={"feasibility-aware": {"alpha": 0.2}})
    assert res["feasibility-aware"].completed == 30
    with pytest.raises(ValueError):
        run_policy_comparison(SimConfig(), scenario="paper-table6")


def test_cell_runner_shares_traces_and_forecast():
    """One cell, two policies: the run results must match what two
    standalone simulators produce (sharing is an optimization, not a
    behaviour change)."""
    from repro.core import ClusterSimulator, make_policy
    from repro.core.scenarios import get_scenario
    from repro.core.sweep import _run_cell

    cfg = get_scenario("forecastable-brownouts").sim_config(**SMALL)
    _label, _seed, out = _run_cell(
        (cfg, "x", cfg.seed, ("energy-only", "plan-ahead"), {}, True))
    for name, got, summary in out:
        solo = ClusterSimulator(cfg, make_policy(name)).run()
        assert round(got.grid_kwh, 6) == round(solo.grid_kwh, 6), name
        assert got.migrations == solo.migrations
        assert summary["completed"] == solo.completed
    # keep_results=False strips the per-job payload worker-side
    _l, _s, out2 = _run_cell(
        (cfg, "x", cfg.seed, ("energy-only",), {}, False))
    assert out2[0][1] is None and out2[0][2]["completed"] == 30


def test_split_seed_streams_vary_only_their_stream():
    """Variance decomposition: vary='traces' reruns the identical job
    workload under different environments; vary='jobs' reruns different
    workloads over the one pinned environment.  The default vary='both'
    must remain byte-identical to the legacy coupled seeding."""
    base = SweepSpec(scenarios=("paper-table6",), policies=("energy-only",),
                     seeds=(0, 1, 2), overrides=SMALL)
    both = run_sweep(base, workers=1)
    # legacy equivalence: the coupled mode reproduces run_policy_comparison
    legacy = run_policy_comparison(
        SimConfig(**SMALL, seed=1), policies=("energy-only",))
    assert {k: v for k, v in both.runs[1].summary.items()
            if k not in TIMING_KEYS} == \
           {k: v for k, v in legacy["energy-only"].summary().items()
            if k not in TIMING_KEYS}

    tr = run_sweep(SweepSpec(**{**base.__dict__, "vary": "traces"}),
                   workers=1)
    jb = run_sweep(SweepSpec(**{**base.__dict__, "vary": "jobs"}), workers=1)
    # traces mode: identical workload (same arrival/compute draw) ...
    tot = {round(sum(j.compute_s for j in r.result.jobs), 6)
           for r in tr.runs}
    assert len(tot) == 1
    # ... but different environments -> different outcomes
    assert len({r.summary["grid_kwh"] for r in tr.runs}) > 1
    # jobs mode: workloads differ, seed 0 matches the coupled run exactly
    tot_j = {round(sum(j.compute_s for j in r.result.jobs), 6)
             for r in jb.runs}
    assert len(tot_j) == 3
    assert jb.runs[0].summary["grid_kwh"] == both.runs[0].summary["grid_kwh"]


def test_split_seed_sweeps_deterministic_across_workers():
    spec = SweepSpec(scenarios=("paper-table6", "carbon-peaks"),
                     policies=("energy-only", "receding-horizon"),
                     seeds=(0, 1), overrides=SMALL, vary="traces")
    seq = run_sweep(spec, workers=1, keep_results=False)
    par = run_sweep(spec, workers=2, keep_results=False)
    assert seq.deterministic_summaries() == par.deterministic_summaries()
    with pytest.raises(ValueError):
        SweepSpec(scenarios=("paper-table6",), policies=("static",),
                  vary="nope").cells()


def test_decide_s_is_first_class():
    from repro.core import ClusterSimulator, normalized_table

    res = run_policy_comparison(SimConfig(**SMALL),
                                policies=("static", "energy-only"))
    for r in res.values():
        assert r.decide_s >= 0.0
        assert "decide_s" in r.summary()
    rows = normalized_table(res)
    assert all("decide_s" in row for row in rows)
