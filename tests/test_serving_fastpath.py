"""Chunked serving fast path: bit-identity against the per-event parity
oracle across routers and edge regimes (queue overflow at the chunk
boundary, zero-replica sites, fault/window edges, max-batch fill),
request conservation at the ~1.1M-request acceptance rate, proactive
load-shedding ahead of forecast blackouts, and the RNG stream-stability
guarantee (zeroing one site's replicas never shifts another site's
arrival draws).  A hypothesis-gated property test fuzzes the burst
regime when the library is available."""
import json

import numpy as np
import pytest

from repro.core.scenarios import ServingProfile
from repro.core.simulator import ClusterSimulator
from repro.core.serving import ModelClass, generate_requests
from repro.core.sweep import TIMING_KEYS

#: two-site fleet for the hot-stream parity cases: the per-event oracle
#: pays ~20x the chunked wall on these, so halving the stream keeps the
#: suite fast without losing the regime
TWO_SITES = dict(n_sites=2, arrival_skew=(1.0, 1.0))


def _run(scenario, policy, engine, **overrides):
    sim = ClusterSimulator.from_scenario(
        scenario, policy, overrides=dict(serving_engine=engine, **overrides))
    r = sim.run()
    s = {k: v for k, v in r.summary().items() if k not in TIMING_KEYS}
    return s, r


def _assert_parity(scenario, policy, **overrides):
    a, ra = _run(scenario, policy, "chunked", **overrides)
    b, rb = _run(scenario, policy, "event", **overrides)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert ra.ticks == rb.ticks
    return ra


def _conserved(r):
    assert r.requests_arrived == (r.requests_served + r.requests_dropped
                                  + r.requests_shed)


# ---------------------------------------------------------------------------
# parity across routers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("router", ["nearest", "green-first", "carbon-slo"])
def test_chunked_matches_event_across_routers(router):
    r = _assert_parity(
        "paper-table6", "static", n_jobs=0, days=2,
        serving=ServingProfile(req_per_s_per_site=0.05),
        serving_router=router)
    assert r.requests_served > 0
    _conserved(r)


def test_chunked_matches_event_on_train_plus_serve():
    # training migrations interleave with serving spans: the deferred
    # bill buffer must drain before every training posting so the
    # ledger's shared conservation accumulators see the per-event order
    r = _assert_parity("train-plus-serve", "feasibility-aware")
    assert r.requests_served > 0 and r.completed > 0


# ---------------------------------------------------------------------------
# edge regimes
# ---------------------------------------------------------------------------

def test_chunked_parity_at_overflow_boundary():
    # one replica, a two-batch queue and a hot stream: overflow drops
    # land exactly at batch-close boundaries, where the chunk span must
    # abort and replay per-event to keep the drop set identical
    r = _assert_parity(
        "paper-table6", "static", n_jobs=0, days=1, **TWO_SITES,
        serving=ServingProfile(req_per_s_per_site=1.5, max_batch=2,
                               max_queue_batches=2, replicas_per_site=1),
        serving_router="nearest")
    assert r.requests_dropped > 0
    _conserved(r)


def test_chunked_parity_with_zero_replica_site():
    r = _assert_parity(
        "paper-table6", "static", n_jobs=0, days=1,
        serving=ServingProfile(req_per_s_per_site=0.3,
                               replicas_by_site=(2, 0, 2, 2, 2)),
        serving_router="nearest")
    assert r.requests_served > 0
    _conserved(r)


def test_chunked_parity_across_fault_edges():
    # blackout-cascade: chunk spans end on fault/window edges; the merge
    # must hand exactly the same state back to the per-event engine at
    # every boundary
    r = _assert_parity(
        "blackout-cascade", "plan-ahead", days=2,
        serving=ServingProfile(req_per_s_per_site=0.05),
        serving_router="carbon-slo")
    assert r.requests_arrived > 0
    _conserved(r)


def test_chunked_parity_at_max_batch_fill():
    # max_batch=2 under a hot stream: most batches close by fill, not
    # timeout — the fill-jump positions in the precomputed unit
    # partition carry the span segmentation
    r = _assert_parity(
        "paper-table6", "static", n_jobs=0, days=1, **TWO_SITES,
        serving=ServingProfile(req_per_s_per_site=1.0, max_batch=2),
        serving_router="nearest")
    assert r.requests_served > 0
    _conserved(r)


# ---------------------------------------------------------------------------
# acceptance-scale conservation + proactive shedding
# ---------------------------------------------------------------------------

def test_conservation_audit_at_million_request_rate():
    sim = ClusterSimulator.from_scenario(
        "inference-heavy", "static",
        overrides=dict(serving_engine="chunked"))
    r = sim.run()
    assert r.requests_arrived >= 1_000_000
    _conserved(r)
    assert r.requests_served == r.requests_arrived  # headroom: no drops
    assert r.latency_p95_s > 0.0
    # the serving energy ledger balanced per site (sources == sinks is
    # asserted inside audit; a stale deferred-bill buffer would throw)
    sim.ledger.audit()


def test_proactive_shed_on_blackout_cascade():
    # rolling blackouts + carbon-slo: once the fault plan is active, a
    # batch no candidate can finish inside the SLO budget is shed
    # instead of queued for a guaranteed miss.  A model class whose
    # service cost sits right at its SLO makes every batch infeasible,
    # so the assertion doesn't need an hour of queue buildup — and the
    # shed column stays separate from overflow drops
    slow = (ModelClass(name="xl", frac=1.0, batch_s=2.4, per_req_s=0.05,
                       slo_s=2.5, req_bytes=2.0e6),)
    sim = ClusterSimulator.from_scenario(
        "blackout-cascade", "plan-ahead",
        overrides=dict(
            days=1, serving_engine="chunked",
            serving=ServingProfile(req_per_s_per_site=0.02,
                                   model_classes=slow,
                                   batch_timeout_s=0.2,
                                   replicas_per_site=1),
            serving_router="carbon-slo"))
    r = sim.run()
    assert r.requests_shed > 0
    _conserved(r)
    sim.ledger.audit()


# ---------------------------------------------------------------------------
# RNG stream stability
# ---------------------------------------------------------------------------

def test_zero_replica_site_leaves_other_streams_identical():
    # generate_requests skips dead sites *before* building their RNG, so
    # zeroing one site's replicas must leave every other site's arrival
    # stream byte-identical — the regression that would silently move
    # all serving digits if the skip happened after the draws
    full = generate_requests(
        ServingProfile(req_per_s_per_site=0.05), 4, 1, seed=7)
    dead = generate_requests(
        ServingProfile(req_per_s_per_site=0.05,
                       replicas_by_site=(2, 0, 2, 2)), 4, 1, seed=7)
    assert {r.origin for r in dead} == {0, 2, 3}
    for site in (0, 2, 3):
        fa = [(r.t_arrival_s, r.cls.name, r.deadline_s)
              for r in full if r.origin == site]
        da = [(r.t_arrival_s, r.cls.name, r.deadline_s)
              for r in dead if r.origin == site]
        assert fa == da


# ---------------------------------------------------------------------------
# property-based burst fuzzing (hypothesis-gated)
# ---------------------------------------------------------------------------

def test_chunked_parity_under_random_bursts():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(
        seed=st.integers(min_value=0, max_value=2**16),
        rate=st.sampled_from([0.02, 0.1, 0.5, 1.5]),
        max_batch=st.sampled_from([1, 2, 8]),
        timeout_s=st.sampled_from([0.5, 2.0, 10.0]),
        max_q=st.sampled_from([1, 2, 16]))
    def prop(seed, rate, max_batch, timeout_s, max_q):
        r = _assert_parity(
            "paper-table6", "static", n_jobs=0, days=1, seed=seed,
            serving=ServingProfile(
                req_per_s_per_site=rate, max_batch=max_batch,
                batch_timeout_s=timeout_s, max_queue_batches=max_q,
                replicas_per_site=1),
            serving_router="nearest")
        _conserved(r)

    prop()
