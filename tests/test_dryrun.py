"""Dry-run machinery: HLO collective parsing unit tests + one real
(arch × shape × 256-device mesh) lowering in a subprocess (the 512-device
override must not leak into this test process, per the assignment)."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import _shape_bytes, collective_bytes

HLO = """
  %ag = bf16[16,4096,5120]{2,1,0} all-gather(bf16[1,4096,5120]{2,1,0} %p), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %y), dimensions={0}
  %a2a = (f32[8,32]{1,0}, f32[8,32]{1,0}) all-to-all(f32[8,32]{1,0} %a, f32[8,32]{1,0} %b), dimensions={0}
  %cp = u32[2]{0} collective-permute(u32[2]{0} %c), source_target_pairs={{0,1}}
  %notacoll = f32[4]{0} add(f32[4]{0} %d, f32[4]{0} %e)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096,5120]") == 16 * 4096 * 5120 * 2
    assert _shape_bytes("f32[1024]") == 4096
    assert _shape_bytes("(f32[8,32], f32[8,32])") == 2 * 8 * 32 * 4


def test_collective_bytes_parse():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 16 * 4096 * 5120 * 2
    assert got["all-reduce"] == 1024 * 4
    assert got["reduce-scatter"] == 64 * 128 * 4
    assert got["all-to-all"] == 2 * 8 * 32 * 4
    assert got["collective-permute"] == 2 * 4
    assert "add" not in got


@pytest.mark.slow
def test_one_cell_lowers_on_production_mesh(tmp_path):
    """Deliverable (e) spot check: a real cell lowers+compiles on the
    16x16 production mesh (full sweep lives in launch/dryrun.py --all)."""
    code = (
        "from repro.launch.dryrun import lower_cell\n"
        "import json\n"
        "r = lower_cell('qwen3-1.7b', 'decode_32k', multi_pod=False, save_artifact=False)\n"
        "print(json.dumps({'status': r['status'], 'peak': r['memory']['peak_bytes']}))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["status"] == "OK"
    assert rec["peak"] < 16 * 2 ** 30  # fits v5e HBM
