"""Next-event engine: parity with the fixed-dt reference on the paper
scenarios, determinism, exact energy conservation, and the WAN-topology
scenarios end-to-end (simulator + dryrun --plan + serve --green-route all
consuming the same WanTopology)."""
import numpy as np
import pytest

from repro.core import ClusterSimulator, get_scenario
from repro.core.wan import WanTopology

GBPS = 1e9


def run_both(scenario, policy, **overrides):
    out = {}
    for engine in ("fixed-dt", "event"):
        sim = ClusterSimulator.from_scenario(
            scenario, policy, overrides=dict(engine=engine, **overrides))
        out[engine] = sim.run()
    return out["fixed-dt"], out["event"]


# ---------------------------------------------------------------------------
# Parity: the event engine reproduces fixed-dt results within tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,policy", [
    ("paper-table6", "feasibility-aware"),
    ("paper-table6", "energy-only"),
    ("flaky-wan", "feasibility-aware"),
    ("flaky-wan", "energy-only"),
])
def test_event_engine_matches_fixed_dt(scenario, policy):
    """Full 7-day/240-job runs: grid/renewable kWh, completions and
    migrations agree between engines (not bit-for-bit — fixed-dt rounds
    completions up to the next 30 s tick; the event engine is exact)."""
    fixed, event = run_both(scenario, policy)
    assert event.engine == "event" and fixed.engine == "fixed-dt"
    assert event.completed == fixed.completed == 240
    assert event.grid_kwh == pytest.approx(fixed.grid_kwh, rel=0.05)
    assert event.renewable_kwh == pytest.approx(fixed.renewable_kwh, rel=0.05)
    assert event.migrations == pytest.approx(fixed.migrations, rel=0.15)
    assert abs(event.failed_migrations - fixed.failed_migrations) <= 5
    assert event.mean_jct_s == pytest.approx(fixed.mean_jct_s, rel=0.07)
    # the whole point: far fewer steps than fixed-dt ticks
    assert event.ticks < fixed.ticks / 3


@pytest.mark.parametrize("scenario,policy", [
    ("paper-table6", "grid-throttle"),
    ("paper-table6", "defer-to-window"),
    ("forecastable-brownouts", "plan-ahead"),
    ("carbon-peaks", "receding-horizon"),
])
def test_event_engine_parity_for_action_policies(scenario, policy):
    """Engine parity beyond migrate-style policies: Throttle, Defer and the
    plan-ahead Pause/Resume sequences must integrate identically — in
    particular the paused_policy_s / queue_s accounting the fixed-dt loop
    accrues per tick and the event engine integrates per span."""
    fixed, event = run_both(scenario, policy, days=4, n_jobs=120)
    assert event.completed == fixed.completed == 120
    assert event.grid_kwh == pytest.approx(fixed.grid_kwh, rel=0.05)
    assert event.renewable_kwh == pytest.approx(fixed.renewable_kwh, rel=0.05)
    # per-job state accounting (policy-initiated pause + queue time)
    paused_f = sum(j.paused_policy_s for j in fixed.jobs)
    paused_e = sum(j.paused_policy_s for j in event.jobs)
    queue_f = sum(j.queue_s for j in fixed.jobs)
    queue_e = sum(j.queue_s for j in event.jobs)
    assert paused_e == pytest.approx(paused_f, rel=0.15, abs=600.0)
    assert queue_e == pytest.approx(queue_f, rel=0.15, abs=600.0)
    if policy == "grid-throttle":
        # Throttle slows every grid-powered span in both engines alike
        assert all(j.power_frac in (0.5, 1.0) for j in event.jobs)
    if policy == "plan-ahead":
        assert paused_e > 0  # the Pause-for-window plans actually ran
        assert abs(event.failed_migrations - fixed.failed_migrations) <= 3
    if policy == "receding-horizon":
        # the signal accounting integrates identically across engines
        # (analytic per-span vs per-tick rectangle rule)
        assert paused_e > 0  # the park plans actually ran
        assert event.grid_gco2 == pytest.approx(fixed.grid_gco2, rel=0.07)
        assert event.grid_cost == pytest.approx(fixed.grid_cost, rel=0.07)


def test_event_engine_deterministic_given_seed():
    r1 = ClusterSimulator.from_scenario("paper-table6", "feasibility-aware").run()
    r2 = ClusterSimulator.from_scenario("paper-table6", "feasibility-aware").run()
    assert r1.grid_kwh == r2.grid_kwh
    assert r1.renewable_kwh == r2.renewable_kwh
    assert r1.migrations == r2.migrations
    assert r1.ticks == r2.ticks
    assert [j.done_s for j in r1.jobs] == [j.done_s for j in r2.jobs]


def test_event_engine_energy_conservation_is_exact():
    """Analytic per-span integration: total energy equals compute energy
    plus migration energy to float precision (fixed-dt needed 2% slack for
    tick-boundary overshoot)."""
    sim = ClusterSimulator.from_scenario(
        "paper-table6", "feasibility-aware",
        overrides=dict(days=4, n_jobs=120))
    r = sim.run()
    assert r.completed == 120
    compute_kwh = sum(j.progress_s for j in r.jobs) / 3600 * sim.cfg.p_node_kw
    total = r.grid_kwh + r.renewable_kwh
    assert total == pytest.approx(compute_kwh + r.migration_kwh, rel=1e-9)
    for j in r.jobs:
        assert j.progress_s == pytest.approx(j.compute_s, abs=1e-6)


def test_event_engine_summary_surfaces_validity_and_throughput():
    r = ClusterSimulator.from_scenario(
        "paper-table6", "static",
        overrides=dict(days=2, n_jobs=20)).run()
    s = r.summary()
    assert "rejected_actions" in s and s["rejected_actions"] == 0
    assert "ticks_per_sec" in s and s["ticks_per_sec"] > 0


def test_failure_storm_runs_on_event_engine():
    r = ClusterSimulator.from_scenario(
        "failure-storm", "feasibility-aware",
        overrides=dict(days=2, n_jobs=30)).run()
    assert r.failures > 0
    assert r.completed == 30


# ---------------------------------------------------------------------------
# WAN-topology scenarios end-to-end
# ---------------------------------------------------------------------------


NEW_SCENARIOS = ("hub-spoke-wan", "asymmetric-uplink", "partitioned-wan")


@pytest.mark.parametrize("name", NEW_SCENARIOS)
def test_topology_scenarios_run_end_to_end(name):
    sim = ClusterSimulator.from_scenario(
        name, "feasibility-aware", overrides=dict(days=2, n_jobs=24))
    # the simulator consumes the scenario's materialized topology
    scn_topo = get_scenario(name).build_wan()
    np.testing.assert_allclose(sim.wan_topology.link_bps, scn_topo.link_bps)
    np.testing.assert_allclose(sim.wan_topology.nic_out_bps, scn_topo.nic_out_bps)
    r = sim.run()
    assert r.completed == 24
    assert r.rejected_actions == 0


def test_hub_spoke_advertises_thin_spoke_links():
    sim = ClusterSimulator.from_scenario("hub-spoke-wan", "static",
                                         overrides=dict(days=2, n_jobs=4))
    bw = sim.snapshot(0.0).bandwidth_bps
    # multi-hop relaying through the hub lifts spoke-to-spoke to the
    # 10 Gbps spoke NIC rate (direct spoke link is only 1 Gbps)
    assert bw[1, 2] == pytest.approx(10 * GBPS)
    assert bw[0, 1] == pytest.approx(10 * GBPS)  # hub->spoke: spoke NIC binds
    assert bw[1, 0] == pytest.approx(10 * GBPS)


def test_partitioned_wan_advertises_thin_cross_links():
    sim = ClusterSimulator.from_scenario("partitioned-wan", "static",
                                         overrides=dict(days=2, n_jobs=4))
    bw = sim.snapshot(0.0).bandwidth_bps
    assert bw[0, 1] == pytest.approx(10 * GBPS)  # intra-partition
    assert bw[3, 4] == pytest.approx(10 * GBPS)
    assert bw[1, 3] == pytest.approx(0.25 * GBPS)  # cross-partition
    assert bw[4, 2] == pytest.approx(0.25 * GBPS)


def test_asymmetric_uplink_halves_concurrent_evacuations():
    """Two transfers out of one dark site share the 2.5 Gbps egress NIC."""
    sim = ClusterSimulator.from_scenario("asymmetric-uplink", "static",
                                         overrides=dict(days=2, n_jobs=8))
    j0, j1 = sim.jobs[0], sim.jobs[1]
    for j, dest in ((j0, 1), (j1, 2)):
        sim._move(j, state="queued", site=0)
        sim._move(j, state="running")
        j.transfer_dest = dest
        j.transfer_remaining_bits = 8.0 * j.ckpt_bytes
        sim._move(j, state="migrating")
    eff = sim._effective_bw([j0, j1], 0.0)
    assert eff[j0.jid] == pytest.approx(1.25 * GBPS)
    state = sim.snapshot(0.0)
    assert state.bandwidth_bps[0, 1] == pytest.approx(1.25 * GBPS)
    # ingress stays uncontended for other sources
    assert state.bandwidth_bps[3, 4] == pytest.approx(2.5 * GBPS)


def test_plan_and_serve_consume_the_same_topology():
    """dryrun --plan and serve --green-route build their snapshots from
    Scenario.build_wan() — identical to the simulator's topology."""
    from repro.launch.dryrun import plan_orchestration
    from repro.launch.serve import build_serving_state

    state, _actions = plan_orchestration("hub-spoke-wan", "feasibility-aware",
                                         at_hour=12.0)
    assert isinstance(state.wan, WanTopology)
    assert state.bandwidth_bps[1, 2] == pytest.approx(10 * GBPS)  # relayed
    assert state.bandwidth_bps[0, 1] == pytest.approx(10 * GBPS)

    sstate = build_serving_state("asymmetric-uplink", at_hour=12.0)
    assert isinstance(sstate.wan, WanTopology)
    assert sstate.bandwidth_bps[0, 1] == pytest.approx(2.5 * GBPS)

    sim_topo = ClusterSimulator.from_scenario(
        "hub-spoke-wan", "static", overrides=dict(days=2, n_jobs=2)).wan_topology
    np.testing.assert_allclose(state.wan.link_bps, sim_topo.link_bps)


def test_unreachable_migrations_rejected_not_stranded():
    """On a *fully* partitioned fabric (inter_gbps=0) a Migrate across the
    cut can never complete — the simulator must reject it (rejected_actions)
    instead of stranding the job in 'migrating' forever."""
    import dataclasses

    from repro.core import WanProfile, get_scenario, partitioned_links
    from repro.core.scenarios import register_scenario
    from repro.core import scenarios as scn_mod

    base = get_scenario("partitioned-wan")
    hard = base.replace(
        name="partitioned-wan-hard",
        wan=WanProfile(gbps=10.0,
                       link_gbps=partitioned_links(((0, 1, 2), (3, 4)),
                                                   inter_gbps=0.0)))
    register_scenario(hard)
    try:
        r = ClusterSimulator.from_scenario(
            "partitioned-wan-hard", "energy-only",
            overrides=dict(days=2, n_jobs=24)).run()
    finally:
        scn_mod._REGISTRY.pop("partitioned-wan-hard", None)
    assert r.completed == 24  # nobody stranded mid-migration
    assert r.rejected_actions > 0  # cross-cut Migrates were refused
    for j in r.jobs:
        assert j.state == "done"


def test_partitioned_wan_feasibility_prefers_intra_partition():
    """Cross-partition moves are class-B/C at 0.25 Gbps for >7.5 GB
    checkpoints, so the feasibility filter keeps class-B jobs inside their
    island."""
    r = ClusterSimulator.from_scenario(
        "partitioned-wan", "feasibility-aware",
        overrides=dict(days=3, n_jobs=40)).run()
    assert r.completed == 40
    for j in r.jobs:
        if j.size_class == "B" and j.migrations:
            # class B (10-40 GB): 0.25 Gbps transfer >= 320 s => class C
            # cross-partition, so any migration stayed inside the island
            same_island = ({j.home_site, j.site} <= {0, 1, 2}
                           or {j.home_site, j.site} <= {3, 4})
            assert same_island
