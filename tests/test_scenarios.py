"""Scenario registry: built-ins, resolution errors, composability, and
that each scenario actually changes what the simulator sees."""
import dataclasses

import pytest

from repro.core import ClusterSimulator, make_policy
from repro.core.scenarios import (
    JobMix, Scenario, WanProfile, available_scenarios, get_scenario,
    register_scenario,
)
from repro.core.simulator import generate_jobs
from repro.core.traces import TraceProfile

BUILTINS = ("paper-table6", "flaky-wan", "solar-heavy", "large-ckpt-classC",
            "failure-storm", "hub-spoke-wan", "asymmetric-uplink",
            "partitioned-wan", "forecastable-brownouts")


def test_all_builtins_registered():
    names = available_scenarios()
    for b in BUILTINS:
        assert b in names
    for b in BUILTINS:
        scn = get_scenario(b)
        assert scn.name == b
        assert scn.description


def test_unknown_scenario_lists_available():
    with pytest.raises(KeyError) as ei:
        get_scenario("no-such-scenario")
    msg = str(ei.value)
    assert "no-such-scenario" in msg
    for b in BUILTINS:
        assert b in msg


def test_get_scenario_passthrough_and_registration():
    scn = Scenario(name="test-tmp", description="x", wan=WanProfile(gbps=2.0))
    assert get_scenario(scn) is scn
    register_scenario(scn)
    try:
        assert get_scenario("test-tmp").wan.gbps == 2.0
    finally:
        from repro.core import scenarios as _m
        _m._REGISTRY.pop("test-tmp", None)


def test_paper_table6_matches_paper_defaults():
    cfg = get_scenario("paper-table6").sim_config()
    assert cfg.n_sites == 5 and cfg.slots_per_site == 4
    assert cfg.wan_gbps == 10.0 and cfg.days == 7 and cfg.n_jobs == 240
    assert cfg.frac_a == 0.70 and cfg.frac_b == 0.20


def test_sim_config_overrides_win():
    cfg = get_scenario("paper-table6").sim_config(wan_gbps=1.0, dt_s=120.0)
    assert cfg.wan_gbps == 1.0 and cfg.dt_s == 120.0
    assert cfg.n_jobs == 240  # untouched fields keep scenario values
    assert cfg.wan.gbps == 1.0  # scalar override reaches the WanProfile


def test_wan_gbps_override_rejected_when_shadowed_by_nic_gbps():
    """On topology scenarios with per-site NIC rates the uniform wan_gbps
    override would be silently ignored — it must raise instead."""
    with pytest.raises(ValueError, match="nic_gbps"):
        get_scenario("hub-spoke-wan").sim_config(wan_gbps=1.0)
    # partitioned-wan keeps uniform NICs: the override applies there
    cfg = get_scenario("partitioned-wan").sim_config(wan_gbps=1.0)
    assert cfg.wan.gbps == 1.0 and cfg.wan.link_gbps is not None


def test_scenarios_compose_with_replace():
    base = get_scenario("flaky-wan")
    harsher = dataclasses.replace(
        base, name="flaky-wan-1g", wan=dataclasses.replace(base.wan, gbps=1.0))
    assert harsher.wan.hourly_degrade_prob == base.wan.hourly_degrade_prob
    assert harsher.sim_config().wan_gbps == 1.0
    assert base.sim_config().wan_gbps == 10.0  # original untouched


def test_large_ckpt_scenario_skews_job_mix():
    cfg = get_scenario("large-ckpt-classC").sim_config(n_jobs=200)
    jobs = generate_jobs(cfg)
    frac_c = sum(1 for j in jobs if j.size_class == "C") / len(jobs)
    assert frac_c > 0.35  # nominal 50%


def test_solar_heavy_trace_profile_flows_to_traces():
    scn = get_scenario("solar-heavy")
    assert scn.trace.mean_window_h == 6.5
    traces = scn.build_traces()
    from repro.core import trace_stats
    st = trace_stats(traces)
    base = trace_stats(get_scenario("paper-table6").build_traces())
    assert st["mean_h"] > base["mean_h"]


def test_failure_storm_produces_failures():
    sim = ClusterSimulator.from_scenario(
        "failure-storm", "static",
        overrides=dict(days=2, n_jobs=30, dt_s=120.0))
    r = sim.run()
    assert r.failures > 0
    assert r.completed == 30


def test_flaky_wan_has_degraded_hours():
    sim = ClusterSimulator.from_scenario(
        "flaky-wan", "static", overrides=dict(days=2, n_jobs=5, dt_s=120.0))
    rates = {sim._nic_bps(h * 3600.0) for h in range(48)}
    assert rates == {0.5e9, 10e9}  # both degraded and nominal hours occur
