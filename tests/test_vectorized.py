"""Parity between the vectorized decide path and its scalar oracles.

The SoA fast path (batched SiteTrace/Forecaster/ForecastHorizon queries,
``score_migrations``, the vectorized ``Policy.decide`` bodies) must emit
*exactly* what the per-job/per-call scalar implementations emit — same
Action lists, same floats — on arbitrary inputs.  The scalar oracles
(``decide_scalar``, the per-site bisect queries) are kept precisely so
these tests stay meaningful.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic tests still run
    HAS_HYPOTHESIS = False

from repro.core.forecast import ForecastHorizon, OutageForecast, WindowForecast
from repro.core.orchestrator import (
    DeferToWindowPolicy, EnergyOnlyPolicy, FeasibilityAwarePolicy,
    GridThrottlePolicy, PlanAheadPolicy, RecedingHorizonPolicy,
    algorithm1_grid, benefit_grid_arrays, feasibility_grid_arrays,
    pick_best_grid, score_migrations,
)
from repro.core.signals import generate_signals
from repro.core.state import ClusterState, JobView, SiteView
from repro.core.traces import Forecaster, SiteTrace, Window, stack_traces

GB = 1e9
HOUR = 3600.0


# ---------------------------------------------------------------------------
# deterministic fixtures
# ---------------------------------------------------------------------------


def make_traces(seed=0, n_sites=4, days=3):
    rng = np.random.default_rng(seed)
    traces = []
    for s in range(n_sites):
        wins, t0 = [], 0.0
        for _ in range(rng.integers(0, days * 2 + 1)):
            gap = float(rng.uniform(0.5, 8.0)) * HOUR
            dur = float(rng.uniform(0.5, 6.0)) * HOUR
            wins.append(Window(t0 + gap, t0 + gap + dur))
            t0 += gap + dur
        traces.append(SiteTrace(s, wins))
    return traces


def make_horizon(seed=0, n_sites=4, with_outages=True, with_signals=None):
    rng = np.random.default_rng(seed + 100)
    site_windows = []
    for s in range(n_sites):
        wins, t0 = [], 0.0
        for _ in range(int(rng.integers(0, 5))):
            gap = float(rng.uniform(0.5, 8.0)) * HOUR
            dur = float(rng.uniform(0.5, 6.0)) * HOUR
            wins.append(WindowForecast(t0 + gap, t0 + gap + dur))
            t0 += gap + dur
        site_windows.append(tuple(wins))
    outages = []
    if with_outages:
        for _ in range(int(rng.integers(0, 12))):
            src = int(rng.integers(-1, n_sites))
            dst = int(rng.integers(0, n_sites)) if src >= 0 else -1
            if src == dst:
                continue
            a = float(rng.uniform(0, 40)) * HOUR
            outages.append(OutageForecast(
                a, a + float(rng.uniform(0.5, 4.0)) * HOUR,
                src if src >= 0 else -1, dst, float(rng.uniform(0, 2e9))))
    outages.sort(key=lambda o: (o.start_s, o.src, o.dst))
    # roughly half the random horizons carry grid signals (some with
    # demand-response events) so the signal-aware paths see both regimes
    if with_signals is None:
        with_signals = bool(rng.random() < 0.5)
    signals = None
    if with_signals:
        thr = 500.0 if rng.random() < 0.5 else None
        signals = generate_signals(n_sites, 3, seed=seed,
                                   curtail_threshold=thr)
    return ForecastHorizon(horizon_s=24 * HOUR, sigma_s=0.0,
                           site_windows=tuple(site_windows),
                           outages=tuple(outages), signals=signals)


QUERY_TS = [0.0, 0.3 * HOUR, 1.0 * HOUR, 5.7 * HOUR, 12.0 * HOUR,
            25.1 * HOUR, 47.9 * HOUR, 80.0 * HOUR]


# ---------------------------------------------------------------------------
# batched SiteTrace / Forecaster queries
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_trace_stack_point_queries_match_scalar(seed):
    traces = make_traces(seed)
    stack = stack_traces(traces)
    for t in QUERY_TS:
        act, rem, nxt = stack.point(t)
        for s, tr in enumerate(traces):
            assert bool(act[s]) == tr.active(t)
            assert float(rem[s]) == tr.remaining(t)
            nw = tr.next_window(t)
            want = nw.start_s if nw is not None else float("inf")
            assert float(nxt[s]) == want


@pytest.mark.parametrize("seed", range(6))
def test_trace_stack_renewable_seconds_matches_scalar(seed):
    traces = make_traces(seed)
    stack = stack_traces(traces)
    rng = np.random.default_rng(seed)
    sites = rng.integers(0, len(traces), 64)
    t0s = rng.uniform(0, 60 * HOUR, 64)
    t1 = 61 * HOUR
    got = stack.renewable_seconds(sites, t0s, t1)
    for k in range(64):
        want = traces[int(sites[k])].renewable_seconds(float(t0s[k]), t1)
        # cumulative-difference formulation: equal to float round-off
        assert got[k] == pytest.approx(want, abs=1e-6)


def test_forecaster_batched_draws_match_scalar_stream():
    traces = make_traces(3)
    t_seq = [0.0, 2 * HOUR, 7 * HOUR, 30 * HOUR]
    fa = Forecaster(traces, sigma_s=900.0, seed=11)
    fb = Forecaster(traces, sigma_s=900.0, seed=11)
    for t in t_seq:
        # scalar: per-site calls in site order (the old snapshot loop)
        scalar_rem = [fa.remaining(s, t) for s in range(len(traces))]
        scalar_nxt = [fa.next_window_start(s, t) for s in range(len(traces))]
        act, rem, nxt = fb.snapshot_all(t)
        assert [float(x) for x in rem] == scalar_rem
        assert [float(x) for x in nxt] == scalar_nxt
        assert [bool(a) for a in act] == [traces[s].active(t)
                                         for s in range(len(traces))]


# ---------------------------------------------------------------------------
# batched ForecastHorizon grids
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_forecast_grids_match_scalar_queries(seed):
    fc = make_horizon(seed)
    n = fc.n_sites
    for t in QUERY_TS:
        o_start, o_end, o_cap = fc.next_outage_grid(t)
        after = fc.next_outage_start_after_grid(t)
        up = fc.next_uplink_outage_grid(t)
        nws = fc.next_window_start_grid(t)
        cn = fc.window_open_or_next_start_grid(t)
        for s in range(n):
            assert float(nws[s]) == fc.next_window_start_s(s, t)
            w = fc.next_window(s, t)
            assert float(cn[s]) == (w.start_s if w is not None
                                    else float("inf"))
            assert float(up[s]) == fc.next_uplink_outage_start_s(s, t)
            for d in range(n):
                o = fc.next_outage(s, d, t)
                if o is None:
                    assert float(o_start[s, d]) == float("inf")
                else:
                    assert float(o_start[s, d]) == o.start_s
                    assert float(o_end[s, d]) == o.end_s
                    assert float(o_cap[s, d]) == o.capacity_bps
                assert float(after[s, d]) == fc.next_outage_start_after(
                    s, d, t)


def test_forecast_grids_fresh_after_reveal_edge():
    """Regression: an epoch-cached grid queried exactly at a reveal edge
    (t == start - horizon, where `start < t + horizon` is still False)
    must not serve that pre-reveal value for later ticks in the same
    epoch.  Orch ticks land exactly on hour-aligned edges all the time."""
    w = WindowForecast(30 * HOUR, 33 * HOUR)
    fc = ForecastHorizon(horizon_s=24 * HOUR, sigma_s=0.0,
                         site_windows=((w,),), outages=())
    t_edge = 6 * HOUR  # == start - horizon: window NOT yet visible
    assert float(fc.next_window_start_grid(t_edge)[0]) == float("inf")
    assert fc.next_window_start_s(0, t_edge) == float("inf")
    t_in = t_edge + 600.0  # same epoch, window now inside the lookahead
    assert float(fc.next_window_start_grid(t_in)[0]) == 30 * HOUR
    assert fc.next_window_start_s(0, t_in) == 30 * HOUR
    assert float(fc.window_open_or_next_start_grid(t_in)[0]) == 30 * HOUR
    # outage grids: same shape of bug, via the dual-keyed cache
    o = OutageForecast(30 * HOUR, 31 * HOUR, 0, 1, 1e9)
    fo = ForecastHorizon(horizon_s=24 * HOUR, sigma_s=0.0,
                         site_windows=((), ()), outages=(o,))
    assert float(fo.next_outage_grid(t_edge)[0][0, 1]) == float("inf")
    assert float(fo.next_outage_grid(t_in)[0][0, 1]) == 30 * HOUR
    assert float(fo.next_outage_start_after_grid(t_edge)[0, 1]) == float("inf")
    assert float(fo.next_outage_start_after_grid(t_in)[0, 1]) == 30 * HOUR


@pytest.mark.parametrize("seed", range(4))
def test_forecast_grids_match_scalar_on_shared_horizon_sequences(seed):
    """Parity on ONE horizon object queried at an increasing tick
    sequence that includes exact breakpoints — the access pattern the
    simulator produces and the epoch caches must survive."""
    fc = make_horizon(seed)
    ts = sorted(set(
        [o.start_s for o in fc.outages]
        + [o.end_s for o in fc.outages]
        + [o.start_s - fc.horizon_s for o in fc.outages]
        + [w.start_s - fc.horizon_s for wins in fc.site_windows for w in wins]
        + [w.start_s for wins in fc.site_windows for w in wins]
        + list(np.linspace(0, 50 * HOUR, 23))))
    ts = [t for t in ts if t >= 0] + [t + 1.0 for t in ts if t >= 0]
    for t in sorted(ts):
        nws = fc.next_window_start_grid(t)
        after = fc.next_outage_start_after_grid(t)
        o_start, _, _ = fc.next_outage_grid(t)
        for s in range(fc.n_sites):
            assert float(nws[s]) == fc.next_window_start_s(s, t), t
            for d in range(fc.n_sites):
                o = fc.next_outage(s, d, t)
                want = o.start_s if o is not None else float("inf")
                assert float(o_start[s, d]) == want, (t, s, d)
                assert float(after[s, d]) == fc.next_outage_start_after(
                    s, d, t), (t, s, d)


def test_feasibility_grid_arrays_matches_algorithm1_grid():
    jobs = [JobView(i, i % 3, float(sz) * GB, 8 * HOUR)
            for i, sz in enumerate((2, 30, 250, 7, 90))]
    sites = [SiteView(s, 4, s, 1, s % 2 == 0, [0.0, 2.5 * HOUR, 9 * HOUR][s])
             for s in range(3)]
    state = ClusterState.build(0.0, jobs, sites, nic_bps=1e9)
    for eps, sigma in ((0.0, 0.0), (0.05, 900.0)):
        ok_ref, tt_ref = algorithm1_grid(state, jobs, alpha=0.1, eps=eps,
                                         forecast_sigma_s=sigma)
        soa = state.soa
        cand = np.arange(len(jobs))
        ok, tt = feasibility_grid_arrays(
            soa.ckpt_bytes[cand][:, None], soa.t_load_s[cand][:, None],
            state.bandwidth_bps[soa.site[cand], :],
            state.site_window_s[None, :], alpha=0.1, eps=eps,
            forecast_sigma_s=sigma)
        assert np.array_equal(np.asarray(ok_ref, bool), ok)
        assert np.array_equal(np.asarray(tt_ref), tt)


@pytest.mark.parametrize("seed", range(8))
def test_score_migrations_equals_composed_kernels(seed):
    """The fused hot-path kernel must stay in lockstep with its readable
    building blocks (feasibility_grid_arrays + benefit_grid_arrays +
    pick_best_grid) — this is what keeps the three copies of the stage-2
    arithmetic from drifting apart."""
    state = random_state(seed, with_forecast=False)
    soa = state.soa
    cand = np.flatnonzero((soa.state == 1) & soa.eligible)
    if not len(cand):
        return
    bw = state.bandwidth_bps[soa.site[cand], :]
    kw = dict(alpha=0.1, gamma=1.0, beta=1.0, queue_penalty_s=7200.0,
              min_benefit_s=1500.0)
    ok, tt, dest0 = score_migrations(state, cand, bw, **kw)
    ok_ref, tt_ref = feasibility_grid_arrays(
        soa.ckpt_bytes[cand][:, None], soa.t_load_s[cand][:, None], bw,
        state.site_window_s[None, :], alpha=kw["alpha"])
    benefit, t_cost = benefit_grid_arrays(
        state, cand, tt_ref, gamma=kw["gamma"], beta=kw["beta"],
        queue_penalty_s=kw["queue_penalty_s"])
    valid = (ok_ref
             & (np.arange(state.n_sites)[None, :] != soa.site[cand][:, None])
             & (benefit > np.maximum(t_cost, kw["min_benefit_s"])))
    dest_ref = pick_best_grid(benefit, tt_ref, valid) if valid.any() else None
    assert np.array_equal(ok, ok_ref) and np.array_equal(tt, tt_ref)
    if dest_ref is None:
        assert dest0 is None
    else:
        assert np.array_equal(dest0, dest_ref)


# ---------------------------------------------------------------------------
# vectorized Policy.decide == decide_scalar
# ---------------------------------------------------------------------------

POLICIES = [
    FeasibilityAwarePolicy(),
    FeasibilityAwarePolicy(min_benefit_s=0.0),
    FeasibilityAwarePolicy(eps=0.05, forecast_sigma_s=900.0),
    EnergyOnlyPolicy(),
    GridThrottlePolicy(power_frac=0.5),
    DeferToWindowPolicy(),
    PlanAheadPolicy(),
    PlanAheadPolicy(min_benefit_s=0.0, arrival_margin_s=0.0),
    RecedingHorizonPolicy(),
    RecedingHorizonPolicy(min_benefit_g=0.0, delay_cost_g_per_s=0.0,
                          peak_threshold_g=200.0),
    RecedingHorizonPolicy(price_weight_g_per_usd=5000.0),
]


def random_state(seed, with_forecast=True, t=1.7 * HOUR):
    rng = np.random.default_rng(seed)
    n_sites = int(rng.integers(2, 6))
    sites = []
    for s in range(n_sites):
        green = bool(rng.random() < 0.5)
        sites.append(SiteView(
            sid=s, slots=int(rng.integers(1, 5)), busy=int(rng.integers(0, 5)),
            queued=int(rng.integers(0, 4)), renewable_active=green,
            window_remaining_s=float(rng.uniform(0, 9 * HOUR)) if green else 0.0,
            incoming=int(rng.integers(0, 2)),
            next_window_start_s=(t + float(rng.uniform(0, 9 * HOUR))
                                 if rng.random() < 0.8 else float("inf")),
        ))
    jobs = []
    for j in range(int(rng.integers(0, 14))):
        jobs.append(JobView(
            jid=j, site=int(rng.integers(0, n_sites)),
            ckpt_bytes=float(rng.uniform(0.1, 400)) * GB,
            remaining_compute_s=float(rng.uniform(600, 24 * HOUR)),
            state=("queued", "running", "paused")[int(rng.integers(0, 3))],
            eligible=bool(rng.random() < 0.8),
            power_frac=float(rng.choice([1.0, 0.5])),
            defer_until_s=(t + float(rng.uniform(-1, 2)) * HOUR
                           if rng.random() < 0.3 else -1e18),
        ))
    transfers = tuple(
        (int(rng.integers(0, n_sites)), int(rng.integers(0, n_sites)))
        for _ in range(int(rng.integers(0, 3))))
    fc = make_horizon(seed, n_sites=n_sites) if with_forecast else None
    return ClusterState.build(t, jobs, sites, nic_bps=2e9,
                              transfers=transfers, forecast=fc)


@pytest.mark.parametrize("with_forecast", [True, False])
@pytest.mark.parametrize("seed", range(30))
def test_vectorized_decide_matches_scalar_oracle(seed, with_forecast):
    state = random_state(seed, with_forecast)
    for pol in POLICIES:
        got = pol.decide(state)
        want = pol.decide_scalar(state)
        assert got == want, (pol.name, got, want)


if HAS_HYPOTHESIS:
    @settings(max_examples=120, deadline=None)
    @given(st.integers(0, 10_000), st.booleans(),
           st.floats(min_value=0.0, max_value=100 * HOUR))
    def test_vectorized_decide_matches_scalar_oracle_hypothesis(
            seed, with_forecast, t):
        state = random_state(seed, with_forecast, t=t)
        for pol in POLICIES:
            assert pol.decide(state) == pol.decide_scalar(state), pol.name

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000),
           st.floats(min_value=0.0, max_value=60 * HOUR),
           st.floats(min_value=0.0, max_value=60 * HOUR))
    def test_trace_stack_matches_scalar_hypothesis(seed, t0, dt):
        traces = make_traces(seed % 50, n_sites=3)
        stack = stack_traces(traces)
        act, rem, nxt = stack.point(t0)
        for s, tr in enumerate(traces):
            assert bool(act[s]) == tr.active(t0)
            assert float(rem[s]) == tr.remaining(t0)
        got = stack.renewable_seconds(
            np.arange(len(traces)), np.full(len(traces), t0), t0 + dt)
        for s, tr in enumerate(traces):
            assert got[s] == pytest.approx(
                tr.renewable_seconds(t0, t0 + dt), abs=1e-6)
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_vectorized_decide_matches_scalar_oracle_hypothesis():
        pass
