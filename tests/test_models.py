"""Per-architecture smoke tests (reduced configs, CPU): one train step with
shape + finiteness assertions, decode-vs-prefill equivalence, mixer-level
oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config, param_count
from repro.models import build_model
from repro.models import xlstm as xlstm_lib
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.train_step import TrainStepConfig, make_train_step


def small_batch(model, cfg, B=2, S=16):
    batch = {}
    key = jax.random.PRNGKey(1)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)).astype(cfg.dtype)
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model)).astype(cfg.dtype)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["positions"] = jnp.stack([pos] * 3, axis=-1)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, output shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = small_batch(model, cfg)
    logits, aux = model.forward(params, batch)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = make_train_step(model, TrainStepConfig(opt=AdamWConfig(lr=1e-3)))
    opt = init_opt_state(params)
    p2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # params actually changed
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "phi3.5-moe-42b-a6.6b",
                                  "jamba-v0.1-52b", "gemma2-2b", "qwen2-vl-7b"])
def test_arch_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 32
    cache = model.init_cache(B, T)
    db = {"index": jnp.int32(0)}
    if cfg.input_mode == "embeddings":
        db["embeds"] = jnp.ones((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        db["token"] = jnp.array([1, 2], jnp.int32)
    if cfg.rope_type == "mrope":
        db["positions"] = jnp.zeros((B, 1, 3), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, db)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-2b", "jamba-v0.1-52b", "xlstm-1.3b"])
def test_prefill_decode_equivalence(arch):
    """Step-by-step decode reproduces the full-sequence forward exactly."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(
            params, cache, {"token": toks[:, t], "index": jnp.int32(t)}
        )
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4, rtol=2e-4)


def test_mlstm_chunked_matches_recurrent():
    b, s, H, dh = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q, k, v = (jax.random.normal(ks[i], (b, s, H, dh)) for i in range(3))
    i_raw = jax.random.normal(ks[3], (b, s, H))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, s, H)) + 2)
    h1, c1 = xlstm_lib.mlstm_cell(q, k, v, i_raw, logf, chunk=16)
    h2, c2 = xlstm_lib.mlstm_cell_recurrent(q, k, v, i_raw, logf)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-5, rtol=2e-4)
    for a, b_ in zip(c1, c2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5, rtol=2e-4)


def test_gemma2_softcap_and_window_active():
    cfg = get_config("gemma2-2b").reduced()
    assert cfg.attn_softcap == 50.0 and cfg.logit_softcap == 30.0
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = small_batch(model, cfg, S=24)
    logits, _ = model.forward(params, batch)
    assert float(jnp.max(jnp.abs(logits.astype(jnp.float32)))) <= 30.0


def test_assigned_config_dims_exact():
    """The 10 assigned architecture configs carry the exact assigned dims."""
    want = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }
    for arch, (L, d, nh, nkv, dff, V) in want.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
            L, d, nh, nkv, dff, V), arch
    # MoE details
    assert get_config("phi3.5-moe-42b-a6.6b").num_experts == 16
    assert get_config("phi3.5-moe-42b-a6.6b").top_k == 2
    assert get_config("granite-moe-1b-a400m").num_experts == 32
    assert get_config("granite-moe-1b-a400m").top_k == 8
    assert get_config("jamba-v0.1-52b").num_experts == 16


def test_param_counts_sane():
    """Analytic param_count lands in the advertised ballpark."""
    bounds = {
        "qwen2.5-32b": (28e9, 36e9),
        "qwen1.5-32b": (28e9, 36e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "jamba-v0.1-52b": (46e9, 58e9),
        "gemma2-2b": (2.0e9, 3.3e9),
        "qwen3-1.7b": (1.5e9, 2.3e9),
        "xlstm-1.3b": (1.5e9, 2.4e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
    }
    for arch, (lo, hi) in bounds.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_param_count_matches_instantiated():
    """Analytic count == instantiated pytree count (exact) for a reduced
    config of each family."""
    for arch in ["qwen3-1.7b", "phi3.5-moe-42b-a6.6b", "jamba-v0.1-52b", "xlstm-1.3b"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))
        analytic = param_count(cfg)
        assert abs(actual - analytic) / actual < 0.12, (arch, actual, analytic)


def test_long_context_eligibility():
    subq = {a for a in ASSIGNED if get_config(a).is_subquadratic}
    assert subq == {"jamba-v0.1-52b", "xlstm-1.3b"}
    for a in ASSIGNED:
        shapes = get_config(a).shapes()
        assert ("long_500k" in shapes) == (a in subq)
