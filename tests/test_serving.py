"""Serving plane: request generation, router registry, queue/batch
conservation invariants, Little's-law accounting on a stationary Poisson
stream, sweep determinism across worker counts, the serving-off ==
bit-identical guarantee, and the headline acceptance claim (carbon-slo
routes strictly less request carbon than nearest at equal SLO attainment
on train-plus-serve, 8 seeds, mean +/- 95% CI)."""
import dataclasses

import numpy as np
import pytest

from repro.core.serving import (
    DEFAULT_MODEL_CLASSES, ModelClass, Router, ServingProfile,
    available_routers, generate_requests, make_router, register_router,
)
from repro.core.simulator import ClusterSimulator
from repro.core.sweep import SweepSpec, run_sweep

HOUR = 3600.0


# ---------------------------------------------------------------------------
# request generation
# ---------------------------------------------------------------------------

def test_generate_requests_deterministic_and_sorted():
    prof = ServingProfile(req_per_s_per_site=0.01)
    a = generate_requests(prof, 4, 1, seed=3)
    b = generate_requests(prof, 4, 1, seed=3)
    assert len(a) == len(b) > 0
    assert [(r.t_arrival_s, r.origin, r.cls.name) for r in a] == \
           [(r.t_arrival_s, r.origin, r.cls.name) for r in b]
    ts = [r.t_arrival_s for r in a]
    assert ts == sorted(ts)
    assert {r.origin for r in a} <= set(range(4))
    # a different seed reshuffles the stream
    c = generate_requests(prof, 4, 1, seed=4)
    assert [(r.t_arrival_s, r.origin) for r in a] != \
           [(r.t_arrival_s, r.origin) for r in c]


def test_generate_requests_trace_mode_replays_verbatim():
    trace = ((10.0, 1), (20.0, 0), (30.0, 2), (40.0, 99))  # 99 out of range
    prof = ServingProfile(arrival_trace=trace)
    reqs = generate_requests(prof, 3, 1, seed=0)
    assert [(r.t_arrival_s, r.origin) for r in reqs] == \
           [(10.0, 1), (20.0, 0), (30.0, 2)]
    for r in reqs:
        assert r.deadline_s == pytest.approx(r.t_arrival_s + r.cls.slo_s)


def test_diurnal_peak_concentrates_arrivals():
    prof = ServingProfile(req_per_s_per_site=0.02, diurnal_amplitude=1.0,
                          peak_hour=20.0, peak_width_h=2.0, site_spread=0.0)
    reqs = generate_requests(prof, 2, 4, seed=0)
    hod = np.array([(r.t_arrival_s / HOUR) % 24.0 for r in reqs])
    near_peak = ((hod > 18.0) & (hod < 22.0)).mean()
    off_peak = ((hod > 6.0) & (hod < 10.0)).mean()
    assert near_peak > 1.5 * off_peak


# ---------------------------------------------------------------------------
# router registry
# ---------------------------------------------------------------------------

def test_router_registry_round_trip():
    names = available_routers()
    assert {"nearest", "green-first", "carbon-slo"} <= set(names)
    for name in names:
        r = make_router(name)
        assert isinstance(r, Router)
    # aliases resolve to the same class as the canonical name
    assert type(make_router("green")) is type(make_router("green-first"))
    assert type(make_router("carbon")) is type(make_router("carbon-slo"))
    assert type(make_router("latency")) is type(make_router("nearest"))
    # normalization: case / underscores
    assert type(make_router("Green_First")) is type(make_router("green-first"))


def test_make_router_unknown_lists_available():
    with pytest.raises(KeyError, match="carbon-slo"):
        make_router("no-such-router")


def test_register_router_rejects_duplicates():
    with pytest.raises(ValueError):
        @register_router("nearest")
        class Dup(Router):  # pragma: no cover - registration fails first
            def route(self, batch, state):
                return batch.origin


# ---------------------------------------------------------------------------
# conservation + Little's law (serving-only runs)
# ---------------------------------------------------------------------------

def serving_only_sim(prof, scenario="paper-table6", router="nearest",
                     **overrides):
    return ClusterSimulator.from_scenario(
        scenario, "static",
        overrides=dict(n_jobs=0, engine="event", serving=prof,
                       serving_router=router, **overrides))


def test_request_conservation_with_audit():
    prof = ServingProfile(req_per_s_per_site=0.01, validate=True)
    sim = serving_only_sim(prof, days=1)
    res = sim.run()
    plane = sim.serving
    assert res.requests_arrived == len(plane.requests) > 0
    assert res.requests_arrived == res.requests_served + res.requests_dropped
    assert plane.in_flight == 0
    assert sum(plane.site_served) == res.requests_served
    assert sum(plane.site_routed) == res.requests_served + res.requests_dropped
    assert len(plane.latencies) == res.requests_served
    assert res.request_gco2 == pytest.approx(sum(plane.site_request_gco2))


def test_littles_law_on_stationary_poisson():
    """With all requests eventually served, integral N dt must equal the
    summed sojourn times — L = lambda * W as an accounting identity."""
    cls = (ModelClass("uni", 1.0, 0.3, 0.05, 60.0, 1e5),)
    prof = ServingProfile(req_per_s_per_site=0.02, diurnal_amplitude=0.0,
                          site_spread=0.0, model_classes=cls)
    sim = serving_only_sim(prof, days=1)
    res = sim.run()
    plane = sim.serving
    assert res.requests_served > 100
    assert res.requests_dropped == 0
    W = float(np.mean(plane.latencies))
    T = 1 * 24 * HOUR
    lam = res.requests_served / T
    L = plane.area_request_s / T
    assert L == pytest.approx(lam * W, rel=0.05)


def test_queue_overflow_drops_requests():
    # one replica, long service, tiny queue: the flood must shed load
    cls = (ModelClass("heavy", 1.0, 50.0, 10.0, 30.0, 1e5),)
    trace = tuple((float(i), 0) for i in range(200))
    prof = ServingProfile(arrival_trace=trace, model_classes=cls,
                          replicas_per_site=1, max_batch=2,
                          max_queue_batches=2, validate=True)
    sim = serving_only_sim(prof, days=1)
    res = sim.run()
    assert res.requests_dropped > 0
    assert res.requests_arrived == res.requests_served + res.requests_dropped


# ---------------------------------------------------------------------------
# integration with the training engine
# ---------------------------------------------------------------------------

def test_serving_disabled_is_bit_identical():
    """A zero-rate serving profile must not perturb a training run at all:
    the plane is never constructed and no RNG stream is consumed."""
    base = ClusterSimulator.from_scenario(
        "paper-table6", "feasibility-aware",
        overrides=dict(days=2, n_jobs=24)).run()
    off = ClusterSimulator.from_scenario(
        "paper-table6", "feasibility-aware",
        overrides=dict(days=2, n_jobs=24,
                       serving=ServingProfile(req_per_s_per_site=0.0))).run()
    wallclock = {"wall_s", "ticks_per_sec", "decide_s", "decide_first_s"}
    trim = lambda s: {k: v for k, v in s.items() if k not in wallclock}
    assert trim(off.summary()) == trim(base.summary()) != {}
    assert base.requests_arrived == 0


def test_fixed_dt_engine_rejects_serving():
    prof = ServingProfile(req_per_s_per_site=0.01)
    sim = ClusterSimulator.from_scenario(
        "paper-table6", "static",
        overrides=dict(days=1, n_jobs=4, engine="fixed-dt", serving=prof))
    with pytest.raises(ValueError, match="next-event"):
        sim.run()


def test_train_plus_serve_scenario_runs_and_reports():
    sim = ClusterSimulator.from_scenario(
        "train-plus-serve", "feasibility-aware",
        overrides=dict(days=2, n_jobs=24))
    res = sim.run()
    s = res.summary()
    assert res.completed == 24
    assert s["requests_arrived"] > 0
    assert s["requests_served"] + s["requests_dropped"] == s["requests_arrived"]
    assert 0.0 <= s["slo_attainment"] <= 1.0
    assert s["latency_p50_s"] <= s["latency_p95_s"] <= s["latency_p99_s"]
    # served energy is billed separately from training energy
    assert s["serve_grid_kwh"] + s["serve_renewable_kwh"] > 0.0


def test_sweep_determinism_across_worker_counts():
    spec = SweepSpec(scenarios=("inference-diurnal",),
                     policies=("feasibility-aware",), seeds=(0, 1),
                     overrides=dict(days=1, n_jobs=8))
    a = run_sweep(spec, workers=1)
    b = run_sweep(spec, workers=2)
    wallclock = {"wall_s", "ticks_per_sec", "decide_s", "decide_first_s"}

    def key(res):
        return sorted(
            (r.scenario, r.seed,
             tuple(sorted((k, v) for k, v in r.summary.items()
                          if k not in wallclock)))
            for r in res.runs)

    assert key(a) == key(b)
    assert all(r.summary["requests_served"] > 0 for r in a.runs)


# ---------------------------------------------------------------------------
# acceptance: carbon-slo beats nearest on request carbon at equal SLO
# ---------------------------------------------------------------------------

def test_carbon_slo_beats_nearest_on_train_plus_serve():
    """ISSUE acceptance: over 8 seeds of train-plus-serve, the carbon-slo
    router posts lower total request grid gCO2 than nearest at
    equal-or-better p95 SLO attainment (mean + 95% CI via the sweep
    engine)."""
    def sweep(router):
        spec = SweepSpec(scenarios=("train-plus-serve",),
                         policies=("feasibility-aware",),
                         seeds=tuple(range(8)),
                         overrides=dict(days=2, n_jobs=24,
                                        serving_router=router))
        res = run_sweep(spec, workers=4)
        return res.aggregate()[("train-plus-serve", "feasibility-aware")]

    near = sweep("nearest")
    slo = sweep("carbon-slo")
    g_near, g_slo = near["request_gco2"], slo["request_gco2"]
    # non-overlapping 95% confidence intervals, not just a lower mean
    assert g_slo["mean"] + g_slo["ci95"] < g_near["mean"] - g_near["ci95"]
    assert slo["slo_attainment"]["mean"] >= \
        near["slo_attainment"]["mean"] - 1e-9
    # same arrival stream per seed regardless of router
    assert slo["requests_arrived"]["mean"] == near["requests_arrived"]["mean"]
