"""Algorithm 1 invariants on the typed Action/ClusterState API: the
feasibility filter is a hard safety boundary, the policy registry resolves
names/aliases/configs, and the advertised bandwidth matrix matches the
per-NIC share model."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic tests still run
    HAS_HYPOTHESIS = False

from repro.core import feasibility as fz
from repro.core.actions import Migrate, Throttle
from repro.core.orchestrator import (
    EnergyOnlyPolicy, FeasibilityAwarePolicy, FeasibilityConfig,
    GridThrottlePolicy, OraclePolicy, StaticPolicy, available_policies,
    make_policy,
)
from repro.core.state import ClusterState, JobView, SiteView, advertised_bandwidth

GB = 1e9


def make_state(jobs, sites, bw_gbps=10.0):
    return ClusterState.build(t=0.0, jobs=jobs, sites=sites,
                              nic_bps=bw_gbps * 1e9)


def green_site(sid, window_h=2.5, slots=4, busy=0, queued=0):
    return SiteView(sid, slots, busy, queued, True, window_h * 3600.0)


def dark_site(sid, slots=4, busy=0, queued=0):
    return SiteView(sid, slots, busy, queued, False, 0.0)


def test_static_never_migrates():
    jobs = [JobView(0, 0, 1 * GB, 3600.0)]
    state = make_state(jobs, [dark_site(0), green_site(1)])
    assert StaticPolicy().decide(state) == []


def test_feasibility_never_migrates_class_c():
    """Class C (T_transfer >= 300 s) jobs are NEVER migrated (§VI.D)."""
    jobs = [JobView(0, 0, 400 * GB, 50 * 3600.0)]  # 320 s @ 10 Gbps
    state = make_state(jobs, [dark_site(0), green_site(1, window_h=9.5)])
    assert FeasibilityAwarePolicy().decide(state) == []


def test_feasibility_respects_alpha_window():
    """A migration whose T_cost exceeds α·window is rejected even for small
    checkpoints."""
    jobs = [JobView(0, 0, 30 * GB, 50 * 3600.0)]  # t_cost ≈ 34.7 s
    # α=0.1: need window > 347 s; give 300 s
    sites = [dark_site(0), SiteView(1, 4, 0, 0, True, 300.0)]
    assert FeasibilityAwarePolicy().decide(make_state(jobs, sites)) == []
    # with a 2.5 h window it migrates
    sites = [dark_site(0), green_site(1)]
    actions = FeasibilityAwarePolicy().decide(make_state(jobs, sites))
    assert actions == [Migrate(0, 1)]


def test_feasibility_prefers_less_loaded_feasible_site():
    jobs = [JobView(0, 0, 2 * GB, 10 * 3600.0)]
    sites = [
        dark_site(0),
        green_site(1, window_h=3.0, busy=4, queued=6),  # congested
        green_site(2, window_h=3.0, busy=0),
    ]
    actions = FeasibilityAwarePolicy().decide(make_state(jobs, sites))
    assert actions == [Migrate(0, 2)]


def test_feasibility_skips_non_migratable_jobs():
    """Queued/paused jobs and jobs inside the cooldown are never migrated."""
    jobs = [
        JobView(0, 0, 2 * GB, 10 * 3600.0, state="queued"),
        JobView(1, 0, 2 * GB, 10 * 3600.0, state="paused"),
        JobView(2, 0, 2 * GB, 10 * 3600.0, state="running", eligible=False),
        JobView(3, 0, 2 * GB, 10 * 3600.0, state="running"),
    ]
    actions = FeasibilityAwarePolicy().decide(
        make_state(jobs, [dark_site(0), green_site(1)]))
    assert actions == [Migrate(3, 1)]


def test_energy_only_ignores_feasibility():
    """The baseline launches Class C transfers — that's its failure mode."""
    jobs = [JobView(0, 0, 400 * GB, 50 * 3600.0)]
    state = make_state(jobs, [dark_site(0), green_site(1)])
    assert EnergyOnlyPolicy().decide(state) == [Migrate(0, 1)]


def test_grid_throttle_only_on_dark_sites():
    jobs = [
        JobView(0, 0, 1 * GB, 3600.0),  # dark site -> throttle
        JobView(1, 1, 1 * GB, 3600.0),  # green site at full power -> nothing
        JobView(2, 1, 1 * GB, 3600.0, power_frac=0.5),  # green -> restore
    ]
    actions = GridThrottlePolicy(power_frac=0.5).decide(
        make_state(jobs, [dark_site(0), green_site(1)]))
    assert actions == [Throttle(0, 0.5), Throttle(2, 1.0)]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_oracle_is_feasibility_aware():
    p = make_policy("oracle")
    assert isinstance(p, FeasibilityAwarePolicy)
    assert isinstance(p, OraclePolicy)
    assert p.name == "oracle"
    assert p.wants_oracle_forecast


def test_registry_lists_all_builtins():
    names = available_policies()
    for want in ("static", "energy-only", "feasibility-aware", "oracle",
                 "grid-throttle", "defer-to-window", "plan-ahead"):
        assert want in names


def test_defer_to_window_skips_held_jobs():
    """Regression (ISSUE 3): a queued job already holding for a window must
    not be re-deferred every tick — one Defer per (job, window)."""
    from repro.core.actions import Defer
    from repro.core.orchestrator import DeferToWindowPolicy

    site = SiteView(0, 4, 4, 1, False, 0.0, next_window_start_s=1800.0)
    fresh = [JobView(0, 0, 1 * GB, 3600.0, state="queued")]
    state = ClusterState.build(t=0.0, jobs=fresh, sites=[site], nic_bps=1e10)
    assert DeferToWindowPolicy().decide(state) == [Defer(0, 1800.0)]
    held = [JobView(0, 0, 1 * GB, 3600.0, state="queued",
                    defer_until_s=1800.0)]
    state2 = ClusterState.build(t=0.0, jobs=held, sites=[site], nic_bps=1e10)
    assert DeferToWindowPolicy().decide(state2) == []
    # once the hold expired (and the site is still dark before a later
    # window) a fresh Defer is legitimate again
    site3 = SiteView(0, 4, 4, 1, False, 0.0, next_window_start_s=7200.0)
    state3 = ClusterState.build(t=3600.0, jobs=held, sites=[site3],
                                nic_bps=1e10)
    assert DeferToWindowPolicy().decide(state3) == [Defer(0, 7200.0)]


def test_registry_aliases_and_normalization():
    assert isinstance(make_policy("energy_only"), EnergyOnlyPolicy)
    assert isinstance(make_policy("energyonly"), EnergyOnlyPolicy)
    assert isinstance(make_policy("ours"), FeasibilityAwarePolicy)
    assert isinstance(make_policy("Feasibility"), FeasibilityAwarePolicy)


def test_registered_names_are_normalized_and_resolvable():
    """Names registered with underscores/uppercase must round-trip through
    make_policy (keys are stored normalized)."""
    from repro.core.orchestrator import (
        _ALIASES, _CONFIGS, _REGISTRY, Policy, register_policy,
    )

    @register_policy("My_Custom_Policy", aliases=("MCP",))
    class MyCustomPolicy(Policy):
        def decide(self, state):
            return []

    try:
        assert MyCustomPolicy.name == "my-custom-policy"
        assert isinstance(make_policy("My_Custom_Policy"), MyCustomPolicy)
        assert isinstance(make_policy("my-custom-policy"), MyCustomPolicy)
        assert isinstance(make_policy("mcp"), MyCustomPolicy)
        assert "my-custom-policy" in available_policies()
    finally:
        _REGISTRY.pop("my-custom-policy", None)
        _CONFIGS.pop("my-custom-policy", None)
        _ALIASES.pop("mcp", None)


def test_unknown_policy_lists_available_names():
    with pytest.raises(KeyError) as ei:
        make_policy("does-not-exist")
    msg = str(ei.value)
    assert "does-not-exist" in msg
    for name in available_policies():
        assert name in msg


def test_config_fields_stay_in_sync_with_policies():
    """The config dataclasses mirror their policy's fields; this guards the
    two-place knob lists against drifting apart (a missing mirror makes
    make_policy raise TypeError on the asdict splat)."""
    import dataclasses

    from repro.core.orchestrator import (
        DeferConfig, DeferToWindowPolicy, GridThrottlePolicy, PlanAheadConfig,
        PlanAheadPolicy, ThrottleConfig,
    )

    for config_cls, policy_cls in [
        (FeasibilityConfig, FeasibilityAwarePolicy),
        (ThrottleConfig, GridThrottlePolicy),
        (DeferConfig, DeferToWindowPolicy),
        (PlanAheadConfig, PlanAheadPolicy),
    ]:
        cfg_fields = {f.name for f in dataclasses.fields(config_cls)}
        pol_fields = {f.name for f in dataclasses.fields(policy_cls)}
        assert cfg_fields == pol_fields, (config_cls, policy_cls)


def test_policy_config_dataclass_reaches_policy():
    cfgd = FeasibilityConfig(eps=0.05, forecast_sigma_s=900.0, alpha=0.2)
    p = make_policy("feasibility-aware", config=cfgd)
    assert p.eps == 0.05 and p.forecast_sigma_s == 900.0 and p.alpha == 0.2
    # kwargs override config fields
    p2 = make_policy("feasibility-aware", config=cfgd, alpha=0.3)
    assert p2.alpha == 0.3 and p2.eps == 0.05


def test_stochastic_feasibility_tightens_decisions():
    """eps>0 + sigma>0 rejects migrations the deterministic gate accepts
    when the window barely clears T_cost/α."""
    jobs = [JobView(0, 0, 30 * GB, 50 * 3600.0)]  # t_cost ≈ 34.7 s -> need 347 s
    sites = [dark_site(0), SiteView(1, 4, 0, 0, True, 420.0)]
    state = make_state(jobs, sites)
    det = FeasibilityAwarePolicy(min_benefit_s=0.0)
    assert det.decide(state) == [Migrate(0, 1)]
    stoch = FeasibilityAwarePolicy(min_benefit_s=0.0, eps=0.05,
                                   forecast_sigma_s=900.0)
    assert stoch.decide(state) == []


# ---------------------------------------------------------------------------
# ClusterState bandwidth advertisement (per-NIC share counts)
# ---------------------------------------------------------------------------


def test_advertised_bandwidth_matches_nic_shares():
    nic = 10e9
    # two transfers out of site 0, one into site 2
    bw = advertised_bandwidth(4, nic, transfers=[(0, 2), (0, 3)])
    assert bw[0, 1] == pytest.approx(nic / 2)  # src shared 2-way, dst idle
    assert bw[0, 2] == pytest.approx(nic / 2)  # min(nic/2, nic/1)
    assert bw[1, 2] == pytest.approx(nic)  # dst has 1 flow: full rate...
    assert bw[1, 3] == pytest.approx(nic)
    assert bw[1, 0] == pytest.approx(nic)  # inbound to 0 is free


def test_advertised_bandwidth_min_of_both_nics():
    nic = 10e9
    bw = advertised_bandwidth(3, nic, transfers=[(0, 1), (0, 1), (2, 1)])
    # site0 src 2 flows; site1 dst 3 flows -> min(nic/2, nic/3)
    assert bw[0, 1] == pytest.approx(nic / 3)
    assert bw[2, 1] == pytest.approx(nic / 3)
    assert bw[2, 0] == pytest.approx(nic)


# ---------------------------------------------------------------------------
# Property: every decision satisfies the formal feasibility domain (§VI.E)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    job_st = st.builds(
        JobView,
        jid=st.integers(0, 63),
        site=st.integers(0, 4),
        ckpt_bytes=st.floats(min_value=0.1 * GB, max_value=500 * GB),
        remaining_compute_s=st.floats(min_value=600, max_value=24 * 3600),
    )

    site_st = st.builds(
        SiteView,
        sid=st.integers(0, 0),  # replaced below
        slots=st.just(4),
        busy=st.integers(0, 4),
        queued=st.integers(0, 6),
        renewable_active=st.booleans(),
        window_remaining_s=st.floats(min_value=0, max_value=9.5 * 3600),
    )

    @settings(max_examples=100, deadline=None)
    @given(st.lists(job_st, min_size=1, max_size=8),
           st.lists(site_st, min_size=5, max_size=5),
           st.floats(min_value=0.5, max_value=100.0))
    def test_decisions_always_in_feasible_domain(jobs, sites, bw_gbps):
        for i, s in enumerate(sites):
            s.sid = i
            if not s.renewable_active:
                s.window_remaining_s = 0.0
        # deduplicate jids (the simulator guarantees uniqueness)
        jobs_by_id = {}
        for j in jobs:
            j.site = j.site % 5
            jobs_by_id.setdefault(j.jid, j)
        jobs = list(jobs_by_id.values())
        state = make_state(jobs, sites, bw_gbps)
        for action in FeasibilityAwarePolicy().decide(state):
            assert isinstance(action, Migrate)
            j = jobs_by_id[action.jid]
            assert action.dest != j.site
            v = fz.evaluate(
                j.ckpt_bytes, bw_gbps * 1e9, sites[action.dest].window_remaining_s
            )
            assert bool(v.feasible), (
                f"infeasible migration chosen: {j.ckpt_bytes/GB:.1f} GB "
                f"@ {bw_gbps} Gbps window={sites[action.dest].window_remaining_s}s"
            )
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests inactive")
    def test_decisions_always_in_feasible_domain():
        pass
