"""Algorithm 1 invariants: the feasibility filter is a hard safety boundary."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import feasibility as fz
from repro.core.orchestrator import (
    EnergyOnlyPolicy, FeasibilityAwarePolicy, JobView, OrchestratorContext,
    SiteView, StaticPolicy, make_policy,
)

GB = 1e9


def make_ctx(jobs, sites, bw_gbps=10.0):
    n = len(sites)
    return OrchestratorContext(
        t=0.0, jobs=jobs, sites=sites,
        bandwidth_bps=np.full((n, n), bw_gbps * 1e9),
    )


def green_site(sid, window_h=2.5, slots=4, busy=0, queued=0):
    return SiteView(sid, slots, busy, queued, True, window_h * 3600.0)


def dark_site(sid, slots=4, busy=0, queued=0):
    return SiteView(sid, slots, busy, queued, False, 0.0)


def test_static_never_migrates():
    jobs = [JobView(0, 0, 1 * GB, 3600.0)]
    ctx = make_ctx(jobs, [dark_site(0), green_site(1)])
    assert StaticPolicy().decide(ctx) == []


def test_feasibility_never_migrates_class_c():
    """Class C (T_transfer >= 300 s) jobs are NEVER migrated (§VI.D)."""
    jobs = [JobView(0, 0, 400 * GB, 50 * 3600.0)]  # 320 s @ 10 Gbps
    ctx = make_ctx(jobs, [dark_site(0), green_site(1, window_h=9.5)])
    assert FeasibilityAwarePolicy().decide(ctx) == []


def test_feasibility_respects_alpha_window():
    """A migration whose T_cost exceeds α·window is rejected even for small
    checkpoints."""
    jobs = [JobView(0, 0, 30 * GB, 50 * 3600.0)]  # t_cost ≈ 34.7 s
    # α=0.1: need window > 347 s; give 300 s
    sites = [dark_site(0), SiteView(1, 4, 0, 0, True, 300.0)]
    assert FeasibilityAwarePolicy().decide(make_ctx(jobs, sites)) == []
    # with a 2.5 h window it migrates
    sites = [dark_site(0), green_site(1)]
    dec = FeasibilityAwarePolicy().decide(make_ctx(jobs, sites))
    assert dec == [(0, 1)]


def test_feasibility_prefers_less_loaded_feasible_site():
    jobs = [JobView(0, 0, 2 * GB, 10 * 3600.0)]
    sites = [
        dark_site(0),
        green_site(1, window_h=3.0, busy=4, queued=6),  # congested
        green_site(2, window_h=3.0, busy=0),
    ]
    dec = FeasibilityAwarePolicy().decide(make_ctx(jobs, sites))
    assert dec == [(0, 2)]


def test_energy_only_ignores_feasibility():
    """The baseline launches Class C transfers — that's its failure mode."""
    jobs = [JobView(0, 0, 400 * GB, 50 * 3600.0)]
    ctx = make_ctx(jobs, [dark_site(0), green_site(1)])
    assert EnergyOnlyPolicy().decide(ctx) == [(0, 1)]


def test_oracle_is_feasibility_aware():
    p = make_policy("oracle")
    assert isinstance(p, FeasibilityAwarePolicy)
    assert p.name == "oracle"


# ---------------------------------------------------------------------------
# Property: every decision satisfies the formal feasibility domain (§VI.E)
# ---------------------------------------------------------------------------

job_st = st.builds(
    JobView,
    jid=st.integers(0, 63),
    site=st.integers(0, 4),
    ckpt_bytes=st.floats(min_value=0.1 * GB, max_value=500 * GB),
    remaining_compute_s=st.floats(min_value=600, max_value=24 * 3600),
)

site_st = st.builds(
    SiteView,
    sid=st.integers(0, 0),  # replaced below
    slots=st.just(4),
    busy=st.integers(0, 4),
    queued=st.integers(0, 6),
    renewable_active=st.booleans(),
    window_remaining_s=st.floats(min_value=0, max_value=9.5 * 3600),
)


@settings(max_examples=100, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=8), st.lists(site_st, min_size=5, max_size=5),
       st.floats(min_value=0.5, max_value=100.0))
def test_decisions_always_in_feasible_domain(jobs, sites, bw_gbps):
    for i, s in enumerate(sites):
        s.sid = i
        if not s.renewable_active:
            s.window_remaining_s = 0.0
    # deduplicate jids (the simulator guarantees uniqueness)
    jobs_by_id = {}
    for j in jobs:
        j.site = j.site % 5
        jobs_by_id.setdefault(j.jid, j)
    jobs = list(jobs_by_id.values())
    ctx = make_ctx(jobs, sites, bw_gbps)
    for jid, dest in FeasibilityAwarePolicy().decide(ctx):
        j = jobs_by_id[jid]
        assert dest != j.site
        v = fz.evaluate(
            j.ckpt_bytes, bw_gbps * 1e9, sites[dest].window_remaining_s
        )
        assert bool(v.feasible), (
            f"infeasible migration chosen: {j.ckpt_bytes/GB:.1f} GB "
            f"@ {bw_gbps} Gbps window={sites[dest].window_remaining_s}s"
        )
